"""Quickstart: Arcus in 60 seconds.

Two tenants share one accelerator.  We register SLOs with the runtime
(admission control), run the managed dataplane (hardware token-bucket
shaping + Algorithm-1 monitoring), and print per-tenant achieved
throughput vs. SLO.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import SLO, FlowSpec, Path, TrafficPattern
from repro.core.accelerator import CATALOG
from repro.core.runtime import ArcusRuntime


def main() -> None:
    # one 32 Gbps IPSec accelerator, provider-managed
    rt = ArcusRuntime([CATALOG["ipsec32"]])

    # two tenants want 10 and 20 Gbps of accelerator throughput
    ok1 = rt.register(FlowSpec(0, vm_id=0, path=Path.FUNCTION_CALL,
                               accel_id=0,
                               pattern=TrafficPattern(1500, load=0.9),
                               slo=SLO.gbps(10)))
    ok2 = rt.register(FlowSpec(1, vm_id=1, path=Path.FUNCTION_CALL,
                               accel_id=0,
                               pattern=TrafficPattern(1500, load=0.9),
                               slo=SLO.gbps(20)))
    # a third tenant wanting 10 more Gbps is REJECTED: the profiled
    # Capacity(t, X, N) table says the mixture can't satisfy 40 Gbps
    ok3 = rt.register(FlowSpec(2, vm_id=2, path=Path.FUNCTION_CALL,
                               accel_id=0,
                               pattern=TrafficPattern(1500, load=0.9),
                               slo=SLO.gbps(10)))
    print(f"admission: tenant0={ok1} tenant1={ok2} tenant2={ok3} (expected "
          "True True False)")

    # run ~4 ms of the cycle-accurate dataplane with periodic SLO checks
    _, reports = rt.run_managed(total_ticks=120_000, window_ticks=30_000,
                                load_ref_gbps={0: 32.0, 1: 32.0})
    for r in reports:
        line = " ".join(f"tenant{k}={v:6.2f}Gbps" for k, v in
                        sorted(r.measured.items()))
        print(f"t={r.t_end_s*1e3:6.2f}ms  {line}  violations={r.violated}")
    print("SLOs: tenant0=10.00 Gbps, tenant1=20.00 Gbps")


if __name__ == "__main__":
    main()
