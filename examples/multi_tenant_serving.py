"""Multi-tenant TPU model serving with Arcus SLOs (end-to-end driver).

Serves a (reduced) gemma3-family model to three tenants with batched
requests through the continuous-batching engine:

  * tenant 0: Reserved     — 3000 tokens/s guarantee
  * tenant 1: OnDemand     — 2000 tokens/s
  * tenant 2: Opportunistic — no guarantee, harvests leftover capacity
    (the paper's live-migration / background-job story, Sec 5.4)

The scheduler's clock is the v5e roofline cost model; per-tenant token
buckets (the Arcus mechanism) gate prompt admission.

    PYTHONPATH=src python examples/multi_tenant_serving.py
"""
import numpy as np

from repro.configs.registry import get_reduced_config
from repro.core.flow import SLO
from repro.models import transformer as T
from repro.serving.costmodel import HardwareSpec, StepCostModel
from repro.serving.engine import ServingEngine
from repro.serving.request import Request, Tenant
from repro.serving.scheduler import ArcusScheduler


def main() -> None:
    cfg = get_reduced_config("gemma3-12b")
    print(f"arch family: {cfg.name} ({cfg.n_layers}L d={cfg.d_model})")
    params, _ = T.init_model(0, cfg)
    engine = ServingEngine(cfg, params, max_batch=8, max_len=256)
    cost = StepCostModel(cfg, HardwareSpec(chips=1))
    tenants = [Tenant(0, SLO.iops(3000.0), "reserved"),
               Tenant(1, SLO.iops(2000.0), "on_demand"),
               Tenant(2, SLO.iops(1e9), "opportunistic")]
    sched = ArcusScheduler(engine, tenants, cost)

    rng = np.random.default_rng(0)
    rid = 0
    # the opportunistic tenant dumps a pile of long prompts at t=0
    for _ in range(10):
        sched.submit(Request(rid, 2, list(rng.integers(0, cfg.vocab, 64)),
                             16))
        rid += 1
    # SLO tenants trickle short requests
    for k in range(12):
        for tid in (0, 1):
            sched.submit(Request(rid, tid,
                                 list(rng.integers(0, cfg.vocab, 12)), 6,
                                 arrive_s=k * 0.12))
            rid += 1

    stats = sched.run(duration_s=3.0, max_rounds=600)
    print(f"virtual time served: {sched.now_s:.2f}s")
    for tid, st in sorted(stats.items()):
        ttft = f"{np.mean(st.ttft)*1e3:7.1f}ms" if st.ttft else "    n/a"
        print(f"tenant{tid} [{tenants[tid].policy:13s}] tokens={st.served_tokens:5d} "
              f"finished={st.finished:3d} mean_ttft={ttft}")


if __name__ == "__main__":
    main()
