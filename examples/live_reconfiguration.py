"""Live shaping reconfiguration (paper Sec 5.3.1 "Dynamism").

A tenant's SLO is raised mid-flight; the control plane rewrites the
token-bucket registers WITHOUT stopping the dataplane (the simulator's
carry keeps queues/timers/counters), exactly like the paper's ~10 us MMIO
reconfiguration.  The register write is a traced argument of the compiled
engine, so every window after the first is a pure cache hit (the engine
stats printed at the end show one compile for all three windows), and the
carry is donated between windows — state stays on device.

    PYTHONPATH=src python examples/live_reconfiguration.py
"""
import numpy as np

from repro.core import engine, token_bucket as tb
from repro.core.accelerator import CATALOG, AccelTable
from repro.core.flow import SLO, FlowSet, FlowSpec, Path, TrafficPattern
from repro.core.interconnect import LinkSpec
from repro.core.sim import SimConfig, gen_arrivals, simulate


def main() -> None:
    flows = FlowSet.build([
        FlowSpec(0, 0, Path.FUNCTION_CALL, 0,
                 TrafficPattern(1024, load=0.9), SLO.gbps(10)),
        FlowSpec(1, 1, Path.FUNCTION_CALL, 0,
                 TrafficPattern(1024, load=0.9), SLO.gbps(5)),
    ])
    accels = AccelTable.build([CATALOG["synthetic50"]])
    window = SimConfig(n_ticks=50_000)  # 1.6 ms windows
    import dataclasses
    full = dataclasses.replace(window, n_ticks=3 * window.n_ticks)
    arr = gen_arrivals(flows, full, load_ref_gbps={0: 50.0, 1: 50.0})

    carry = None
    prev = np.zeros(2)
    slos = [(10.0, 5.0), (10.0, 25.0), (10.0, 25.0)]   # raise tenant1 @ w1
    for w, (s0, s1) in enumerate(slos):
        tbs = tb.pack([tb.params_for_gbps(s0), tb.params_for_gbps(s1)])
        res, carry = simulate(flows, accels, LinkSpec(), window, tbs, *arr,
                              t0_ticks=w * window.n_ticks, carry=carry,
                              return_carry=True)
        done = np.asarray(res.counters["c_done_bytes"], float)
        w_s = window.n_ticks * window.tick_cycles / window.clock_hz
        rate = (done - prev) * 8 / w_s / 1e9
        prev = done
        note = "  <- registers rewritten mid-flight" if w == 1 else ""
        print(f"window {w}: SLO=({s0},{s1})  measured="
              f"({rate[0]:.2f}, {rate[1]:.2f}) Gbps{note}")
    info = engine.cache_info()
    print(f"engine: {info['traces']} compile(s) across {len(slos)} windows")


if __name__ == "__main__":
    main()
