"""End-to-end training driver: train a ~100M-param dense LM for a few
hundred steps on the synthetic pipeline (qwen2.5 family, reduced depth).

    PYTHONPATH=src python examples/train_small_lm.py [--steps 300]

On the production pod this same step function is what
`repro.launch.dryrun` lowers at (16, 16) / (2, 16, 16) mesh scale.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import module as nn, transformer as T
from repro.models.config import reduced
from repro.training import checkpoint as ckpt, optimizer as opt, train as TR


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    # ~100M params: qwen family, 8 layers, d=640
    cfg = reduced(get_config("qwen2.5-14b"), n_layers=8, d_model=640,
                  n_heads=8, d_ff=2048, vocab=32768)
    params, _ = T.init_model(0, cfg)
    print(f"model: {cfg.name} {nn.param_count(params)/1e6:.1f}M params")

    ocfg = opt.AdamWConfig(lr=6e-4, warmup_steps=30, total_steps=args.steps)
    step = jax.jit(TR.make_train_step(cfg, ocfg, remat=False))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch))
    ost = opt.init(params)
    t0 = time.time()
    for i, b in zip(range(args.steps), data.batches()):
        batch = {"tokens": jnp.asarray(b["tokens"]),
                 "mask": jnp.asarray(b["mask"])}
        params, ost, m = step(params, ost, batch)
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={float(m['loss']):.4f} "
                  f"gnorm={float(m['grad_norm']):.2f} "
                  f"lr={float(m['lr']):.2e} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
    ckpt.save(args.ckpt, params, ost, step=args.steps)
    print(f"checkpoint saved to {args.ckpt}")


if __name__ == "__main__":
    main()
