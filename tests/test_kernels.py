"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import token_bucket as tb
from repro.kernels.decode_attention import ops as da_ops, ref as da_ref
from repro.kernels.ssd_scan import ops as ssd_ops, ref as ssd_ref
from repro.kernels.token_bucket import ops as tb_ops, ref as tb_ref

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# token bucket
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 7, 128, 1000, 4096])
@pytest.mark.parametrize("elapsed", [0, 8, 1000, 10**7])
def test_token_bucket_kernel_matches_oracle(n, elapsed):
    st = tb.init(RNG.integers(1, 5000, n).astype(np.int32),
                 RNG.integers(512, 1 << 20, n).astype(np.int32),
                 RNG.integers(1, 1024, n).astype(np.int32),
                 RNG.integers(0, 2, n).astype(np.int32))
    st = st._replace(
        tokens=jnp.asarray(RNG.integers(0, 1 << 20, n), jnp.int32),
        cyc=jnp.asarray(RNG.integers(0, 1024, n), jnp.int32) % st.interval)
    cost = RNG.integers(1, 8192, n).astype(np.int32)
    want = RNG.random(n) < 0.8
    new_k, adm_k = tb_ops.token_bucket_step(st, elapsed, cost, want)
    tk, ck, adm_r = tb_ref.token_bucket_step(
        st.tokens, st.cyc, st.refill_rate, st.bkt_size, st.interval,
        st.mode, elapsed, cost, want)
    np.testing.assert_array_equal(np.asarray(new_k.tokens), np.asarray(tk))
    np.testing.assert_array_equal(np.asarray(new_k.cyc), np.asarray(ck))
    np.testing.assert_array_equal(np.asarray(adm_k), np.asarray(adm_r))


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

DA_CASES = [
    # B, H, KvH, D, S, window, dtype
    (2, 16, 8, 128, 1024, 0, jnp.float32),
    (1, 8, 1, 64, 512, 0, jnp.float32),
    (3, 12, 2, 80, 777, 0, jnp.float32),
    (2, 16, 8, 128, 2048, 256, jnp.bfloat16),
    (1, 40, 8, 128, 4096, 1024, jnp.float32),
    (2, 16, 16, 96, 300, 0, jnp.bfloat16),
    (1, 24, 2, 128, 640, 128, jnp.float32),
]


@pytest.mark.parametrize("case", DA_CASES)
def test_decode_attention_matches_oracle(case):
    B, H, KvH, D, S, w, dt = case
    q = jnp.asarray(RNG.standard_normal((B, H, D)), dt)
    k = jnp.asarray(RNG.standard_normal((B, S, KvH, D)), dt)
    v = jnp.asarray(RNG.standard_normal((B, S, KvH, D)), dt)
    lengths = jnp.asarray(RNG.integers(max(1, S // 4), S + 1, B), jnp.int32)
    out_k = da_ops.decode_attention(q, k, v, lengths, window=w)
    out_r = da_ref.decode_attention(q, k, v, lengths, window=w)
    tol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    err = np.max(np.abs(np.asarray(out_k, np.float32)
                        - np.asarray(out_r, np.float32)))
    assert err < tol, (case, err)


def test_decode_attention_ignores_padding_region():
    """Entries beyond `lengths` must not affect the output."""
    B, H, KvH, D, S = 2, 8, 4, 64, 256
    q = jnp.asarray(RNG.standard_normal((B, H, D)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, KvH, D)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, KvH, D)), jnp.float32)
    lengths = jnp.asarray([100, 180], jnp.int32)
    out1 = da_ops.decode_attention(q, k, v, lengths)
    k2 = k.at[:, 200:].set(1e6)
    v2 = v.at[:, 200:].set(-1e6)
    out2 = da_ops.decode_attention(q, k2, v2, lengths)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2))


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

SSD_CASES = [
    # Bsz, L, H, P, G, N, chunk, dtype
    (2, 256, 4, 64, 1, 128, 64, jnp.float32),
    (1, 100, 3, 32, 1, 64, 32, jnp.float32),
    (2, 128, 8, 64, 2, 128, 128, jnp.float32),
    (1, 512, 4, 64, 1, 128, 128, jnp.bfloat16),
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_scan_matches_oracle(case):
    Bz, L, H, P, G, N, ck, dt = case
    x = jnp.asarray(RNG.standard_normal((Bz, L, H, P)) * 0.5, dt)
    a = jnp.asarray(RNG.uniform(0.7, 0.999, (Bz, L, H)), jnp.float32)
    B = jnp.asarray(RNG.standard_normal((Bz, L, G, N)) * 0.3, dt)
    C = jnp.asarray(RNG.standard_normal((Bz, L, G, N)) * 0.3, dt)
    yk, sk = ssd_ops.ssd_scan(x, a, B, C, chunk=ck)
    yr, sr = ssd_ref.ssd_scan(x, a, B, C)
    tol = 1e-1 if dt == jnp.bfloat16 else 2e-3
    rel = np.max(np.abs(np.asarray(yk, np.float32)
                        - np.asarray(yr, np.float32))) \
        / (np.abs(np.asarray(yr, np.float32)).max() + 1e-9)
    assert rel < tol, (case, rel)
    srel = np.max(np.abs(np.asarray(sk) - np.asarray(sr))) \
        / (np.abs(np.asarray(sr)).max() + 1e-9)
    assert srel < tol


def test_ssd_decode_step_matches_scan_tail():
    """Running L-1 steps via scan then 1 decode step == full scan."""
    Bz, L, H, P, G, N = 1, 64, 2, 32, 1, 64
    x = jnp.asarray(RNG.standard_normal((Bz, L, H, P)) * 0.5, jnp.float32)
    a = jnp.asarray(RNG.uniform(0.8, 0.999, (Bz, L, H)), jnp.float32)
    B = jnp.asarray(RNG.standard_normal((Bz, L, G, N)) * 0.3, jnp.float32)
    C = jnp.asarray(RNG.standard_normal((Bz, L, G, N)) * 0.3, jnp.float32)
    y_full, s_full = ssd_ref.ssd_scan(x, a, B, C)
    _, s_head = ssd_ref.ssd_scan(x[:, :L-1], a[:, :L-1], B[:, :L-1],
                                 C[:, :L-1])
    s_dec, y_dec = ssd_ref.ssd_decode_step(s_head, x[:, L-1], a[:, L-1],
                                           B[:, L-1], C[:, L-1])
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full[:, L-1]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_dec), np.asarray(s_full),
                               rtol=1e-4, atol=1e-4)
