"""Serving engine + Arcus scheduler tests."""
import numpy as np
import pytest

from repro.configs.registry import get_reduced_config
from repro.core.flow import SLO
from repro.models import transformer as T
from repro.serving.costmodel import HardwareSpec, StepCostModel
from repro.serving.engine import ServingEngine
from repro.serving.request import Request, Tenant
from repro.serving.scheduler import ArcusScheduler, FCFSScheduler

RNG = np.random.default_rng(0)


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced_config("qwen2.5-14b")
    params, _ = T.init_model(0, cfg)
    return cfg, params


def test_engine_generates_deterministically(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, max_batch=2, max_len=64)
    req = Request(0, 0, list(RNG.integers(0, cfg.vocab, 8)), 5)
    eng.admit(req)
    while not req.done:
        eng.step()
    assert len(req.generated) == 5
    # same prompt, fresh engine -> same tokens (greedy)
    eng2 = ServingEngine(cfg, params, max_batch=2, max_len=64)
    req2 = Request(1, 0, list(req.prompt), 5)
    eng2.admit(req2)
    while not req2.done:
        eng2.step()
    assert req.generated == req2.generated


def test_engine_batched_equals_single(setup):
    """Continuous batching must not change any request's tokens."""
    cfg, params = setup
    prompts = [list(RNG.integers(0, cfg.vocab, 8)) for _ in range(3)]
    solo = []
    for i, p in enumerate(prompts):
        eng = ServingEngine(cfg, params, max_batch=1, max_len=64)
        r = Request(i, 0, p, 4)
        eng.admit(r)
        while not r.done:
            eng.step()
        solo.append(r.generated)
    eng = ServingEngine(cfg, params, max_batch=4, max_len=64)
    reqs = [Request(10 + i, 0, p, 4) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.admit(r)
    while any(not r.done for r in reqs):
        eng.step()
    for s, r in zip(solo, reqs):
        assert s == r.generated


def test_cost_model_monotonic(setup):
    cfg, _ = setup
    cm = StepCostModel(cfg, HardwareSpec(chips=1))
    assert cm.decode_s(8, 1024) > cm.decode_s(1, 1024)
    assert cm.decode_s(1, 8192) > cm.decode_s(1, 256)
    assert cm.prefill_s(1, 2048) > cm.prefill_s(1, 128)


def test_arcus_scheduler_shapes_greedy_tenant(setup):
    cfg, params = setup

    def build(shaped):
        eng = ServingEngine(cfg, params, max_batch=4, max_len=128)
        cm = StepCostModel(cfg, HardwareSpec(chips=1))
        tenants = [Tenant(0, SLO.iops(2000.0)), Tenant(1, SLO.iops(200.0))]
        cls = ArcusScheduler if shaped else FCFSScheduler
        sched = cls(eng, tenants, cm)
        rid = 0
        # tenant 1 greedy: long prompts at t=0; tenant 0 trickles
        for _ in range(6):
            sched.submit(Request(rid, 1,
                                 list(RNG.integers(0, cfg.vocab, 48)), 8))
            rid += 1
        for k in range(6):
            sched.submit(Request(rid, 0,
                                 list(RNG.integers(0, cfg.vocab, 8)), 4,
                                 arrive_s=k * 0.05))
            rid += 1
        return sched

    arcus = build(True).run(3.0, max_rounds=250)
    fcfs = build(False).run(3.0, max_rounds=250)
    # shaped: tenant1 admission gated by its bucket -> tenant0 served early
    t0_ttft_arcus = np.mean(arcus[0].ttft) if arcus[0].ttft else np.inf
    t0_ttft_fcfs = np.mean(fcfs[0].ttft) if fcfs[0].ttft else np.inf
    assert arcus[0].served_tokens > 0
    assert t0_ttft_arcus <= t0_ttft_fcfs + 1e-9
