"""Property tests on layer-level invariants (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # optional dev dep — property tests skip
    from _hypothesis_stub import given, settings, st

from repro.models import layers as L, module as nn
from repro.models.config import ArchConfig

RNG = np.random.default_rng(7)


def _naive_attention(q, k, v, *, causal=True, window=0, chunk_size=0):
    B, Sq, H, D = q.shape
    Sk, KvH = k.shape[1], k.shape[2]
    G = H // KvH
    qg = q.reshape(B, Sq, KvH, G, D).astype(jnp.float32)
    s = jnp.einsum("bqnhd,bknd->bqnhk", qg,
                   k.astype(jnp.float32)) * D ** -0.5
    qi = jnp.arange(Sq)[:, None]
    ki = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qi >= ki
        if window > 0:
            mask &= qi - ki < window
        if chunk_size > 0:
            mask &= (qi // chunk_size) == (ki // chunk_size)
    s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    o = jnp.einsum("bqnhk,bknd->bqnhd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D)


@settings(max_examples=12, deadline=None)
@given(s=st.integers(4, 96), h=st.sampled_from([2, 4]),
       kv=st.sampled_from([1, 2]), d=st.sampled_from([8, 16]),
       window=st.sampled_from([0, 7, 16]),
       kv_chunk=st.sampled_from([8, 32, 512]))
def test_flash_attention_matches_naive(s, h, kv, d, window, kv_chunk):
    """The chunked-online-softmax attention == naive softmax attention for
    any shape, window, and chunking."""
    if h % kv:
        h = kv * max(1, h // kv)
    q = jnp.asarray(RNG.standard_normal((2, s, h, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((2, s, kv, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((2, s, kv, d)), jnp.float32)
    got = L.flash_attention(q, k, v, mask_kind="causal", window=window,
                            kv_chunk=kv_chunk)
    want = _naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_chunked_mask():
    """llama4-style chunked attention equals naive with the same mask."""
    q = jnp.asarray(RNG.standard_normal((1, 64, 4, 16)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 64, 2, 16)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 64, 2, 16)), jnp.float32)
    got = L.flash_attention(q, k, v, chunk_size=16, kv_chunk=32)
    want = _naive_attention(q, k, v, chunk_size=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def _moe_cfg(n_experts=4, top_k=2):
    return ArchConfig(name="t", arch_type="moe", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                      n_experts=n_experts, top_k=top_k,
                      capacity_factor=100.0)  # huge capacity -> dropless


@pytest.mark.parametrize("top_k", [1, 2])
def test_moe_capacity_equals_dropless_at_high_capacity(top_k):
    """With capacity >> tokens, the capacity path must equal ragged_dot
    dropless dispatch exactly (same router, same experts)."""
    cfg = _moe_cfg(top_k=top_k)
    key = nn.KeyGen(3)
    p, _ = L.init_moe(key, cfg)
    x = jnp.asarray(RNG.standard_normal((2, 8, cfg.d_model)), jnp.float32)
    y_cap, _ = L.moe_block(p, x, cfg, dropless=False)
    y_drop, _ = L.moe_block(p, x, cfg, dropless=True)
    np.testing.assert_allclose(np.asarray(y_cap), np.asarray(y_drop),
                               rtol=1e-4, atol=1e-5)


def test_moe_gates_sum_to_one_effect():
    """Scaling all expert outputs scales the MoE output (gate linearity)."""
    cfg = _moe_cfg()
    key = nn.KeyGen(3)
    p, _ = L.init_moe(key, cfg)
    x = jnp.asarray(RNG.standard_normal((1, 6, cfg.d_model)), jnp.float32)
    y1, _ = L.moe_block(p, x, cfg, dropless=True)
    p2 = dict(p)
    p2["wo"] = p["wo"] * 2.0
    y2, _ = L.moe_block(p2, x, cfg, dropless=True)
    np.testing.assert_allclose(np.asarray(y2), 2 * np.asarray(y1),
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100), s=st.integers(4, 32))
def test_rglru_scan_matches_sequential(seed, s):
    """Associative-scan RG-LRU == step-by-step recurrence."""
    rng = np.random.default_rng(seed)
    B, W = 2, 8
    a = jnp.asarray(rng.uniform(0.5, 0.99, (B, s, W)), jnp.float32)
    gx = jnp.asarray(rng.standard_normal((B, s, W)), jnp.float32)
    h, h_last = L.rglru_scan(a, gx)
    # sequential reference
    ht = np.zeros((B, W), np.float32)
    mult = np.sqrt(np.maximum(1 - np.asarray(a) ** 2, 1e-9))
    for t in range(s):
        ht = np.asarray(a)[:, t] * ht + mult[:, t] * np.asarray(gx)[:, t]
        np.testing.assert_allclose(np.asarray(h[:, t]), ht, rtol=2e-4,
                                   atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_last), ht, rtol=2e-4, atol=2e-4)


def test_rglru_scan_with_initial_state():
    B, s, W = 1, 5, 4
    a = jnp.full((B, s, W), 0.9, jnp.float32)
    gx = jnp.ones((B, s, W), jnp.float32)
    h0 = 3.0 * jnp.ones((B, W), jnp.float32)
    h, _ = L.rglru_scan(a, gx, h0)
    # h_1 = a*h0 + sqrt(1-a^2)*gx
    want = 0.9 * 3.0 + np.sqrt(1 - 0.81)
    np.testing.assert_allclose(np.asarray(h[:, 0]), want, rtol=1e-5)


def test_rope_preserves_norm_and_relativity():
    """RoPE is an isometry, and q.k depends only on relative position."""
    D = 16
    x = jnp.asarray(RNG.standard_normal((1, 8, 2, D)), jnp.float32)
    pos = jnp.arange(8)[None, :]
    y = L.rope(x, pos, theta=10_000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    q = jnp.asarray(RNG.standard_normal((1, 1, 1, D)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 1, 1, D)), jnp.float32)
    def dot_at(pq, pk):
        qr = L.rope(q, jnp.asarray([[pq]]), theta=10_000.0)
        kr = L.rope(k, jnp.asarray([[pk]]), theta=10_000.0)
        return float(jnp.sum(qr * kr))
    assert abs(dot_at(5, 3) - dot_at(9, 7)) < 1e-4
    assert abs(dot_at(5, 3) - dot_at(6, 3)) > 1e-6  # actually position-dep
