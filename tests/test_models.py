"""Per-architecture smoke tests (reduced same-family variants, CPU) +
prefill/decode consistency + training step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import (ARCH_IDS, LONG_CONTEXT_ARCHS,
                                    get_config, get_reduced_config,
                                    shape_supported)
from repro.data.pipeline import DataConfig, SyntheticLM, frontend_stub
from repro.models import transformer as T
from repro.training import optimizer as opt, train as TR

RNG = np.random.default_rng(0)


def _frontend(cfg, B):
    if not cfg.frontend:
        return None
    return jnp.asarray(frontend_stub(cfg.frontend, B, cfg.frontend_len,
                                     cfg.frontend_dim))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_and_finite(arch):
    """One forward step on a REDUCED variant: output shapes + no NaNs."""
    cfg = get_reduced_config(arch)
    params, axes = T.init_model(0, cfg)
    assert jax.tree.structure(params) == jax.tree.structure(
        axes, is_leaf=lambda x: isinstance(x, tuple))
    B, S = 2, 64
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab, (B, S)))
    logits, aux = T.forward(params, cfg, tokens, _frontend(cfg, B))
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    """One train step on CPU: loss finite, grads applied."""
    cfg = get_reduced_config(arch)
    params, _ = T.init_model(0, cfg)
    step = jax.jit(TR.make_train_step(cfg, opt.AdamWConfig(lr=1e-3,
                                                           total_steps=10)))
    B, S = 2, 32
    batch = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab, (B, S))),
             "mask": jnp.ones((B, S), jnp.int32)}
    if cfg.frontend:
        batch["frontend"] = _frontend(cfg, B)
    ost = opt.init(params)
    p2, ost2, m = step(params, ost, batch)
    assert bool(jnp.isfinite(m["loss"]))
    assert int(ost2.step) == 1
    # params actually moved
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), params, p2)
    assert max(jax.tree.leaves(d)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_reduced_config(arch)
    params, _ = T.init_model(0, cfg)
    B, S = 2, 96   # exceeds the reduced window (64): rolling caches on
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab, (B, S)))
    fe = _frontend(cfg, B)
    logits_full, _ = T.forward(params, cfg, tokens, fe)
    cache = T.init_cache(cfg, B, max_len=S + 8, dtype=jnp.float32)
    lg_pre, cache, lengths = T.prefill(params, cfg, tokens[:, :S-1], cache,
                                       fe)
    lg_dec, _ = T.decode_step(params, cfg, tokens[:, S-1:S], lengths, cache)
    tol = 5e-3 if cfg.n_experts else 2e-3
    for got, want in ((lg_pre, logits_full[:, S-2]),
                      (lg_dec, logits_full[:, S-1])):
        rel = float(jnp.abs(got - want).max()
                    / (jnp.abs(want).max() + 1e-9))
        assert rel < tol, (arch, rel)


def test_scan_unroll_equivalence():
    """unroll=reps must not change the math (used by the dry-run FLOPs
    pass)."""
    cfg = get_reduced_config("gemma3-12b", n_layers=6)
    params, _ = T.init_model(0, cfg)
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab, (1, 32)))
    l1, _ = T.forward(params, cfg, tokens)
    l2, _ = T.forward(params, cfg, tokens, unroll=cfg.n_layers // cfg.period)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=2e-4, atol=2e-4)


def test_long_context_arch_flags():
    for a in ARCH_IDS:
        assert shape_supported(a, "train_4k")
        assert shape_supported(a, "long_500k") == (a in LONG_CONTEXT_ARCHS)


def test_full_configs_match_assignment():
    spec = {
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "mamba2-780m": (48, 1536, 1, 1, 0, 50280),
    }
    for arch, (L, E, H, KvH, F, V) in spec.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (L, E, H, KvH, F, V), arch
    # MoE structure
    l4 = get_config("llama4-maverick-400b-a17b")
    assert l4.n_experts == 128 and l4.top_k == 1
    mx = get_config("mixtral-8x22b")
    assert mx.n_experts == 8 and mx.top_k == 2
    mb = get_config("mamba2-780m")
    assert mb.ssm_state == 128


def test_param_counts_plausible():
    """Reduced configs are small; FULL configs hit the advertised scale
    (checked structurally via eval_shape, no allocation)."""
    for arch, lo, hi in (("gemma3-12b", 10e9, 14e9),
                        ("mamba2-780m", 0.6e9, 1.0e9),
                        ("mixtral-8x22b", 120e9, 155e9),
                        ("llama4-maverick-400b-a17b", 360e9, 430e9)):
        cfg = get_config(arch)
        shapes = jax.eval_shape(lambda c=cfg: T.init_model_params_only(0, c))
        n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
        assert lo < n < hi, (arch, n / 1e9)


def test_data_pipeline_deterministic():
    cfg = DataConfig(vocab=1000, seq_len=64, global_batch=4, seed=7)
    a = next(iter(SyntheticLM(cfg).batches()))
    b = next(iter(SyntheticLM(cfg).batches()))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (4, 64)
    assert a["tokens"].min() >= 0 and a["tokens"].max() < 1000


def test_checkpoint_roundtrip(tmp_path):
    from repro.training import checkpoint as ckpt
    cfg = get_reduced_config("qwen2.5-14b")
    params, _ = T.init_model(0, cfg)
    ost = opt.init(params)
    ckpt.save(str(tmp_path / "c"), params, ost, step=3)
    p2, o2, meta = ckpt.restore(str(tmp_path / "c"), params, ost)
    assert meta["step"] == 3
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), params, p2)
