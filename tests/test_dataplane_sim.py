"""Integration + property tests for the cycle-accurate dataplane."""
import dataclasses
import hashlib

import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # optional dev dep — property tests skip
    from _hypothesis_stub import given, settings, st

from repro.core import baselines, token_bucket as tb
from repro.core.accelerator import AccelTable, CATALOG
from repro.core.flow import SLO, FlowSet, FlowSpec, Path, TrafficPattern
from repro.core.interconnect import ARB_RR, LinkSpec
from repro.core.sim import (SHAPING_HW, SHAPING_NONE, SimConfig,
                            gen_arrivals, simulate)


def _sim_two(slos=(10.0, 20.0), n_ticks=60_000, shaping=SHAPING_HW,
             msg=1024, accel=None, **cfg_kw):
    specs = [
        FlowSpec(i, i, Path.FUNCTION_CALL, 0,
                 TrafficPattern(msg, load=0.9, process="poisson"),
                 SLO.gbps(s))
        for i, s in enumerate(slos)
    ]
    flows = FlowSet.build(specs)
    accel = accel or CATALOG["synthetic50"]
    cfg = SimConfig(n_ticks=n_ticks, shaping=shaping, arbiter=ARB_RR,
                    **cfg_kw)
    arr = gen_arrivals(flows, cfg,
                       load_ref_gbps={i: 55.0 for i in range(len(slos))})
    if shaping == SHAPING_HW:
        tbs = tb.pack([tb.params_for_gbps(s) for s in slos])
    else:
        tbs = baselines.make_tb_state(baselines.HOST_NO_TS,
                                      [tb.TBParams(1, 1, 1)] * len(slos))
    res = simulate(flows, AccelTable.build([accel]), LinkSpec(), cfg, tbs,
                   *arr)
    return res, flows


def test_shaped_rates_hit_slo():
    res, flows = _sim_two()
    for i, slo in enumerate((10.0, 20.0)):
        got = res.mean_ingress_gbps(i, flows)
        assert abs(got - slo) / slo < 0.05, (i, got)


def test_conservation_admitted_vs_completed():
    """Every admitted message either completes or is still in flight."""
    res, _ = _sim_two()
    adm = res.counters["c_adm_msgs"]
    done = res.counters["c_done_msgs"]
    assert (done <= adm).all()
    assert (adm - done <= 600).all()  # bounded in-flight


def test_unshaped_exceeds_shaped():
    r1, f1 = _sim_two(shaping=SHAPING_HW)
    r2, f2 = _sim_two(shaping=SHAPING_NONE)
    total1 = sum(r1.mean_ingress_gbps(i, f1) for i in range(2))
    total2 = sum(r2.mean_ingress_gbps(i, f2) for i in range(2))
    assert total2 > total1  # 30 shaped vs ~46 free-for-all


def test_latency_records_positive_and_ordered():
    res, _ = _sim_two()
    assert (res.comp_lat_s >= 0).all()
    assert (res.comp_sz > 0).all()


def test_accelerator_capacity_respected():
    """Completed throughput never exceeds the accelerator's effective
    capacity at the message size."""
    accel = CATALOG["synthetic50"]
    res, flows = _sim_two(slos=(40.0, 40.0), accel=accel)
    total = sum(res.mean_ingress_gbps(i, flows) for i in range(2))
    assert total <= accel.effective_gbps(1024) * 1.05


def test_link_direction_budget_respected():
    """Function-call ingress (h2d) cannot exceed the configured link rate."""
    link = LinkSpec(h2d_gbps=10.0, d2h_gbps=10.0, efficiency=1.0)
    specs = [FlowSpec(0, 0, Path.FUNCTION_CALL, 0,
                      TrafficPattern(4096, load=0.9), SLO.gbps(50))]
    flows = FlowSet.build(specs)
    cfg = SimConfig(n_ticks=50_000, shaping=SHAPING_NONE)
    arr = gen_arrivals(flows, cfg, load_ref_gbps={0: 50.0})
    tbs = baselines.make_tb_state(baselines.HOST_NO_TS, [tb.TBParams(1, 1, 1)])
    res = simulate(flows, AccelTable.build([CATALOG["synthetic50"]]), link,
                   cfg, tbs, *arr)
    assert res.mean_ingress_gbps(0, flows) <= 10.5


@settings(max_examples=8, deadline=None)
@given(slo=st.floats(2.0, 30.0), msg=st.sampled_from([512, 1024, 4096]))
def test_property_shaping_accuracy(slo, msg):
    """For any SLO under capacity, shaped throughput lands within 6%."""
    res, flows = _sim_two(slos=(slo,), n_ticks=40_000, msg=msg)
    got = res.mean_ingress_gbps(0, flows)
    assert abs(got - slo) / slo < 0.06, (slo, msg, got)


def _trace_digest(flows, cfg, seed, ref):
    t, s = gen_arrivals(flows, cfg, seed=seed, load_ref_gbps=ref)
    h = hashlib.sha256()
    h.update(np.ascontiguousarray(t.astype("<i4")).tobytes())
    h.update(np.ascontiguousarray(s.astype("<i4")).tobytes())
    return t.shape, h.hexdigest()


def test_gen_arrivals_same_seed_digests_pinned():
    """Same-seed traces are pinned byte-for-byte.

    PR 1's vectorized RNG already changed the draw order of same-seed
    traces once; these digests make any future vectorization that would
    silently reshuffle traces (and thereby every downstream 'same-seed'
    comparison) an explicit, visible decision."""
    specs = [
        FlowSpec(0, 0, Path.FUNCTION_CALL, 0,
                 TrafficPattern(1024, load=0.4, process="cbr"),
                 SLO.gbps(10)),
        FlowSpec(1, 1, Path.FUNCTION_CALL, 0,
                 TrafficPattern(512, load=0.3, process="poisson"),
                 SLO.gbps(10)),
        FlowSpec(2, 2, Path.INLINE_NIC_RX, 0,
                 TrafficPattern(1500, load=0.5, process="onoff",
                                burst_len=16, duty=0.25), SLO.gbps(10)),
        FlowSpec(3, 3, Path.FUNCTION_CALL, 0,
                 TrafficPattern(64, load=0.2, process="poisson",
                                msg_bytes2=4096, p2=0.1), SLO.gbps(10)),
    ]
    flows = FlowSet.build(specs)
    cfg = SimConfig(n_ticks=20_000)
    ref = {i: 32.0 for i in range(4)}
    assert _trace_digest(flows, cfg, 0, ref) == (
        (4, 8017),
        "6995db131b1979ad07c8b260581ae6f05cd8bfb15dd09cb1d2c4c858607d888f")
    assert _trace_digest(flows, cfg, 7, ref) == (
        (4, 7998),
        "5358b52f722082e07ecdfb6fe5b646702b6cb66139dfcd27dd237de11a6dbe84")
    assert _trace_digest(FlowSet.build([specs[1]]), cfg, 3, {0: 55.0}) == (
        (1, 2578),
        "f862ebb2590520bc81a7f119a3b3dba8edc7171e70755373f7bf8966a4d40cdd")


def test_windowed_reconfiguration_carries_state():
    """simulate() with a carry resumes without resetting counters, and a
    register write mid-flight changes the shaped rate (Sec 5.3.1
    'Dynamism')."""
    specs = [FlowSpec(0, 0, Path.FUNCTION_CALL, 0,
                      TrafficPattern(1024, load=0.9), SLO.gbps(10))]
    flows = FlowSet.build(specs)
    cfg = SimConfig(n_ticks=40_000, shaping=SHAPING_HW)
    full = dataclasses.replace(cfg, n_ticks=80_000)
    arr = gen_arrivals(flows, full, load_ref_gbps={0: 50.0})
    tbs1 = tb.pack([tb.params_for_gbps(10)])
    res1, carry = simulate(flows, AccelTable.build([CATALOG["synthetic50"]]),
                           LinkSpec(), cfg, tbs1, *arr, return_carry=True)
    tbs2 = tb.pack([tb.params_for_gbps(20)])
    res2 = simulate(flows, AccelTable.build([CATALOG["synthetic50"]]),
                    LinkSpec(), cfg, tbs2, *arr, t0_ticks=40_000,
                    carry=carry)
    n1 = res1.counters["c_done_msgs"][0]
    n2 = res2.counters["c_done_msgs"][0]
    window_s = cfg.n_ticks * cfg.tick_cycles / cfg.clock_hz
    rate1 = n1 * 1024 * 8 / window_s / 1e9
    rate2 = (n2 - n1) * 1024 * 8 / window_s / 1e9
    assert abs(rate1 - 10) < 1.5
    assert abs(rate2 - 20) < 2.0
