"""Closed-loop control policies: envelope safety, AIMD dynamics, the
global re-target tier, actuation semantics, and the telemetry JSON
schema.

The policy tests fabricate ``WindowMetrics``/``Envelope`` views — no
engine, no profiling — so the invariants (never leave the profiled
envelope, monotone convergence on a clear trace, hold-steady returns
False from ``actuate``) are checked cheaply and exhaustively.  One
integration test drives a real adaptive ``FleetController`` run and
asserts the two load-bearing engine contracts: ONE compiled entry for
the whole adaptive timeline, and hold-steady windows taking the
no-register-rewrite resume path (pack count).
"""
from __future__ import annotations

import dataclasses
import json
import math
import types

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_stub import given, settings, st

from repro.core import control, engine, telemetry
from repro.core import token_bucket as tb
from repro.core.accelerator import CATALOG
from repro.core.controller import FleetController
from repro.core.flow import SLO, FlowSpec, Path, SLOKind, TrafficPattern
from repro.core.profiler import ProfileTable
from repro.core.runtime import ArcusRuntime, WindowReport
from repro.core.shaper import reshape_decision

_PROFILE_TICKS = 6_000


def _metric(fid, *, kind=SLOKind.GBPS, target=8.0, measured=None,
            violated=False, streak=0, lat=float("nan")):
    if measured is None:
        measured = target * (0.5 if violated else 1.2)
    slack = measured / target - 1.0 if kind != SLOKind.LATENCY \
        else (1.0 - lat / target if math.isfinite(lat) else float("nan"))
    return telemetry.WindowMetrics(
        flow_id=fid, lane=0, kind=int(kind), target=target,
        measured=float(measured), slack=float(slack), violated=violated,
        streak=streak, lat_avg_s=float(lat), util=())


def _view(metrics, envelopes, *, server=0, margin=None):
    return control.ServerView(server=server, window_s=1e-3,
                              metrics=metrics, envelopes=envelopes,
                              margin=margin)


# ---------------------------------------------------------------------------
# StaticHold
# ---------------------------------------------------------------------------


def test_static_hold_decides_nothing():
    pol = control.StaticHold()
    assert pol.needs_envelopes is False
    views = [_view({0: _metric(0, violated=True)},
                   {0: control.Envelope(8.0, 20.0)}, server=b)
             for b in range(3)]
    assert pol.decide(0, views) == [None, None, None]


# ---------------------------------------------------------------------------
# SlackAIMD
# ---------------------------------------------------------------------------


def _run_aimd(pol, env, violated_seq, *, fid=0):
    """Feed a violation sequence through one server/one tenant; return
    the RatePlan sequence."""
    plans = []
    for w, bad in enumerate(violated_seq):
        out = pol.decide(w, [_view({fid: _metric(fid, violated=bad)},
                                   {fid: env})])
        plans.append(out[0][fid])
    return plans


def test_aimd_monotone_convergence_on_clear_trace():
    env = control.Envelope(floor=8.0, ceil=24.0)
    pol = control.SlackAIMD(ai=0.25)
    plans = _run_aimd(pol, env, [False] * 8)
    rates = [p.rate for p in plans]
    assert all(b >= a for a, b in zip(rates, rates[1:]))   # monotone
    assert rates[0] == pytest.approx(8.0 + 0.25 * 16.0)    # one AI step
    assert rates[3] == pytest.approx(env.ceil)             # converged
    assert all(r == pytest.approx(env.ceil) for r in rates[3:])
    assert all(p.burst_scale == 1.0 for p in plans)        # never shrank


def test_aimd_decrease_on_violation_never_below_floor():
    env = control.Envelope(floor=8.0, ceil=24.0)
    pol = control.SlackAIMD(ai=0.25, md=0.5, burst_md=0.5, burst_min=0.05)
    plans = _run_aimd(pol, env, [False, False, True, True])
    assert plans[1].rate > plans[2].rate > plans[3].rate
    assert plans[3].rate >= env.floor
    # bucket depth decays multiplicatively, floored at burst_min
    assert plans[2].burst_scale == pytest.approx(0.5)
    assert plans[3].burst_scale == pytest.approx(0.25)
    many = _run_aimd(control.SlackAIMD(), env, [True] * 12)
    assert many[-1].rate == pytest.approx(env.floor)
    assert many[-1].burst_scale == pytest.approx(0.05)


def test_aimd_violated_co_tenant_throttles_the_whole_server():
    """A latency tenant's violation (no envelope of its own) drives the
    rate tenants' decrease — shaping *others* is the Fig. 9 mechanism."""
    env = control.Envelope(floor=8.0, ceil=24.0)
    pol = control.SlackAIMD(start_frac=1.0)
    lat_bad = _metric(7, kind=SLOKind.LATENCY, target=1e-6,
                      violated=True, lat=5e-6)
    out = pol.decide(0, [_view({0: _metric(0), 7: lat_bad}, {0: env})])
    assert out[0][0].rate < env.ceil
    assert out[0][0].burst_scale < 1.0


def test_aimd_guard_band_holds_state():
    """Thin slack without a violation neither ramps nor decays — the
    plan repeats verbatim (actuate will then report no change)."""
    env = control.Envelope(floor=8.0, ceil=24.0)
    pol = control.SlackAIMD(ai=0.25, guard=0.1)
    p0 = pol.decide(0, [_view({0: _metric(0)}, {0: env})])[0][0]
    thin = _metric(0, measured=8.4)          # slack 0.05, inside guard
    p1 = pol.decide(1, [_view({0: thin}, {0: env})])[0][0]
    assert p1 == p0


def test_aimd_no_envelopes_holds_steady():
    pol = control.SlackAIMD()
    out = pol.decide(0, [_view({7: _metric(7, violated=True)}, {})])
    assert out == [None]


def test_aimd_rejects_bad_decrease_factors():
    with pytest.raises(ValueError):
        control.SlackAIMD(md=0.0)
    with pytest.raises(ValueError):
        control.SlackAIMD(burst_md=1.5)


@settings(max_examples=200, deadline=None)
@given(st.lists(st.booleans(), min_size=1, max_size=40),
       st.floats(min_value=0.1, max_value=100.0),
       st.floats(min_value=0.0, max_value=400.0),
       st.floats(min_value=0.01, max_value=1.0),
       st.floats(min_value=0.01, max_value=1.0))
def test_aimd_never_leaves_envelope_property(seq, floor, span, ai, md):
    """Whatever the violation history, the planned rate stays inside the
    profiled capacity envelope and the bucket scale inside
    [burst_min, 1]."""
    env = control.Envelope(floor=floor, ceil=floor + span)
    pol = control.SlackAIMD(ai=ai, md=md)
    for p in _run_aimd(pol, env, seq):
        assert env.floor <= p.rate <= env.ceil + 1e-9
        assert pol.burst_min - 1e-12 <= p.burst_scale <= 1.0


@settings(max_examples=100, deadline=None)
@given(st.integers(min_value=1, max_value=30),
       st.floats(min_value=0.1, max_value=100.0),
       st.floats(min_value=0.0, max_value=400.0),
       st.floats(min_value=0.01, max_value=1.0))
def test_aimd_converges_monotonically_on_steady_trace_property(
        n, floor, span, ai):
    """On a violation-free trace the granted rate is non-decreasing and
    reaches the profiled ceiling within ceil(1/ai) windows."""
    env = control.Envelope(floor=floor, ceil=floor + span)
    rates = [p.rate for p in
             _run_aimd(control.SlackAIMD(ai=ai), env, [False] * n)]
    assert all(b >= a - 1e-9 for a, b in zip(rates, rates[1:]))
    if n >= math.ceil(1.0 / ai):
        assert rates[-1] == pytest.approx(env.ceil)


# ---------------------------------------------------------------------------
# GlobalRetarget
# ---------------------------------------------------------------------------


def test_retarget_shifts_budget_toward_need_and_respects_ceilings():
    envs = {0: control.Envelope(8.0, 24.0), 1: control.Envelope(8.0, 24.0)}
    needy = _metric(0, violated=True, measured=4.0, streak=3)
    happy = _metric(1, violated=False)
    pol = control.GlobalRetarget(control.SlackAIMD(start_frac=1.0),
                                 period=4)
    out = pol.decide(0, [_view({0: needy, 1: happy}, dict(envs))])
    plans = out[0]
    # start_frac=1 puts each tenant at its (re-targeted) ceiling: the
    # needy tenant got the larger share of the grant budget
    assert plans[0].rate > plans[1].rate
    assert plans[0].rate <= envs[0].ceil + 1e-9      # never above profile
    assert plans[1].rate >= envs[1].floor - 1e-9     # never below SLO


def test_retarget_only_every_period_windows():
    envs = {0: control.Envelope(8.0, 24.0), 1: control.Envelope(8.0, 24.0)}
    pol = control.GlobalRetarget(control.SlackAIMD(start_frac=1.0),
                                 period=3)
    needy = _metric(0, violated=True, measured=4.0, streak=2)
    out0 = pol.decide(0, [_view({0: needy, 1: _metric(1)}, dict(envs))])
    assert out0[0] is not None
    ceilings0 = dict(pol._ceilings)
    # window 1: metrics flip, but ceilings must stay those of window 0
    # (the inner AIMD keeps ramping inside them)
    out1 = pol.decide(1, [_view({0: _metric(0), 1: _metric(1)},
                                dict(envs))])
    assert dict(pol._ceilings) == ceilings0
    assert out1[0][0].rate <= ceilings0[(0, 0)] + 1e-9
    # window 3 (== period) re-targets: even split again
    pol.decide(3, [_view({0: _metric(0), 1: _metric(1)}, dict(envs))])
    assert dict(pol._ceilings) != ceilings0


def test_retarget_thin_margin_scales_budget_down():
    envs = {0: control.Envelope(8.0, 24.0)}
    pol = control.GlobalRetarget(control.SlackAIMD(start_frac=1.0),
                                 period=4, margin_floor=0.05)
    # margin 0: the placement layer says the server has no headroom —
    # the whole grant budget collapses to the SLO floor
    out = pol.decide(0, [_view({0: _metric(0)}, dict(envs), margin=0.0)])
    assert out[0][0].rate == pytest.approx(envs[0].floor)
    # comfortable margin: full budget
    pol.reset()
    out = pol.decide(0, [_view({0: _metric(0)}, dict(envs), margin=0.5)])
    assert out[0][0].rate == pytest.approx(envs[0].ceil)


# ---------------------------------------------------------------------------
# Actuation: plan -> registers
# ---------------------------------------------------------------------------


def _fake_rt(spec):
    """A minimal runtime stand-in for plan_params/actuate: the real
    accelerator catalog and planner, no profiling."""
    params = reshape_decision(CATALOG["synthetic50"], spec.slo,
                              spec.pattern.msg_bytes, clock_hz=250e6).params
    st_ = types.SimpleNamespace(spec=spec, params=params, reconfigs=0)
    rt = types.SimpleNamespace(accel_specs=[CATALOG["synthetic50"]],
                               clock_hz=250e6,
                               table={spec.flow_id: st_})
    return rt, st_


def _gbps_spec(fid=0, target=8.0, msg=1024):
    return FlowSpec(fid, fid, Path.FUNCTION_CALL, 0,
                    TrafficPattern(msg, load=0.3), SLO.gbps(target))


def test_plan_at_floor_reproduces_admission_registers():
    spec = _gbps_spec()
    rt, st_ = _fake_rt(spec)
    admission = st_.params
    got = control.plan_params(rt, st_, control.RatePlan(rate=8.0))
    assert got == admission


def test_plan_burst_scale_shrinks_bucket_with_clamp():
    spec = _gbps_spec()
    rt, st_ = _fake_rt(spec)
    base = st_.params
    small = control.plan_params(rt, st_,
                                control.RatePlan(rate=8.0,
                                                 burst_scale=0.5))
    assert small.bkt_size < base.bkt_size
    assert small.refill_rate == base.refill_rate     # rate untouched
    tiny = control.plan_params(rt, st_,
                               control.RatePlan(rate=8.0,
                                                burst_scale=1e-6))
    # clamp: one refill quantum and one message always fit
    assert tiny.bkt_size >= max(base.refill_rate, spec.pattern.msg_bytes)


def test_actuate_hold_steady_reports_unchanged():
    spec = _gbps_spec()
    rt, st_ = _fake_rt(spec)
    assert control.actuate(rt, {0: control.RatePlan(rate=8.0)}) is False
    assert st_.reconfigs == 0
    assert control.actuate(rt, {0: control.RatePlan(rate=16.0)}) is True
    assert st_.reconfigs == 1
    # committing the same plan again is a no-op
    assert control.actuate(rt, {0: control.RatePlan(rate=16.0)}) is False
    assert st_.reconfigs == 1


def test_actuate_skips_unknown_and_latency_tenants():
    spec = _gbps_spec()
    rt, st_ = _fake_rt(spec)
    lat_spec = FlowSpec(9, 9, Path.FUNCTION_CALL, 0,
                        TrafficPattern(64, rate_mps=1e6),
                        SLO.latency(2e-6))
    lat_params = reshape_decision(CATALOG["synthetic50"], lat_spec.slo,
                                  64, clock_hz=250e6).params
    rt.table[9] = types.SimpleNamespace(spec=lat_spec, params=lat_params,
                                        reconfigs=0)
    plans = {9: control.RatePlan(rate=50.0),      # latency: never shaped
             42: control.RatePlan(rate=1.0)}      # unknown fid: ignored
    assert control.actuate(rt, plans) is False
    assert rt.table[9].params == lat_params


# ---------------------------------------------------------------------------
# Telemetry JSON schema
# ---------------------------------------------------------------------------


def test_window_metrics_json_roundtrip():
    m = _metric(3, kind=SLOKind.LATENCY, target=1e-6, measured=0.9,
                violated=True, streak=2, lat=2.5e-6)
    m = dataclasses.replace(m, util=(0.5, 0.125))
    back = telemetry.WindowMetrics.from_json(
        json.loads(json.dumps(m.to_json())))
    assert back == m


def test_window_metrics_json_roundtrip_nan_latency():
    m = _metric(1)           # rate SLO: lat_avg_s is NaN
    back = telemetry.WindowMetrics.from_json(m.to_json())
    assert math.isnan(back.lat_avg_s)
    assert dataclasses.replace(back, lat_avg_s=0.0) == \
        dataclasses.replace(m, lat_avg_s=0.0)


def test_window_report_json_roundtrip():
    rep = WindowReport(
        t_end_s=1.5e-3,
        measured={0: 7.5, 3: 12.0},
        violated=[3],
        reconfigured=[3],
        path_changes=[(3, 1, 2)],
        metrics={0: dataclasses.replace(_metric(0, measured=7.5),
                                        lat_avg_s=2.0e-6),
                 3: dataclasses.replace(_metric(3, violated=True),
                                        lat_avg_s=0.0, util=(0.25,))})
    back = WindowReport.from_json(json.loads(json.dumps(rep.to_json())))
    assert back.t_end_s == rep.t_end_s
    assert back.measured == rep.measured
    assert back.violated == rep.violated
    assert back.reconfigured == rep.reconfigured
    assert back.path_changes == rep.path_changes
    assert back.metrics == rep.metrics


# ---------------------------------------------------------------------------
# Integration: adaptive run — one engine entry, hold-steady resume path
# ---------------------------------------------------------------------------


def _adaptive_ctrl(profile):
    rts = [ArcusRuntime([CATALOG["synthetic50"]], profile_table=profile)]
    ctrl = FleetController(rts, control=control.SlackAIMD(ai=0.5))
    spec = FlowSpec(0, 0, Path.FUNCTION_CALL, 0,
                    TrafficPattern(1024, load=0.3, process="poisson"),
                    SLO.gbps(4.0))
    assert ctrl.admit_fleet([[spec]]) == [[True]]
    return ctrl


def test_adaptive_run_one_engine_entry_and_hold_steady_packs(monkeypatch):
    """An adaptive timeline compiles ONE engine entry, and once the AIMD
    ramp converges (params stop changing) the remaining windows take the
    no-register-rewrite resume path — no pack, no rewrite."""
    profile = ProfileTable(n_ticks=_PROFILE_TICKS)
    kwargs = dict(total_ticks=18_000, window_ticks=3_000, seeds=[1],
                  load_ref_gbps=[{0: 32.0}])
    # warm admission + envelope contexts on a throwaway clone
    _adaptive_ctrl(profile).run(**kwargs)

    ctrl = _adaptive_ctrl(profile)
    rt = ctrl.runtimes[0]
    env = control.capacity_envelopes(rt)
    assert env[0].floor == pytest.approx(4.0)        # SLO-required rate
    assert env[0].ceil > env[0].floor                # profiled headroom

    packs = []
    real_pack = tb.pack
    monkeypatch.setattr(tb, "pack", lambda ps: packs.append(1) or
                        real_pack(ps))
    engine.cache_clear()
    _results, reports = ctrl.run(**kwargs)
    assert engine.cache_info() == {"entries": 1, "traces": 1}

    n_windows = len(reports[0])
    assert n_windows == 6
    # the lightly-loaded tenant never trips the legacy loop
    assert all(not w.reconfigured and not w.path_changes
               for w in reports[0])
    # packs: window 0 always packs; window w>0 packs iff the policy
    # changed registers after window w-1 (== one reconfig bump)
    assert len(packs) == 1 + rt.table[0].reconfigs, (len(packs),
                                                     rt.table[0].reconfigs)
    # ai=0.5 on a clear trace converges in 2 steps: later windows must
    # hold steady (the resume path) — strictly fewer packs than windows
    assert 1 <= rt.table[0].reconfigs <= 2
    assert len(packs) < n_windows
    # converged shaped rate sits at the profiled ceiling, so measured
    # throughput never dropped below the (met) SLO along the way
    assert all(not np.isnan(w.metrics[0].measured) for w in reports[0])
    assert all(not w.metrics[0].violated for w in reports[0])
