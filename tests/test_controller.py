"""Tenant-lifecycle controller tests: static parity, departure inertness,
admit→depart→readmit determinism, churn timelines on one compiled engine,
rebalancing onto freed capacity, the stateful score cache, and the
clock-threading satellite.

The legacy fleet entry points (`register_fleet` / `place_fleet` /
`run_managed_batch`) are deprecation shims over `FleetController`, so the
existing `tests/test_fleet.py` + `tests/test_placement.py` suites pin the
shim side of the parity contract (bitwise-equal to serial `run_managed`);
this file exercises what only the controller can do."""
import dataclasses

import numpy as np
import pytest

from repro.core import engine, placement, token_bucket as tb
from repro.core.accelerator import CATALOG
from repro.core.controller import FleetController, TenantEvent
from repro.core.flow import SLO, FlowSpec, Path, TrafficPattern
from repro.core.interconnect import LinkSpec
from repro.core.profiler import ProfileTable, profiling_stats
from repro.core.runtime import ArcusRuntime

_PROFILE_TICKS = 6_000

_CNT_KEYS = ("c_adm_msgs", "c_done_msgs", "c_drops", "c_adm_bytes",
             "c_done_bytes")


def _spec(fid, slo_gbps, accel_id=0, msg=1024, load=0.5, rate_mps=None):
    return FlowSpec(fid, fid, Path.FUNCTION_CALL, accel_id,
                    TrafficPattern(msg, load=load, rate_mps=rate_mps,
                                   process="poisson" if rate_mps is None
                                   else "cbr"),
                    SLO.gbps(slo_gbps))


def _mk_fleet(complements, profile=None):
    profile = profile or ProfileTable(n_ticks=_PROFILE_TICKS)
    return [ArcusRuntime([CATALOG[n] for n in names],
                         profile_table=profile)
            for names in complements]


# ---------------------------------------------------------------------------
# Static parity: controller.run == serial run_managed, bitwise
# ---------------------------------------------------------------------------


def test_controller_static_run_matches_serial_bitwise():
    """A FleetController driven directly (no shim) over a static tenant
    set produces counters, WindowReports and control state bitwise-equal
    to serial per-server run_managed — the deprecation-shim parity
    contract, anchored on the serial reference."""
    def mk():
        rts = _mk_fleet((["synthetic50"], ["ipsec32", "synthetic50"]))
        assert rts[0].register(_spec(0, 10.0))
        assert rts[0].register(_spec(1, 5.0, msg=2048))
        assert rts[1].register(_spec(0, 8.0, msg=1500))
        return rts

    kwargs = dict(total_ticks=12_000, window_ticks=4_000)
    refs = [{0: 32.0, 1: 32.0}, {0: 32.0}]
    rts_s = mk()
    serial = [rt.run_managed(seed=b + 1, load_ref_gbps=refs[b], **kwargs)
              for b, rt in enumerate(rts_s)]
    rts_c = mk()
    ctrl = FleetController(rts_c)
    results, reports = ctrl.run(seeds=[1, 2], load_ref_gbps=refs, **kwargs)
    for b, (res_s, rep_s) in enumerate(serial):
        for k in _CNT_KEYS:
            np.testing.assert_array_equal(res_s.counters[k],
                                          results[b].counters[k])
        np.testing.assert_array_equal(res_s.comp_flow, results[b].comp_flow)
        assert len(rep_s) == len(reports[b])
        for ws, wb in zip(rep_s, reports[b]):
            assert ws.measured == wb.measured
            assert ws.violated == wb.violated
            assert ws.reconfigured == wb.reconfigured
            # the telemetry digest agrees too (NaN-aware: frozen
            # dataclass == fails on NaN fields)
            assert set(ws.metrics) == set(wb.metrics)
            for fid in ws.metrics:
                ms, mb = ws.metrics[fid], wb.metrics[fid]
                np.testing.assert_equal(ms.lat_avg_s, mb.lat_avg_s)
                np.testing.assert_equal(ms.slack, mb.slack)
                assert (dataclasses.replace(ms, lat_avg_s=0.0, slack=0.0)
                        == dataclasses.replace(mb, lat_avg_s=0.0,
                                               slack=0.0))
        for fid in rts_s[b].table:
            assert rts_s[b].table[fid].params == rts_c[b].table[fid].params
            assert (rts_s[b].table[fid].violations
                    == rts_c[b].table[fid].violations)


# ---------------------------------------------------------------------------
# Departure: the freed lane is provably inert
# ---------------------------------------------------------------------------


def _depart_fleet(profile):
    rts = _mk_fleet((["synthetic50"], ["synthetic50"]), profile)
    assert rts[0].register(_spec(0, 5.0, load=0.4))
    assert rts[0].register(_spec(1, 5.0, load=0.4))     # the tenant
    assert rts[1].register(_spec(2, 5.0, load=0.4))
    return rts


def test_depart_event_freezes_lane_counters():
    """DEPART at a window boundary: the lane's admission/drop counters
    freeze at exactly their boundary values (bitwise-equal to a run
    truncated at the departure window), later reports drop the tenant,
    and the remaining flows keep progressing — all without a recompile
    (one engine entry for the whole churn run)."""
    profile = ProfileTable(n_ticks=_PROFILE_TICKS)
    kwargs = dict(window_ticks=3_000, seeds=[3, 4],
                  load_ref_gbps=[{0: 32.0, 1: 32.0}, {0: 32.0}])
    # truncated reference: exactly the two pre-departure windows
    trunc, _ = FleetController(_depart_fleet(profile)).run(
        total_ticks=6_000, **kwargs)
    rts = _depart_fleet(profile)
    ctrl = FleetController(rts)
    engine.cache_clear()
    res, reports = ctrl.run(total_ticks=15_000,
                            events=[TenantEvent.depart(2, tenant_id=1)],
                            **kwargs)
    assert engine.cache_info() == {"entries": 1, "traces": 1}
    # admission stopped at the boundary, bitwise; queued leftovers were
    # flushed, so no post-departure drops either
    for k in ("c_adm_msgs", "c_adm_bytes", "c_drops"):
        assert res[0].counters[k][1] == trunc[0].counters[k][1], k
    # in-flight at the boundary drained; nothing new completed after
    assert (res[0].counters["c_done_msgs"][1]
            <= trunc[0].counters["c_done_msgs"][1] + 8)
    # the tenant vanished from the control plane at its window
    assert 1 not in rts[0].table
    assert ctrl.lane_map(0) == [0, None]
    for w, rep in enumerate(reports[0]):
        assert (1 in rep.measured) == (w < 2)
    # everyone else kept running
    assert res[0].counters["c_done_msgs"][0] > trunc[0].counters[
        "c_done_msgs"][0]
    assert res[1].counters["c_done_msgs"][0] > trunc[1].counters[
        "c_done_msgs"][0]
    assert ctrl.stats["departed"] == 1


def test_departed_idle_tenant_bitwise_equal_to_never_admitted():
    """An admitted tenant that departs before its first message leaves
    the other flows' counters and reports bitwise-equal to a fleet that
    never admitted it: occupying a lane, carrying registers and being
    measured (and even reconfigured) is provably inert as long as no
    message flows."""
    profile = ProfileTable(n_ticks=_PROFILE_TICKS)
    window, total = 3_000, 15_000
    window_s = window * 8 / 250e6
    # first CBR arrival lands mid-window-2 — after the boundary-2 depart
    idle = _spec(9, 1.0, rate_mps=1.0 / (2.5 * window_s))

    def run(with_tenant):
        rts = _mk_fleet((["synthetic50"],), profile)
        assert rts[0].register(_spec(0, 8.0, load=0.5))
        if with_tenant:
            assert rts[0].register(idle)
        ctrl = FleetController(rts)
        events = [TenantEvent.depart(2, tenant_id=9)] if with_tenant else []
        res, rep = ctrl.run(total_ticks=total, window_ticks=window,
                            seeds=[7], load_ref_gbps=[{0: 32.0}],
                            events=events)
        return rts, res, rep

    rts_x, res_x, rep_x = run(True)
    rts_y, res_y, rep_y = run(False)
    for k in _CNT_KEYS:
        assert res_x[0].counters[k][0] == res_y[0].counters[k][0], k
        # the idle tenant's lane never counted anything at all
        assert res_x[0].counters[k][1] == 0, k
    for wx, wy in zip(rep_x[0], rep_y[0]):
        assert wx.measured[0] == wy.measured[0]
    assert rts_x[0].table[0].params == rts_y[0].table[0].params


# ---------------------------------------------------------------------------
# Admit → depart → readmit reproduces the original placement decision
# ---------------------------------------------------------------------------


def test_admit_depart_readmit_reproduces_placement():
    profile = ProfileTable(n_ticks=_PROFILE_TICKS)
    ctrl = FleetController(_mk_fleet(
        (["synthetic50"], ["synthetic50"], ["synthetic50"]), profile))
    names = ["synthetic50"] * 3
    first = [ctrl.admit(_spec(i, 9.0), accel_name=names[i])
             for i in range(3)]
    assert all(p.accepted for p in first)
    target = first[1]
    before = profiling_stats()
    assert ctrl.depart(1) == target.server
    again = ctrl.admit(_spec(1, 9.0), accel_name="synthetic50")
    after = profiling_stats()
    assert again.accepted
    assert (again.server, again.accel_id) == (target.server,
                                              target.accel_id)
    # the sweep reused at least one untouched server's cached score
    assert after["score_hits"] > before["score_hits"]
    # and no new profiling simulation ran — every context was known
    assert after["contexts"] == before["contexts"]


# ---------------------------------------------------------------------------
# Churn timeline: one compiled engine entry, re-pack only when touched
# ---------------------------------------------------------------------------


def _churn_fleet(profile):
    rts = _mk_fleet((["synthetic50"], ["synthetic50", "aes256"],
                     ["synthetic50"]), profile)
    specs = [[_spec(0, 4.0, load=0.3)],
             [_spec(1, 4.0, load=0.3), _spec(2, 3.0, accel_id=1, load=0.3)],
             [_spec(3, 4.0, load=0.3)]]
    return rts, specs


def test_churn_timeline_single_engine_entry_and_no_clean_repacks(
        monkeypatch):
    profile = ProfileTable(n_ticks=_PROFILE_TICKS)
    events = [
        TenantEvent.arrive(1, _spec(100, 4.0, load=0.3),
                           accel_name="synthetic50"),
        TenantEvent.depart(3, tenant_id=1),
        TenantEvent.arrive(4, _spec(101, 4.0, load=0.3),
                           accel_name="synthetic50"),
    ]
    kwargs = dict(total_ticks=18_000, window_ticks=3_000,
                  seeds=[1, 2, 3],
                  load_ref_gbps=[{0: 32.0}, {0: 32.0, 1: 32.0}, {0: 32.0}])

    # warm the admission contexts on a throwaway clone sharing the
    # ProfileTable, so the live run's placement is pure cache hits
    rts_w, specs_w = _churn_fleet(profile)
    ctrl_w = FleetController(rts_w)
    ctrl_w.admit_fleet(specs_w)
    ctrl_w.run(events=events, **kwargs)

    rts, specs = _churn_fleet(profile)
    ctrl = FleetController(rts)
    ctrl.admit_fleet(specs)
    packs = []
    real_pack = tb.pack
    monkeypatch.setattr(tb, "pack", lambda ps: packs.append(1) or
                        real_pack(ps))
    engine.cache_clear()
    results, reports = ctrl.run(events=events, **kwargs)
    # the whole churn timeline — arrivals, departure included — is ONE
    # compiled engine entry
    assert engine.cache_info() == {"entries": 1, "traces": 1}
    # re-packs: window 0 packs all 3 servers; afterwards a server packs
    # exactly when an event touched it or its previous window
    # reconfigured (one pack even when both hit); clean windows re-pack
    # nothing
    ev_servers: dict[int, set] = {}
    for e in ctrl.last_events:
        if e["server"] is not None:
            ev_servers.setdefault(e["window"], set()).add(e["server"])
    expected = 3
    for w in range(1, len(reports[0])):
        dirty = set(ev_servers.get(w, set()))
        dirty |= {b for b in range(3)
                  if reports[b][w - 1].reconfigured
                  or reports[b][w - 1].path_changes}
        expected += len(dirty)
    assert len(packs) == expected, (len(packs), expected)
    assert len(packs) < 3 * len(reports[0])     # strictly no full re-pack
    # lifecycle landed where expected
    assert ctrl.stats["admitted"] >= 6      # 4 initial + 2 arrivals
    assert ctrl.stats["departed"] == 1
    applied = {(e["kind"], e["tenant"]) for e in ctrl.last_events}
    assert applied == {("arrive", 100), ("depart", 1), ("arrive", 101)}
    # the arrivals actually produced traffic on their servers
    for e in ctrl.last_events:
        if e["kind"] == "arrive":
            b, lane = e["server"], e["lane"]
            assert results[b].counters["c_done_msgs"][lane] > 0
    # the departed tenant shows in reports only before its window
    for w, rep in enumerate(reports[1]):
        assert (1 in rep.measured) == (w < 3)


def test_reuse_lanes_recycled_lane_resets_measurement_baseline():
    """With ``reuse_lanes=True`` a mid-run arrival refills a departed
    tenant's lane — and the recycled lane's measurement baseline resets
    at the splice (device counters zeroed by ``recycle_flow_lane``, the
    host's prev-poll rows zeroed by the controller), so the newcomer's
    first-window measured rate and final per-lane counters contain only
    its own traffic, not the predecessor's cumulative totals."""
    profile = ProfileTable(n_ticks=_PROFILE_TICKS)
    events = [TenantEvent.depart(2, tenant_id=1),
              TenantEvent.arrive(3, _spec(102, 4.0, load=0.3),
                                 accel_name="synthetic50")]
    kwargs = dict(total_ticks=15_000, window_ticks=3_000, seeds=[1],
                  load_ref_gbps=[{0: 32.0, 1: 32.0}])

    def build():
        rts = _mk_fleet((["synthetic50"],), profile)
        ctrl = FleetController(rts, reuse_lanes=True)
        acc = ctrl.admit_fleet([[_spec(0, 4.0, load=0.3),
                                 _spec(1, 4.0, load=0.3)]])
        assert acc == [[True, True]]
        return ctrl

    build().run(events=events, **kwargs)         # warm the contexts
    ctrl = build()
    results, reports = ctrl.run(events=events, **kwargs)

    dep = next(e for e in ctrl.last_events if e["kind"] == "depart")
    arr = next(e for e in ctrl.last_events if e["kind"] == "arrive")
    assert arr["server"] == dep["server"] == 0
    assert arr["lane"] == dep["lane"]            # the hole was recycled
    lane = arr["lane"]

    # the newcomer's measured rate is its own traffic: ~9.6 Gbps of
    # injected load, not the predecessor's cumulative totals replayed
    # through the delta (and never negative / zero from a stale prev row)
    for w in (3, 4):
        got = reports[0][w].measured[102]
        assert 2.0 < got < 16.0, (w, got)
        m = reports[0][w].metrics[102]
        assert m.lane == lane and m.measured == got

    # final per-lane counters: tenant 0 injected for all 5 windows at the
    # same load; the recycled lane saw only the newcomer's 2 windows —
    # without the baseline reset it would also carry the predecessor's
    # 2 windows (~0.8x of tenant 0), which the bound rejects
    adm = results[0].counters["c_adm_msgs"]
    assert adm[lane] > 0
    assert adm[lane] < 0.6 * adm[0], (adm[lane], adm[0])


def test_depart_between_runs_reuses_engine_entry_then_repacks():
    """Below the fragmentation threshold a between-runs departure keeps
    the lane layout (same shapes, same compiled entry); crossing it
    compacts and pays one recompile."""
    profile = ProfileTable(n_ticks=_PROFILE_TICKS)
    rts = _mk_fleet((["synthetic50"], ["synthetic50"]), profile)
    # server 1 runs hotter so it pins the arrival-trace length M: the
    # stacked trace shape (hence the compiled signature) then survives
    # server 0's departure
    for b in range(2):
        assert rts[b].register(_spec(2 * b, 4.0, load=0.3 + 0.15 * b))
        assert rts[b].register(_spec(2 * b + 1, 4.0, load=0.3 + 0.15 * b))
    ctrl = FleetController(rts, repack_threshold=0.5)
    kwargs = dict(total_ticks=6_000, window_ticks=3_000, seeds=[1, 2],
                  load_ref_gbps=[{0: 32.0, 1: 32.0}] * 2)
    engine.cache_clear()
    ctrl.run(**kwargs)
    assert engine.cache_info()["entries"] == 1
    ctrl.depart(1)                          # 1 hole of 2 lanes: == 0.5,
    assert ctrl.lane_map(0) == [0, None]    # at the threshold — kept
    ctrl.run(**kwargs)
    assert engine.cache_info()["entries"] == 1      # same compiled entry
    ctrl.depart(0)                          # 2 holes of 2: crosses it
    assert ctrl.stats["repacks"] == 1
    assert ctrl.lane_map(0) == []
    with pytest.raises(ValueError, match="at least one registered flow"):
        ctrl.run(**kwargs)                  # server 0 is now empty


# ---------------------------------------------------------------------------
# Rebalance: migrate onto freed capacity with the stateful scorer
# ---------------------------------------------------------------------------


def test_rebalance_moves_tenant_onto_freed_capacity():
    profile = ProfileTable(n_ticks=_PROFILE_TICKS)
    rts = _mk_fleet((["synthetic50"], ["synthetic50"]), profile)
    ctrl = FleetController(rts)
    for i in range(3):                      # pile everyone onto server 0
        p = ctrl.admit(_spec(i, 9.0), server=0)
        assert p.accepted
    assert len(rts[0].table) == 3 and not rts[1].table
    moves = ctrl.rebalance()
    assert len(moves) == 1 and ctrl.stats["migrated"] == 1
    mv = moves[0]
    assert mv["src"] == 0 and mv["dst"] == 1
    assert mv["margin_after"] > mv["margin_before"]
    assert len(rts[0].table) == 2 and len(rts[1].table) == 1
    # hysteresis: the new layout is stable — and the second sweep replays
    # untouched servers' margins from the score cache
    before = profiling_stats()
    assert ctrl.rebalance() == []
    after = profiling_stats()
    assert after["score_hits"] > before["score_hits"]
    assert after["contexts"] == before["contexts"]
    # a stay-put sweep preserves control state bit-for-bit
    assert all(st.violations == 0 for st in rts[0].table.values())


def test_score_cache_standalone_in_place_fleet():
    """placement.ScoreCache is usable outside the controller: a shared
    cache across place_fleet calls reuses margins for untouched servers
    (same decisions, fewer scored contexts)."""
    profile = ProfileTable(n_ticks=_PROFILE_TICKS)
    comps = (["synthetic50"], ["synthetic50"], ["synthetic50"])
    cache = placement.ScoreCache()
    from repro.core.runtime import place_fleet
    rts = _mk_fleet(comps, profile)
    specs = [_spec(i, 9.0) for i in range(4)]
    names = ["synthetic50"] * 4
    p0 = profiling_stats()
    placed = place_fleet(rts, specs, policy=placement.SLOAware(),
                         accel_names=names, score_cache=cache)
    p1 = profiling_stats()
    # rounds after the first reuse every untouched server's score: the
    # homogeneous stream re-scores only the previous winner
    assert p1["score_hits"] > 0
    # identical decisions to an uncached fleet
    rts2 = _mk_fleet(comps, profile)
    placed2 = place_fleet(rts2, specs, policy=placement.SLOAware(),
                          accel_names=names)
    assert ([(p.server, p.accel_id, p.accepted) for p in placed]
            == [(p.server, p.accel_id, p.accepted) for p in placed2])


# ---------------------------------------------------------------------------
# Clock threading (satellite): runtime clock -> LinkSpec + profiling
# ---------------------------------------------------------------------------


def test_runtime_clock_threads_into_link_and_profiler():
    rt = ArcusRuntime([CATALOG["synthetic50"]], clock_hz=500e6)
    assert rt.link.clock_hz == 500e6
    assert rt.profile.clock_hz == 500e6
    assert rt.profile._cfg().clock_hz == 500e6
    # an explicitly passed link is the caller's override and wins
    rt2 = ArcusRuntime([CATALOG["synthetic50"]],
                       link=LinkSpec(clock_hz=125e6), clock_hz=500e6)
    assert rt2.link.clock_hz == 125e6
    assert rt2.profile.clock_hz == 125e6
    # ... as does an explicit ProfileTable clock
    pt = ProfileTable(clock_hz=777e6)
    assert pt.clock_hz == 777e6 and pt._cfg().clock_hz == 777e6


def test_profiled_capacity_clock_invariant_at_non_default_clock():
    """Profiled Gbps capacities are wall-clock quantities: with the clock
    threaded end to end, a 500 MHz runtime profiles (and admits) like a
    250 MHz one — before the fix the default 250 MHz LinkSpec under a
    500 MHz window config doubled the link's effective bandwidth."""
    ctx = [(Path.FUNCTION_CALL, 1500, 0.9)] * 2
    cap = {}
    for hz in (250e6, 500e6):
        rt = ArcusRuntime([CATALOG["ipsec32"]], clock_hz=hz,
                          profile_table=ProfileTable(
                              LinkSpec(clock_hz=hz), n_ticks=20_000))
        cap[hz] = rt.profile.profile_context(CATALOG["ipsec32"],
                                             ctx).capacity_gbps
    assert cap[500e6] == pytest.approx(cap[250e6], rel=0.05)
    # admission decisions agree across clocks
    rt5 = ArcusRuntime([CATALOG["ipsec32"]], clock_hz=500e6,
                       profile_table=ProfileTable(LinkSpec(clock_hz=500e6),
                                                  n_ticks=20_000))
    assert rt5.register(_spec(0, 10.0, msg=1500, load=0.9))
    assert rt5.register(_spec(1, 20.0, msg=1500, load=0.9))
    assert not rt5.register(_spec(2, 10.0, msg=1500, load=0.9))


def test_controller_rejects_bad_events():
    profile = ProfileTable(n_ticks=_PROFILE_TICKS)
    rts = _mk_fleet((["synthetic50"],), profile)
    assert rts[0].register(_spec(0, 5.0))
    ctrl = FleetController(rts)
    kwargs = dict(total_ticks=6_000, window_ticks=3_000,
                  load_ref_gbps=[{0: 32.0}])
    with pytest.raises(ValueError, match="outside the run"):
        ctrl.run(events=[TenantEvent.depart(7, tenant_id=0)], **kwargs)
    with pytest.raises(ValueError, match="needs a spec"):
        ctrl.run(events=[TenantEvent(0, "arrive")], **kwargs)
    with pytest.raises(ValueError, match="unknown event kind"):
        ctrl.run(events=[dataclasses.replace(
            TenantEvent.depart(0, tenant_id=0), kind="evict")], **kwargs)
    with pytest.raises(KeyError):
        ctrl.depart(42)
    with pytest.raises(ValueError, match="fleet-unique"):
        ctrl.admit(_spec(0, 1.0))
