"""Workload subsystem: arrival-process registry, production-shaped
generators (same-seed digests pinned), scenario registry + build
determinism, and the replayable trace round-trip."""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np
import pytest

from repro.core import sim
from repro.core.flow import SLO, FlowSet, FlowSpec, Path, TrafficPattern
from repro.core.profiler import ProfileTable
from repro.core.sim import SimConfig, gen_arrivals
from repro import workloads as wl
from repro.workloads.generators import make_trace

_PROFILE_TICKS = 8_000

_CLOCK = 250e6
_TICKS = 20_000          # 640 us horizon at the default 8 cycles/tick
_HORIZON_S = _TICKS * 8 / _CLOCK


@pytest.fixture(scope="module")
def profile():
    return ProfileTable(n_ticks=_PROFILE_TICKS)


# ---------------------------------------------------------------------------
# Arrival-process registry (the sim-side extension point)
# ---------------------------------------------------------------------------


def test_unknown_process_raises_listing_registry():
    spec = FlowSpec(0, 0, Path.FUNCTION_CALL, 0,
                    TrafficPattern(1024, load=0.3, process="nope"),
                    SLO.gbps(10))
    with pytest.raises(ValueError, match="unknown arrival process"):
        gen_arrivals(FlowSet.build([spec]), SimConfig(n_ticks=1000))
    with pytest.raises(ValueError, match="mmpp"):   # lists the registry
        gen_arrivals(FlowSet.build([spec]), SimConfig(n_ticks=1000))


def test_register_process_duplicate_raises():
    def gaps(pats, rates, rng, M0, horizon_s):
        return np.full((len(pats), M0), 1.0)
    sim.register_process("__testproc__", gaps)
    assert "__testproc__" in sim.registered_processes()
    with pytest.raises(ValueError, match="already registered"):
        sim.register_process("__testproc__", gaps)
    sim.register_process("__testproc__", gaps, replace=True)


def test_workloads_import_registers_generators():
    names = sim.registered_processes()
    for name in ("cbr", "poisson", "onoff", "mmpp", "heavytail",
                 "diurnal", "corrburst", "flash", "adversarial"):
        assert name in names, names


def test_traffic_pattern_param_lookup():
    pat = TrafficPattern(1024, params=(("alpha", 1.5), ("dist", "pareto")))
    assert pat.param("alpha") == 1.5
    assert pat.param("dist") == "pareto"
    assert pat.param("missing") is None
    assert pat.param("missing", 7) == 7


# ---------------------------------------------------------------------------
# Same-seed digests: every production-shaped generator pinned
# ---------------------------------------------------------------------------


def _digest(t: np.ndarray, s: np.ndarray):
    h = hashlib.sha256()
    h.update(np.ascontiguousarray(t.astype("<i4")).tobytes())
    h.update(np.ascontiguousarray(s.astype("<i4")).tobytes())
    return t.shape, h.hexdigest()


def _generator_patterns() -> list[TrafficPattern]:
    return [
        TrafficPattern(1024, load=0.3, process="mmpp",
                       params=(("states", (0.25, 2.5)),)),
        TrafficPattern(1024, load=0.3, process="heavytail",
                       params=(("dist", "pareto"), ("alpha", 1.5))),
        TrafficPattern(1024, load=0.3, process="heavytail",
                       params=(("dist", "lognormal"), ("sigma", 1.0))),
        TrafficPattern(1024, load=0.3, process="diurnal",
                       params=(("amp", 0.8),)),
        TrafficPattern(1024, load=0.3, process="corrburst",
                       params=(("group", 3), ("burst_hz", 50_000.0),
                               ("burst_len", 8))),
        TrafficPattern(1024, load=0.3, process="flash",
                       params=(("at", 0.3), ("mult", 6.0))),
        TrafficPattern(1024, rate_mps=5e5, process="adversarial",
                       params=(("bucket_bytes", 32 * 1024),
                               ("period_s", 96e-6))),
    ]


def test_generator_same_seed_digests_pinned():
    """Same-seed traces of every production-shaped generator are pinned
    byte-for-byte — any change to a handler's rng draw order (or to the
    shared-stream iteration order in ``gen_arrivals``) is an explicit,
    visible decision, exactly like the built-in processes' digests in
    test_dataplane_sim.py."""
    pats = _generator_patterns()
    assert _digest(*make_trace(pats, n_ticks=_TICKS, seed=0)) == (
        (7, 1234),
        "33ac781cceab741f6556bb9abf959eae1e31d1569ef644ffacc6c2b79b39f2fd")
    assert _digest(*make_trace(pats, n_ticks=_TICKS, seed=7)) == (
        (7, 1208),
        "8dee228bcdd48e4da05bf65add80d9a7b9b1923cbf36aa8066d58550248fd4a6")


# ---------------------------------------------------------------------------
# Generator sanity properties
# ---------------------------------------------------------------------------


def _valid_times_s(t: np.ndarray, row: int = 0) -> np.ndarray:
    v = t[row][t[row] < np.iinfo(np.int32).max]
    return v / _CLOCK


def test_mmpp_long_run_mean_rate():
    pat = TrafficPattern(1024, load=0.3, process="mmpp",
                         params=(("states", (0.25, 2.5)),
                                 ("sojourn_s", _HORIZON_S / 10)))
    t, _s = make_trace(pat, n_ticks=_TICKS, seed=1)
    want = pat.rate_msgs_per_sec(32.0) * _HORIZON_S
    got = _valid_times_s(t).size
    assert 0.6 * want < got < 1.6 * want, (got, want)


def test_heavytail_sizes_mean_and_cap():
    cap = 64 * 1024
    for dist, knob in (("pareto", ("alpha", 1.5)),
                       ("lognormal", ("sigma", 1.0))):
        pat = TrafficPattern(1024, load=0.3, process="heavytail",
                             params=(("dist", dist), knob,
                                     ("max_bytes", cap)))
        t, s = make_trace(pat, n_ticks=_TICKS, seed=2)
        sz = s[0][t[0] < np.iinfo(np.int32).max]
        assert sz.max() <= cap
        assert sz.min() >= 1
        assert abs(sz.mean() - 1024) / 1024 < 0.25, (dist, sz.mean())


def test_heavytail_alpha_at_most_one_rejected():
    pat = TrafficPattern(1024, load=0.3, process="heavytail",
                         params=(("alpha", 1.0),))
    with pytest.raises(ValueError, match="alpha > 1"):
        make_trace(pat, n_ticks=2_000)


def test_diurnal_rate_swings_with_the_curve():
    pat = TrafficPattern(1024, load=0.3, process="diurnal",
                         params=(("amp", 0.9),))
    t, _s = make_trace(pat, n_ticks=_TICKS, seed=3)
    v = _valid_times_s(t)
    first = (v < _HORIZON_S / 2).sum()
    second = (v >= _HORIZON_S / 2).sum()
    # phase 0, one period over the horizon: day (sin > 0) then night
    assert first > 2 * second, (first, second)


def test_corrburst_epochs_shared_across_seeds():
    """Burst epochs come from the group id, not the trace seed: with the
    nominal rate fully consumed by bursts (base Poisson rate 0), two
    trace seeds produce the SAME trace — which is what keeps tenants on
    different servers (different seeds) bursting in lockstep."""
    hz, blen = 50_000.0, 8
    pat = TrafficPattern(1024, rate_mps=hz * blen, process="corrburst",
                         params=(("group", 11), ("burst_hz", hz),
                                 ("burst_len", blen)))
    t1, s1 = make_trace(pat, n_ticks=_TICKS, seed=4)
    t2, s2 = make_trace(pat, n_ticks=_TICKS, seed=5)
    assert np.array_equal(t1, t2) and np.array_equal(s1, s2)


def test_flash_storm_multiplies_rate():
    pat = TrafficPattern(1024, load=0.3, process="flash",
                         params=(("at", 0.5), ("mult", 8.0)))
    t, _s = make_trace(pat, n_ticks=_TICKS, seed=6)
    v = _valid_times_s(t)
    pre = ((v >= 0.2 * _HORIZON_S) & (v < 0.5 * _HORIZON_S)).sum()
    storm = ((v >= 0.5 * _HORIZON_S) & (v < 0.8 * _HORIZON_S)).sum()
    assert storm > 3 * pre, (pre, storm)


def test_adversarial_bursts_are_deterministic_and_phase_locked():
    bucket, period, msg = 32 * 1024, 96e-6, 1024
    nmsg = bucket // msg
    pat = TrafficPattern(msg, rate_mps=nmsg / period, process="adversarial",
                         params=(("bucket_bytes", bucket),
                                 ("period_s", period)))
    t1, _ = make_trace(pat, n_ticks=_TICKS, seed=8)
    t2, _ = make_trace(pat, n_ticks=_TICKS, seed=9)
    assert np.array_equal(t1, t2), "adversarial trace must not draw rng"
    v = _valid_times_s(t1)
    n_bursts = int(_HORIZON_S / period) + 1
    assert v.size == n_bursts * nmsg, (v.size, n_bursts, nmsg)
    # burst k opens exactly at the k-th period edge
    starts = v[::nmsg]
    assert np.allclose(starts, period * np.arange(n_bursts), atol=1e-8)


def test_trace_budget_covers_bursty_peaks():
    """Registered budget factors reserve enough trace columns that a
    peaked process is not silently truncated (the [N, M] trace matrix is
    sized per flow by ``sim.trace_budget``)."""
    hz, blen = 50_000.0, 8
    pat = TrafficPattern(1024, rate_mps=1e5, process="corrburst",
                         params=(("burst_hz", hz), ("burst_len", blen)))
    rate = pat.rate_msgs_per_sec(32.0)
    m = sim.trace_budget(pat, rate, _HORIZON_S)
    assert m >= hz * blen * _HORIZON_S, m     # bursts alone exceed rate*T
    cbr = TrafficPattern(1024, rate_mps=1e5, process="cbr")
    assert sim.trace_budget(cbr, rate, _HORIZON_S) == \
        int(np.ceil(rate * _HORIZON_S)) + 16


# ---------------------------------------------------------------------------
# Scenario registry + build determinism + replay round-trip
# ---------------------------------------------------------------------------


def test_scenario_registry():
    names = wl.scenario_names()
    for want in ("mmpp_surge", "heavy_tail", "diurnal_corr",
                 "flash_crowd", "adversarial_probe"):
        assert want in names, names
    with pytest.raises(KeyError, match="mmpp_surge"):  # lists registry
        wl.get_scenario("no_such_scenario")
    spec = wl.get_scenario("mmpp_surge")
    with pytest.raises(ValueError, match="already registered"):
        wl.register_scenario(spec)
    wl.register_scenario(spec, replace=True)


#: shrunken flash_crowd (events included) for the expensive run tests
def _small_scenario():
    spec = wl.get_scenario("flash_crowd")
    return dataclasses.replace(spec, window_ticks=1_000, n_windows=4)


def test_scenario_build_is_bitwise_deterministic(profile):
    spec = _small_scenario()
    b1 = spec.build(profile=profile)
    b2 = spec.build(profile=profile)
    assert b1.lane_maps == b2.lane_maps
    assert b1.run_kwargs["seeds"] == b2.run_kwargs["seeds"]
    for (t1, s1), (t2, s2) in zip(b1.arrivals, b2.arrivals):
        assert np.array_equal(t1, t2)
        assert np.array_equal(s1, s2)


def test_trace_roundtrip_json_and_npz(tmp_path, profile):
    spec = _small_scenario()
    built = spec.build(profile=profile)
    meta = {"scenario": spec.name, "seed": spec.seed}
    for ext in (".json", ".npz"):
        p = tmp_path / f"trace{ext}"
        wl.save_trace(p, built.arrivals, meta=meta)
        arr, got_meta = wl.load_trace(p)
        assert got_meta == meta
        for (t1, s1), (t2, s2) in zip(built.arrivals, arr):
            assert t2.dtype == np.int32 and s2.dtype == np.int32
            assert np.array_equal(t1, t2), ext
            assert np.array_equal(s1, s2), ext
    with pytest.raises(ValueError, match="json or .npz"):
        wl.save_trace(tmp_path / "trace.txt", built.arrivals)


def test_replayed_trace_reproduces_counters(tmp_path, profile):
    """The acceptance contract for replayable runs: save a built
    scenario's trace, load it back, run both — identical counters,
    churn events included (their mid-run traces regenerate from the
    same per-event seeds)."""
    spec = _small_scenario()
    b1 = spec.build(profile=profile)
    wl.save_trace(tmp_path / "t.npz", b1.arrivals,
                  meta={"scenario": spec.name})
    arr, _meta = wl.load_trace(tmp_path / "t.npz")
    b2 = spec.build(profile=profile, arrivals=arr)
    r1, rep1 = b1.run()
    r2, rep2 = b2.run()
    for a, b in zip(r1, r2):
        for k in a.counters:
            assert np.array_equal(np.asarray(a.counters[k]),
                                  np.asarray(b.counters[k])), k
    # and the windowed telemetry agrees too
    for rb1, rb2 in zip(rep1, rep2):
        for w1, w2 in zip(rb1, rb2):
            assert w1.measured == w2.measured
