"""Unit + property tests for the token-bucket mechanism (Arcus §4.2)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # optional dev dep — property tests skip
    from _hypothesis_stub import given, settings, st

from repro.core import token_bucket as tb


def test_paper_table2_rates():
    """The paper's published registers shape at >= the nominal SLO
    (their 1 Gbps row carries ~2x headroom; the rest ~2.4%)."""
    for slo, params in tb.PAPER_TABLE2.items():
        rate_gbps = tb.achieved_rate(params) * 8 / 1e9
        assert rate_gbps >= slo, (slo, rate_gbps)
        assert rate_gbps <= 2.1 * slo


@pytest.mark.parametrize("slo", [0.5, 1, 3, 10, 47, 100, 400, 1000])
def test_planner_accuracy_gbps(slo):
    p = tb.params_for_gbps(float(slo))
    rate = tb.achieved_rate(p) * 8 / 1e9
    assert abs(rate - slo) / slo < 0.01
    assert p.bkt_size >= p.refill_rate  # invariant: no refill clipping


@pytest.mark.parametrize("slo", [100, 5_000, 300_000, 2_000_000])
def test_planner_accuracy_iops(slo):
    p = tb.params_for_iops(float(slo))
    rate = tb.achieved_rate(p)
    assert abs(rate - slo) / slo < 0.01


def test_advance_exact_refill_accounting():
    st_ = tb.init([10], [100], [50], [tb.MODE_GBPS], start_full=False)
    st_ = tb.advance(st_, 49)
    assert int(st_.tokens[0]) == 0
    st_ = tb.advance(st_, 1)
    assert int(st_.tokens[0]) == 10
    st_ = tb.advance(st_, 500)      # 10 refills -> clamped at bucket
    assert int(st_.tokens[0]) == 100


def test_admit_and_consume():
    st_ = tb.init([10], [100], [50], [tb.MODE_GBPS])
    st_, ok = tb.try_admit(st_, [60], [True])
    assert bool(ok[0]) and int(st_.tokens[0]) == 40
    st_, ok = tb.try_admit(st_, [60], [True])
    assert not bool(ok[0]) and int(st_.tokens[0]) == 40
    # IOPS mode costs 1 regardless of size
    st2 = tb.init([1], [4], [100], [tb.MODE_IOPS])
    st2, ok = tb.try_admit(st2, [10_000], [True])
    assert bool(ok[0]) and int(st2.tokens[0]) == 3


# ---------------------------------------------------------------------------
# Properties
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(refill=st.integers(1, 1 << 15), bkt=st.integers(1, 1 << 20),
       interval=st.integers(1, 4096),
       steps=st.lists(st.integers(0, 100_000), min_size=1, max_size=30))
def test_tokens_bounded_and_monotone_refill(refill, bkt, interval, steps):
    """tokens stay in [0, bkt]; advancing never removes tokens."""
    bkt = max(bkt, refill)
    s = tb.init([refill], [bkt], [interval], [tb.MODE_GBPS],
                start_full=False)
    for e in steps:
        before = int(s.tokens[0])
        s = tb.advance(s, e)
        after = int(s.tokens[0])
        assert 0 <= after <= bkt
        assert after >= before


@settings(max_examples=40, deadline=None)
@given(refill=st.integers(1, 1024), interval=st.integers(16, 2048),
       n_chunks=st.integers(2, 20), chunk=st.integers(1, 3000))
def test_advance_split_invariance(refill, interval, n_chunks, chunk):
    """Advancing by k chunks == advancing once by the total (catch-up
    semantics are exact — the software-timer pathology is about *when*
    admissions happen, not token conservation)."""
    bkt = refill * (n_chunks * chunk // interval + 2)
    a = tb.init([refill], [bkt], [interval], [tb.MODE_GBPS],
                start_full=False)
    b = tb.init([refill], [bkt], [interval], [tb.MODE_GBPS],
                start_full=False)
    for _ in range(n_chunks):
        a = tb.advance(a, chunk)
    b = tb.advance(b, n_chunks * chunk)
    assert int(a.tokens[0]) == int(b.tokens[0])
    assert int(a.cyc[0]) == int(b.cyc[0])


@settings(max_examples=30, deadline=None)
@given(slo=st.floats(0.5, 900.0))
def test_long_run_rate_never_exceeds_plan(slo):
    """Admitted bytes over a long window <= planned rate x time + bucket."""
    p = tb.params_for_gbps(slo)
    s = tb.pack([p])
    total_cycles = 250_000
    admitted = 0
    msg = 1024
    rng = np.random.default_rng(0)
    for _ in range(200):
        s = tb.advance(s, total_cycles // 200)
        for _ in range(rng.integers(1, 4)):
            s, ok = tb.try_admit(s, [msg], [True])
            admitted += int(ok[0]) * msg
    budget = tb.achieved_rate(p) * total_cycles / 250e6 + p.bkt_size
    assert admitted <= budget * 1.001
