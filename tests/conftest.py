import os
import sys

# tests see ONE device (the dry-run's 512-device override is local to
# launch/dryrun.py, never global)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
