"""Fleet-scale batching tests: ragged accelerator tables (`ac_mask`) and
the vmapped `run_managed_batch` control plane.

The acceptance bar throughout is *bitwise equality*: a batched element —
whatever its flow count or accelerator complement — must produce exactly
the counters, completion records and WindowReports of its unpadded serial
run."""
import numpy as np

from repro.core import baselines, engine, token_bucket as tb
from repro.core.accelerator import CATALOG, AccelTable
from repro.core.flow import SLO, FlowSet, FlowSpec, Path, TrafficPattern
from repro.core.interconnect import LinkSpec
from repro.core.profiler import ProfileTable
from repro.core.runtime import ArcusRuntime, register_fleet, run_managed_batch
from repro.core.sim import (SHAPING_HW, SHAPING_SW, SimConfig, gen_arrivals,
                            gen_stall_mask, simulate, simulate_batch,
                            stack_arrivals)

_EXACT_KEYS = ("c_adm_msgs", "c_done_msgs", "c_drops", "c_adm_bytes",
               "c_done_bytes")


def _assert_equal(serial, batch, label=""):
    for k in _EXACT_KEYS:
        assert np.array_equal(serial.counters[k], batch.counters[k]), \
            (label, k, serial.counters[k], batch.counters[k])
    np.testing.assert_array_equal(serial.comp_flow, batch.comp_flow)
    np.testing.assert_array_equal(serial.comp_t_s, batch.comp_t_s)


# ---------------------------------------------------------------------------
# Ragged accelerator tables in simulate_batch
# ---------------------------------------------------------------------------


def _accel_el(n_flows, accel_names, shaping=SHAPING_HW, k_srv=2, seed=None):
    """One batch element with its own accelerator complement (flows are
    spread across all of its accelerators)."""
    A = len(accel_names)
    specs = [FlowSpec(i, i, Path.FUNCTION_CALL, i % A,
                      TrafficPattern(1024, load=0.8 / n_flows,
                                     process="poisson"),
                      SLO.gbps(5.0 + 3.0 * i))
             for i in range(n_flows)]
    flows = FlowSet.build(specs)
    cfg = SimConfig(n_ticks=5_000, shaping=shaping, k_srv=k_srv, k_eg=8)
    arr = gen_arrivals(flows, cfg, seed=seed if seed is not None else n_flows,
                       load_ref_gbps={i: 50.0 for i in range(n_flows)})
    plans = [tb.params_for_gbps(5.0 + 3.0 * i) for i in range(n_flows)]
    if shaping == SHAPING_SW:
        tbs = baselines.make_tb_state(baselines.HOST_TS_REFLEX, plans)
    else:
        tbs = tb.pack(plans)
    atab = AccelTable.build([CATALOG[a] for a in accel_names])
    return flows, atab, cfg, arr, tbs


def test_ragged_accel_batch_matches_serial_bitwise():
    """simulate_batch over elements with DIFFERENT accelerator counts
    (padded + ac-masked) returns counters and completion records
    bitwise-equal to unpadded serial runs — across shaping modes and on
    both sides of the service-vectorization width threshold (the padded
    batch engine crosses A*k_srv >= 8 while a narrow serial element does
    not, so this also pins vec==seq stage equality across engines)."""
    link = LinkSpec()
    for k_srv in (2, 4):
        for shaping in (SHAPING_HW, SHAPING_SW):
            els = [_accel_el(2, ["synthetic50"], shaping, k_srv),
                   _accel_el(3, ["synthetic50", "aes256"], shaping, k_srv),
                   _accel_el(1, ["ipsec32", "sha3_512", "compress"],
                             shaping, k_srv),
                   _accel_el(4, ["aes256", "synthetic50"], shaping, k_srv,
                             seed=9)]
            stall = None
            if shaping == SHAPING_SW:
                stall = np.stack([
                    gen_stall_mask(e[2], seed=b + 1,
                                   stall_rate_hz=50_000.0,
                                   stall_us=(10.0, 60.0))
                    for b, e in enumerate(els)])
            serial = [simulate(f, a, link, c, t, *arr,
                               stall_mask=None if stall is None
                               else stall[b])
                      for b, (f, a, c, arr, t) in enumerate(els)]
            engine.cache_clear()
            batch = simulate_batch([e[0] for e in els], [e[1] for e in els],
                                   link, els[0][2], [e[4] for e in els],
                                   *stack_arrivals([e[3] for e in els]),
                                   stall_mask=stall)
            assert engine.cache_info()["entries"] == 1
            for b, (s, bt) in enumerate(zip(serial, batch)):
                _assert_equal(s, bt, label=(k_srv, shaping, b))


def test_ac_mask_padded_accels_stay_inert():
    """Stage invariants of the ragged accel padding: a padded accelerator
    row never enqueues, never serves (all lanes disabled) and never
    contributes completions."""
    els = [_accel_el(2, ["synthetic50", "aes256", "ipsec32"]),
           _accel_el(2, ["synthetic50"])]
    link = LinkSpec()
    arr_t, arr_sz = stack_arrivals([e[3] for e in els])
    raw = engine.run_window_batch([e[0] for e in els],
                                  [e[1] for e in els], link, els[0][2],
                                  [e[4] for e in els], arr_t, arr_sz)
    aq_cnt = np.asarray(raw["aq_cnt"])          # [B, A_max]
    lanes = np.asarray(raw["lanes"])            # [B, A_max, lmax]
    assert aq_cnt.shape[1] == 3                 # padded to n_accels_max
    # element 1 has one real accelerator; rows 1-2 are padding
    assert np.all(aq_cnt[1, 1:] == 0)
    assert np.all(lanes[1, 1:] >= 3e38)         # every lane still disabled
    assert np.all(np.asarray(raw["aq_bytes"])[1, 1:] == 0)
    # the active rows did real work
    assert np.asarray(raw["c_done_msgs"])[1, :2].sum() > 0


# ---------------------------------------------------------------------------
# Fleet-batched run_managed
# ---------------------------------------------------------------------------

_FLEET = [
    # (accel complement, [(slo_gbps, msg_bytes) per flow]) — mixed flow
    # counts AND mixed accelerator counts across servers
    (["synthetic50"], [(10.0, 1024), (20.0, 1024)]),
    (["ipsec32", "synthetic50"], [(8.0, 1500)]),
    (["synthetic50", "aes256", "ipsec32"],
     [(6.0, 512), (5.0, 1024), (4.0, 2048)]),
]
_SEEDS = [3, 4, 5]


def _mk_fleet(profile=None):
    profile = profile or ProfileTable(n_ticks=8_000)
    rts, specs = [], []
    for names, flows in _FLEET:
        rt = ArcusRuntime([CATALOG[n] for n in names],
                          profile_table=profile)
        rts.append(rt)
        specs.append([FlowSpec(i, i, Path.FUNCTION_CALL,
                               i % len(names),
                               TrafficPattern(m, load=0.4),
                               SLO.gbps(s))
                      for i, (s, m) in enumerate(flows)])
    return rts, specs


def _refs(specs):
    return [{i: 32.0 for i in range(len(s))} for s in specs]


def _run_serial(total, window):
    rts, specs = _mk_fleet()
    for rt, sp in zip(rts, specs):
        for s in sp:
            assert rt.register(s)
    out = [rt.run_managed(total_ticks=total, window_ticks=window,
                          seed=_SEEDS[b],
                          load_ref_gbps=_refs(specs)[b])
           for b, rt in enumerate(rts)]
    return rts, out


def _run_batch(total, window):
    rts, specs = _mk_fleet()
    acc = register_fleet(rts, specs)
    assert all(all(a) for a in acc)
    engine.cache_clear()
    res, rep = run_managed_batch(rts, total_ticks=total,
                                 window_ticks=window, seeds=_SEEDS,
                                 load_ref_gbps=_refs(specs))
    return rts, res, rep


def _check_fleet_equal(rts_s, serial, rts_b, res_b, rep_b):
    for b, (res_s, rep_s) in enumerate(serial):
        assert len(rep_s) == len(rep_b[b])
        for ws, wb in zip(rep_s, rep_b[b]):
            assert ws.t_end_s == wb.t_end_s
            assert ws.measured == wb.measured, (b, ws.measured, wb.measured)
            assert ws.violated == wb.violated
            assert ws.reconfigured == wb.reconfigured
            assert ws.path_changes == wb.path_changes
        _assert_equal(res_s, res_b[b], label=f"server{b}")
        # post-run control state (registers, headroom, violation counts)
        for fid in rts_s[b].table:
            st_s, st_b = rts_s[b].table[fid], rts_b[b].table[fid]
            assert st_s.params == st_b.params
            assert st_s.headroom == st_b.headroom
            assert st_s.violations == st_b.violations
            assert st_s.measured == st_b.measured


def test_fleet_run_managed_matches_serial_bitwise():
    """B-server run_managed_batch (mixed flow counts AND mixed accelerator
    counts) produces counters, completion records, WindowReports and
    control state bitwise-equal to B serial run_managed loops — as ONE
    compiled engine entry (the tentpole acceptance criterion)."""
    rts_s, serial = _run_serial(20_000, 4_000)
    rts_b, res_b, rep_b = _run_batch(20_000, 4_000)
    assert engine.cache_info() == {"entries": 1, "traces": 1}
    assert all(len(r) == 5 for r in rep_b)
    _check_fleet_equal(rts_s, serial, rts_b, res_b, rep_b)


def test_fleet_trailing_partial_window_survives_vmap():
    """total_ticks % window_ticks != 0 runs the remainder as one short
    batched window (a second engine entry), still bitwise-equal to the
    serial partial-window path (regression: the serial fix of PR 2 must
    survive vmapping)."""
    rts_s, serial = _run_serial(10_000, 4_000)
    rts_b, res_b, rep_b = _run_batch(10_000, 4_000)
    assert engine.cache_info()["entries"] == 2   # full + remainder window
    assert all(len(r) == 3 for r in rep_b)       # 2 full + 1 partial
    _check_fleet_equal(rts_s, serial, rts_b, res_b, rep_b)
    # the tail was really simulated
    for b in range(len(rep_b)):
        assert rep_b[b][-1].t_end_s > rep_b[b][-2].t_end_s


def test_fleet_report_timestamps_use_sim_clock():
    """WindowReport.t_end_s must follow the SimConfig clock — matching the
    serial path's ``result.seconds`` — even when the runtime's control
    clock differs (regression: the fleet pass once stamped reports with
    the runtime clock)."""
    profile = ProfileTable(n_ticks=4_000)

    def mk():
        rt = ArcusRuntime([CATALOG["synthetic50"]], profile_table=profile,
                          clock_hz=500e6)
        assert rt.register(FlowSpec(0, 0, Path.FUNCTION_CALL, 0,
                                    TrafficPattern(1024, load=0.4),
                                    SLO.gbps(10.0)))
        return rt

    res_s, rep_s = mk().run_managed(total_ticks=8_000, window_ticks=4_000,
                                    load_ref_gbps={0: 32.0})
    res_b, rep_b = run_managed_batch([mk()], total_ticks=8_000,
                                     window_ticks=4_000,
                                     load_ref_gbps=[{0: 32.0}])
    assert res_b[0].seconds == res_s.seconds
    for ws, wb in zip(rep_s, rep_b[0]):
        assert ws.t_end_s == wb.t_end_s
        assert ws.measured == wb.measured


def test_register_fleet_matches_serial_admission():
    """register_fleet batches each admission round's profiling but must
    reproduce serial accept/reject decisions exactly — including
    rejections (here: a third 10 Gbps flow oversubscribing ipsec32's ~31
    Gbps profiled capacity)."""
    def specs_for(fid_slo):
        return [FlowSpec(i, i, Path.FUNCTION_CALL, 0,
                         TrafficPattern(1500, load=0.9), SLO.gbps(s))
                for i, s in enumerate(fid_slo)]
    fleet_slos = [(10.0, 20.0, 10.0), (5.0,), (12.0, 12.0, 12.0)]
    # serial
    serial_acc = []
    pt_s = ProfileTable(n_ticks=8_000)
    for slos in fleet_slos:
        rt = ArcusRuntime([CATALOG["ipsec32"]], profile_table=pt_s)
        serial_acc.append([rt.register(s) for s in specs_for(slos)])
    # fleet-batched
    pt_b = ProfileTable(n_ticks=8_000)
    rts = [ArcusRuntime([CATALOG["ipsec32"]], profile_table=pt_b)
           for _ in fleet_slos]
    batch_acc = register_fleet(rts, [specs_for(s) for s in fleet_slos])
    assert batch_acc == serial_acc
    assert batch_acc[0] == [True, True, False]   # 40 > profiled ~31 Gbps
    # identical profiled entries (batched profiling is bitwise-equal)
    assert set(pt_b.entries) == set(pt_s.entries)
    for k, e in pt_s.entries.items():
        assert pt_b.entries[k].capacity_gbps == e.capacity_gbps
        assert pt_b.entries[k].per_flow_gbps == e.per_flow_gbps
