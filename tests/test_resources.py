"""Multi-resource contention tests: the R=1 degenerate bitwise contract
across engine / placement / controller, resource-axis charge semantics
(grant-gated ingress, debt-charged egress, burst carry, fabric-only
exemption), the vector-margin plumbing through CapacityEntry and the
placement policies, scalar-JSON schema compatibility, the CapacityEntry
deprecation shims, and the service-vectorization threshold knob."""
import dataclasses
import json
import warnings

import numpy as np
import pytest

from repro.core import engine, placement, token_bucket as tb
from repro.core.accelerator import CATALOG, AccelTable
from repro.core.controller import FleetController, TenantEvent
from repro.core.flow import SLO, FlowSet, FlowSpec, Path, TrafficPattern
from repro.core.interconnect import (RES_MEM_BW, LinkSpec, ResourceSpec,
                                     host_dma, mem_bw)
from repro.core.profiler import CapacityEntry, ProfileTable, context_key
from repro.core.runtime import ArcusRuntime, place_fleet
from repro.core.sim import (SHAPING_HW, SHAPING_NONE, SimConfig,
                            gen_arrivals, simulate, simulate_batch,
                            stack_arrivals)

_EXACT_KEYS = ("c_adm_msgs", "c_done_msgs", "c_drops", "c_adm_bytes",
               "c_done_bytes")

#: an axis so wide it can never run dry — the inert-axis degenerate case
_HUGE = 1e6


def _assert_results_equal(a, b, label=""):
    for k in _EXACT_KEYS:
        assert np.array_equal(a.counters[k], b.counters[k]), \
            (label, k, a.counters[k], b.counters[k])
    np.testing.assert_array_equal(a.comp_flow, b.comp_flow)
    np.testing.assert_array_equal(a.comp_sz, b.comp_sz)
    np.testing.assert_allclose(a.counters["c_lat_sum"],
                               b.counters["c_lat_sum"], rtol=1e-6)


def _scenario(n_flows=2, n_ticks=12_000, path=Path.FUNCTION_CALL,
              accel="synthetic50", seed=0, load=None, **cfg_kw):
    slos = [10.0 + 5.0 * i for i in range(n_flows)]
    specs = [FlowSpec(i, i, path, 0,
                      TrafficPattern(1024,
                                     load=(load or 0.8) / n_flows,
                                     process="poisson"), SLO.gbps(s))
             for i, s in enumerate(slos)]
    flows = FlowSet.build(specs)
    cfg = SimConfig(n_ticks=n_ticks,
                    **{"shaping": SHAPING_HW, **cfg_kw})
    arr = gen_arrivals(flows, cfg, seed=seed,
                       load_ref_gbps={i: 55.0 for i in range(n_flows)})
    tbs = tb.pack([tb.params_for_gbps(s) for s in slos])
    accels = AccelTable.build([CATALOG[accel]])
    return flows, accels, cfg, tbs, arr


# ---------------------------------------------------------------------------
# Satellite 3 — the R=1 degenerate contract, engine layer
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("variant", ["huge_cap", "zero_demand", "both"])
@pytest.mark.parametrize("fast", [True, False])
def test_inert_axis_bitwise_equal_to_default(variant, fast):
    """A resource vector that cannot bind — a huge-capacity axis and/or an
    axis every accelerator charges 0.0 on — must reproduce the default
    (R=1) engine bitwise: same counters, completions and latencies, on
    both the vectorized and sequential stage paths."""
    flows, accels, cfg, tbs, arr = _scenario(
        grant_fast=fast, stage_fast=fast, k_grant=4)
    base = simulate(flows, accels, LinkSpec(), cfg, tbs, *arr)

    if variant == "huge_cap":
        res = (mem_bw(_HUGE),)
        accels_v = accels
    else:
        # a *tight* axis (2 Gbps would halve goodput) that the device
        # charges nothing on — inert because the demand is zero
        spec = dataclasses.replace(CATALOG["synthetic50"],
                                   res_demand=((RES_MEM_BW, 0.0, 0.0),))
        accels_v = AccelTable.build([spec])
        res = ((mem_bw(2.0), host_dma(_HUGE)) if variant == "both"
               else (mem_bw(2.0),))
    link_v = LinkSpec(resources=res)
    got = simulate(flows, accels_v, link_v, cfg, tbs, *arr)
    _assert_results_equal(base, got, variant)


def test_resource_batch_matches_serial_bitwise():
    """Ragged batch with two live resource axes == serial unpadded runs,
    counter for counter — and the whole batch is ONE compiled entry."""
    link = LinkSpec(resources=(mem_bw(12.0), host_dma(20.0)))
    els = []
    for n, path in ((3, Path.FUNCTION_CALL), (2, Path.INLINE_NIC_TX)):
        f, a, cfg, t, arr = _scenario(n_flows=n, n_ticks=6_000, path=path,
                                      accel="decompress", seed=n)
        els.append((f, a, cfg, t, arr))
    serial = [simulate(f, a, link, c, t, *arr)
              for f, a, c, t, arr in els]
    engine.cache_clear()
    batch = simulate_batch([f for f, *_ in els], els[0][1], link,
                           els[0][2], [t for _, _, _, t, _ in els],
                           *stack_arrivals([arr for *_, arr in els]))
    assert engine.cache_info() == {"entries": 1, "traces": 1}
    for s, b, (f, *_r) in zip(serial, batch, els):
        _assert_results_equal(s, b, f"n={f.n}")


def test_batch_rejects_mismatched_axis_counts():
    f, a, cfg, t, arr = _scenario(n_flows=1, n_ticks=500)
    links = [LinkSpec(), LinkSpec(resources=(mem_bw(10.0),))]
    with pytest.raises(ValueError, match="resource"):
        simulate_batch(f, a, links, cfg, [t, t],
                       *stack_arrivals([arr, arr]))


# ---------------------------------------------------------------------------
# Resource-axis charge semantics
# ---------------------------------------------------------------------------


def _goodput(link, **kw):
    f, a, cfg, t, arr = _scenario(n_flows=1, n_ticks=20_000, **kw)
    res = simulate(f, a, link, cfg, t, *arr)
    return float(res.mean_ingress_gbps(0, f))


def test_tight_axis_throttles_to_demand_algebra():
    """A saturated axis sustains cap / (w_in + w_eg * egress_ratio) of
    ingress goodput: synthetic50 (R=1 egress) with default 1.0/1.0
    demand on an 8 Gbps axis lands at ~4 Gbps — the same algebra
    CapacityEntry's per-flow coefficients use."""
    free = _goodput(LinkSpec())
    tight = _goodput(LinkSpec(resources=(mem_bw(8.0),)))
    assert free > 9.0                        # SLO-shaped, axis not binding
    assert 3.4 < tight < 4.05, tight         # cap/(1+1), minus startup debt


def test_burst_knob_carries_idle_budget():
    """burst_bytes > 0 lets idle-tick budget accumulate (token-bucket
    depth); burst=0 loses it exactly like the link does."""
    lose = _goodput(LinkSpec(resources=(mem_bw(8.0),)))
    keep = _goodput(LinkSpec(resources=(mem_bw(8.0, burst_bytes=2**20),)))
    assert keep >= lose
    assert keep > 3.9                        # bursts recover poisson gaps


def test_fabric_only_axis_exempts_off_fabric_bytes():
    """INLINE_NIC_TX egresses to the wire (off-fabric): a fabric_only
    host-DMA axis charges its ingress bytes only, so the same capacity
    sustains ~2x the goodput of a pooled axis charging both directions."""
    pooled = _goodput(LinkSpec(resources=(mem_bw(8.0),)),
                      path=Path.INLINE_NIC_TX)
    fabric = _goodput(LinkSpec(resources=(host_dma(8.0),)),
                      path=Path.INLINE_NIC_TX)
    assert 3.4 < pooled < 4.05, pooled
    assert fabric > 1.7 * pooled, (fabric, pooled)


# ---------------------------------------------------------------------------
# CapacityEntry: vector margins, legacy shims, JSON schemas
# ---------------------------------------------------------------------------


def test_capacity_entry_vector_margin_is_min_over_axes():
    e = CapacityEntry([50.0, 20.0], [[25.0, 25.0], [2.0, 2.0]], 1.0,
                      res_names=["link", RES_MEM_BW])
    slo = [10.0, 10.0]
    m = e.slo_margins(slo)
    assert len(m) == 2
    assert e.slo_margin(slo) == min(m)
    # axis 1 binds: 10+10 SLO * 2.0 coef = 40 demand > 20 cap
    assert m[1] < 0 < m[0]
    assert not e.slo_tag(slo)
    # R=1 entries keep the scalar semantics exactly
    e1 = CapacityEntry(50.0, [25.0, 25.0], 1.0)
    assert e1.slo_margins(slo) == [e1.slo_margin(slo)]


def test_capacity_entry_legacy_kwargs_warn_but_work():
    with pytest.warns(DeprecationWarning, match="capacity_gbps"):
        e = CapacityEntry(capacity_gbps=27.0, per_flow_gbps=[2.0, 25.0])
    assert e.capacity == [27.0]
    assert e.per_flow == [[2.0, 25.0]]
    assert e.capacity_gbps == 27.0 and e.per_flow_gbps == [2.0, 25.0]
    # positional scalar promotion is the supported spelling — silent
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        e2 = CapacityEntry(27.0, [2.0, 25.0], 1.0)
    assert e2.capacity == e.capacity and e2.per_flow == e.per_flow


def test_profile_table_scalar_json_loads_bit_for_bit(tmp_path):
    """Satellite 1: a pre-vector JSON table (scalar capacity_gbps /
    per_flow_gbps entries) loads as R=1 degenerate vectors whose floats —
    and therefore margins, tags and admission decisions — are bit-for-bit
    the persisted values.  load_json is the from_json alias."""
    table = ProfileTable(n_ticks=4_000)
    ctx = [(Path.FUNCTION_CALL, 1500, 0.9), (Path.FUNCTION_CALL, 512, 0.5)]
    entry = table.profile_context(CATALOG["ipsec32"], ctx)
    new_p, old_p = tmp_path / "new.json", tmp_path / "legacy.json"
    table.to_json(str(new_p))
    # re-emit the same table in the pre-vector schema
    legacy = {k: {"capacity_gbps": v.capacity[0],
                  "per_flow_gbps": list(v.per_flow[0]),
                  "fairness": v.fairness, "ctx": v.ctx}
              for k, v in table.entries.items()}
    old_p.write_text(json.dumps(legacy))

    for p in (new_p, old_p):
        loaded = ProfileTable.load_json(str(p))
        assert loaded.entries.keys() == table.entries.keys()
        for k, v in table.entries.items():
            w = loaded.entries[k]
            assert w.capacity[0] == v.capacity[0]
            assert list(w.per_flow[0]) == list(v.per_flow[0])
            assert w.fairness == v.fairness
            slo = [4.0] * len(v.per_flow[0])
            assert w.slo_margin(slo) == v.slo_margin(slo)
            assert w.slo_tag(slo) == v.slo_tag(slo)
            assert w.residual_gbps(slo) == v.residual_gbps(slo)
    assert entry.slo_margins([4.0, 4.0])[0] == entry.slo_margin([4.0, 4.0])


def test_context_key_stable_without_hints():
    base = [(Path.FUNCTION_CALL, 1024, 0.5), (Path.INLINE_NIC_TX, 64, 0.9)]
    k3 = context_key("aes", base)
    assert "~" not in k3                     # pre-vector keys unchanged
    hinted = [t + (((RES_MEM_BW, 0.05, 0.1),),) for t in base]
    k4 = context_key("aes", hinted)
    assert k4 != k3 and k4.startswith(k3.split("|")[0])
    # hint participates in identity, not in canonical order
    assert context_key("aes", list(reversed(hinted))) == k4


# ---------------------------------------------------------------------------
# Placement: vector margins thread through candidates and policies
# ---------------------------------------------------------------------------


def _cand(server, margin_res, key):
    return placement.Candidate(
        server=server, accel_id=0,
        spec=FlowSpec(0, 0, Path.FUNCTION_CALL, 0, TrafficPattern(1024),
                      SLO.gbps(1.0)),
        entry=CapacityEntry(50.0, [50.0], 1.0), slo_gbps=(1.0,),
        feasible=True, margin=min(margin_res), residual=10.0,
        server_key=key, margin_res=tuple(margin_res))


def test_slo_aware_axis_scoring_vs_vector_scoring():
    """Vector scoring (min over axes) and axis-0 scoring pick different
    servers when link headroom and resource headroom disagree — the
    mechanism benchmarks/contention.py measures fleet-wide."""
    cands = [_cand(0, [0.8, 0.1], key=(("a",), ())),   # link-rich, mem-poor
             _cand(1, [0.4, 0.5], key=(("b",), ()))]   # balanced
    assert placement.SLOAware().select(cands).server == 1
    assert placement.SLOAware(axis=0).select(cands).server == 0
    assert placement.SLOAware(axis=0).name == "slo_aware_axis0"
    # hand-built candidates without margin_res fall back to the scalar
    bare = dataclasses.replace(cands[0], margin_res=())
    assert placement.SLOAware(axis=1)._score(bare) == bare.margin


def test_place_fleet_populates_vector_margins():
    link = LinkSpec(resources=(mem_bw(40.0),))
    profile = ProfileTable(n_ticks=4_000, link=link)
    rts = [ArcusRuntime([CATALOG["synthetic50"]], profile_table=profile,
                        link=link)]
    spec = FlowSpec(0, 0, Path.FUNCTION_CALL, 0,
                    TrafficPattern(1024, load=0.5, process="poisson"),
                    SLO.gbps(8.0))
    placed = place_fleet(rts, [spec], policy=placement.SLOAware())
    assert placed[0].accepted
    entry = rts[0].profile.lookup("synthetic50",
                                  [(Path.FUNCTION_CALL, 1024, 0.5)])
    assert entry is not None and len(entry.capacity) == 2
    assert entry.res_names == ["link", RES_MEM_BW]
    margins = entry.slo_margins([8.0])
    assert len(margins) == 2
    assert entry.slo_margin([8.0]) == min(margins)


# ---------------------------------------------------------------------------
# Satellite 3 — degenerate contract, placement + controller layers
# ---------------------------------------------------------------------------


def _fleet(link, profile_ticks=4_000):
    profile = ProfileTable(n_ticks=profile_ticks, link=link)
    return [ArcusRuntime([CATALOG["synthetic50"]], profile_table=profile,
                         link=link)
            for _ in range(2)]


def _churn(link):
    rts = _fleet(link)
    ctrl = FleetController(rts)
    specs = [FlowSpec(i, i, Path.FUNCTION_CALL, 0,
                      TrafficPattern(1024, load=0.4, process="poisson"),
                      SLO.gbps(6.0 + 2.0 * i))
             for i in range(4)]
    placed = ctrl.place(specs, policy=placement.SLOAware())
    events = [TenantEvent.depart(2, tenant_id=1)]
    res, reports = ctrl.run(total_ticks=12_000, window_ticks=3_000,
                            seeds=[1, 2], events=events,
                            load_ref_gbps=[{i: 32.0 for i in range(4)}] * 2)
    return placed, res, reports, ctrl


def test_degenerate_placement_and_churn_bitwise():
    """An inert huge-capacity axis must not perturb the control plane:
    identical admission decisions, churn counters, window reports and
    controller stats vs the default R=1 link."""
    p0, r0, w0, c0 = _churn(LinkSpec())
    p1, r1, w1, c1 = _churn(LinkSpec(resources=(mem_bw(_HUGE),)))
    assert [(p.accepted, p.server, p.accel_id) for p in p0] == \
           [(p.accepted, p.server, p.accel_id) for p in p1]
    for b in range(2):
        for k in _EXACT_KEYS:
            np.testing.assert_array_equal(r0[b].counters[k],
                                          r1[b].counters[k])
        assert len(w0[b]) == len(w1[b])
        for wa, wb in zip(w0[b], w1[b]):
            assert wa.measured == wb.measured
            assert wa.violated == wb.violated
            assert wa.reconfigured == wb.reconfigured
    assert c0.stats == c1.stats


# ---------------------------------------------------------------------------
# Satellite 2 — the service-vectorization threshold knob
# ---------------------------------------------------------------------------


def test_service_vec_min_env_and_field(monkeypatch):
    assert SimConfig(n_ticks=1).service_vec_min == 8     # A*k_srv >= 8
    monkeypatch.setenv("REPRO_SERVICE_VEC_MIN", "3")
    assert SimConfig(n_ticks=1).service_vec_min == 3     # env rebinds
    assert SimConfig(n_ticks=1,
                     service_vec_min=99).service_vec_min == 99


def test_service_vec_threshold_paths_bitwise_equal():
    """Forcing the vectorized service stage (threshold 1) and forcing the
    sequential fallback (threshold huge) on the SAME scenario must agree
    bitwise — the knob moves a perf cliff, never a result."""
    flows, accels, cfg, tbs, arr = _scenario(
        n_flows=4, n_ticks=6_000, shaping=SHAPING_NONE, stage_fast=True,
        k_srv=4, k_eg=4)
    # A=1, k_srv=4: below the default 8 threshold — the knob decides
    lo = dataclasses.replace(cfg, service_vec_min=1)       # vectorized
    hi = dataclasses.replace(cfg, service_vec_min=10**6)   # sequential
    link = LinkSpec()
    engine.cache_clear()
    r_lo = simulate(flows, accels, link, lo, tbs, *arr)
    r_hi = simulate(flows, accels, link, hi, tbs, *arr)
    # the threshold is structural: two distinct compiled entries
    assert engine.cache_info()["entries"] == 2
    _assert_results_equal(r_lo, r_hi, "service_vec_min")
