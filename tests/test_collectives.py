"""Correctness of the perf-pass distributed attention (shard_map).

On the single-CPU test mesh the shard axes have size 1, so these validate
the masking / scale / combine algebra against the oracle; multi-shard
equivalence follows from the partial-softmax identities (max/psum over
shards), which the dry run exercises at 256 devices.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.collectives import (make_seq_sharded_cache_update,
                                           make_seq_sharded_decode_attn)
from repro.kernels.decode_attention import ref as da_ref
from repro.launch.mesh import make_dev_mesh

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("window", [0, 64])
def test_seq_sharded_attention_matches_oracle(window):
    mesh = make_dev_mesh(1, 1)
    B, H, KvH, D, S = 2, 8, 4, 64, 256
    q = jnp.asarray(RNG.standard_normal((B, H, D)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, KvH, D)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, KvH, D)), jnp.float32)
    lengths = jnp.asarray([100, 220], jnp.int32)
    with mesh:
        fn = make_seq_sharded_decode_attn(mesh, "data", "model")
        got = jax.jit(lambda *a: fn(*a, window=window))(q, k, v, lengths)
    want = da_ref.decode_attention(q, k, v, lengths, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_seq_sharded_attention_d_axis_matches_oracle():
    mesh = make_dev_mesh(1, 1)
    B, H, KvH, D, S = 1, 4, 2, 32, 128
    q = jnp.asarray(RNG.standard_normal((B, H, D)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, KvH, D)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, KvH, D)), jnp.float32)
    lengths = jnp.asarray([128], jnp.int32)
    with mesh:
        fn = make_seq_sharded_decode_attn(mesh, "data", None, "model")
        got = jax.jit(fn)(q, k, v, lengths)
    want = da_ref.decode_attention(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_seq_sharded_cache_update_writes_one_slot():
    mesh = make_dev_mesh(1, 1)
    B, S, KvH, D = 2, 64, 2, 16
    ck = jnp.zeros((B, S, KvH, D), jnp.float32)
    cv = jnp.zeros((B, S, KvH, D), jnp.float32)
    k_new = jnp.ones((B, KvH, D), jnp.float32)
    v_new = 2 * jnp.ones((B, KvH, D), jnp.float32)
    slot = jnp.asarray([3, 10], jnp.int32)
    with mesh:
        fn = make_seq_sharded_cache_update(mesh, "data", "model")
        nk, nv = jax.jit(fn)(ck, cv, k_new, v_new, slot)
    nk, nv = np.array(nk), np.array(nv)
    assert nk[0, 3].sum() == KvH * D and nk[1, 10].sum() == KvH * D
    assert nv[0, 3].sum() == 2 * KvH * D
    nk[0, 3] = nk[1, 10] = 0
    assert nk.sum() == 0


def test_actsharding_disabled_is_identity():
    from repro.distributed import actsharding
    actsharding.disable()
    x = jnp.ones((2, 3, 4))
    assert actsharding.constrain_hidden(x) is x
    assert actsharding.gathered_weight(x) is x


def test_decode_step_with_override_matches_default():
    """decode_step(decode_attn_fn=seq-sharded) == default on 1x1 mesh."""
    from repro.configs.registry import get_reduced_config
    from repro.models import transformer as T
    cfg = get_reduced_config("gemma3-12b")
    params, _ = T.init_model(0, cfg)
    B, S = 2, 40
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab, (B, S)))
    cache = T.init_cache(cfg, B, max_len=S + 8, dtype=jnp.float32)
    _, cache, lengths = T.prefill(params, cfg, tokens[:, :S-1], cache)
    lg_a, _ = T.decode_step(params, cfg, tokens[:, S-1:S], lengths, cache)
    mesh = make_dev_mesh(1, 1)
    with mesh:
        attn = make_seq_sharded_decode_attn(mesh, "data", "model")
        upd = make_seq_sharded_cache_update(mesh, "data", "model")
        lg_b, _ = T.decode_step(params, cfg, tokens[:, S-1:S], lengths,
                                cache, decode_attn_fn=attn,
                                decode_update_fn=upd)
    np.testing.assert_allclose(np.asarray(lg_a), np.asarray(lg_b),
                               rtol=2e-4, atol=2e-4)
