"""Fallback decorators so hypothesis property tests *skip* cleanly instead
of killing collection when the optional dev dependency is missing.

Usage (at the top of a test module):

    try:
        from hypothesis import given, settings, strategies as st
    except ModuleNotFoundError:
        from _hypothesis_stub import given, settings, st

With hypothesis installed (see requirements-dev.txt) the real library is
used and the stub is inert.  Without it, ``@given(...)`` replaces the test
with a zero-argument function that calls ``pytest.skip`` — the rest of the
module still collects and runs.
"""
from __future__ import annotations

import pytest


def given(*_args, **_kwargs):
    def deco(fn):
        def skipper():
            pytest.skip("hypothesis not installed")
        skipper.__name__ = fn.__name__
        skipper.__doc__ = fn.__doc__
        return skipper
    return deco


def settings(*_args, **_kwargs):
    return lambda fn: fn


class _AnyStrategy:
    """Answers every ``st.<name>(...)`` call; values are never drawn."""

    def __getattr__(self, _name):
        return lambda *a, **k: None


st = _AnyStrategy()
