"""Compiled-engine tests: cache hits, donated-carry resumption, vmap batch
equivalence (incl. ragged flow counts + heterogeneous system configs), and
vectorized-stage fidelity."""
import dataclasses

import numpy as np
import pytest

from repro.core import baselines, engine, token_bucket as tb
from repro.core.accelerator import CATALOG, AccelTable
from repro.core.flow import SLO, FlowSet, FlowSpec, Path, TrafficPattern
from repro.core.interconnect import ARB_PRIORITY, LinkSpec
from repro.core.runtime import ArcusRuntime
from repro.core.sim import (SHAPING_HW, SHAPING_NONE, SHAPING_SW, SimConfig,
                            gen_arrivals, gen_stall_mask, simulate,
                            simulate_batch, stack_arrivals)

_COUNTER_KEYS = ("c_adm_msgs", "c_done_msgs", "c_drops")
_EXACT_KEYS = _COUNTER_KEYS + ("c_adm_bytes", "c_done_bytes")


def _assert_results_equal(serial, batch, label=""):
    for k in _EXACT_KEYS:
        assert np.array_equal(serial.counters[k], batch.counters[k]), \
            (label, k, serial.counters[k], batch.counters[k])
    np.testing.assert_array_equal(serial.comp_flow, batch.comp_flow)
    np.testing.assert_array_equal(serial.comp_sz, batch.comp_sz)
    np.testing.assert_allclose(serial.counters["c_lat_sum"],
                               batch.counters["c_lat_sum"], rtol=1e-6)


def _scenario(n_flows=2, n_ticks=15_000, shaping=SHAPING_HW, k_grant=4,
              grant_fast=True, seed=0):
    slos = [10.0 + 5.0 * i for i in range(n_flows)]
    specs = [FlowSpec(i, i, Path.FUNCTION_CALL, 0,
                      TrafficPattern(1024, load=0.8 / n_flows,
                                     process="poisson"), SLO.gbps(s))
             for i, s in enumerate(slos)]
    flows = FlowSet.build(specs)
    cfg = SimConfig(n_ticks=n_ticks, shaping=shaping, k_grant=k_grant,
                    grant_fast=grant_fast)
    arr = gen_arrivals(flows, cfg, seed=seed,
                       load_ref_gbps={i: 55.0 for i in range(n_flows)})
    if shaping == SHAPING_HW:
        tbs = tb.pack([tb.params_for_gbps(s) for s in slos])
    else:
        big = np.full(n_flows, 2**30, np.int32)
        tbs = tb.init(big, big, np.ones(n_flows, np.int32),
                      np.zeros(n_flows, np.int32))
    accels = AccelTable.build([CATALOG["synthetic50"]])
    return flows, accels, LinkSpec(), cfg, tbs, arr


def test_batch_matches_serial_bitwise():
    """simulate_batch over 8 seeds == 8 serial simulate() calls, counter for
    counter (the engine acceptance criterion)."""
    flows, accels, link, cfg, tbs, _ = _scenario(n_ticks=8_000)
    arrs = [gen_arrivals(flows, cfg, seed=s,
                         load_ref_gbps={0: 55.0, 1: 55.0})
            for s in range(8)]
    serial = [simulate(flows, accels, link, cfg, tbs, *a) for a in arrs]
    batch = simulate_batch(flows, accels, link, cfg, [tbs] * 8,
                           *stack_arrivals(arrs))
    assert len(batch) == 8
    for s, b in zip(serial, batch):
        for k in _COUNTER_KEYS + ("c_adm_bytes", "c_done_bytes"):
            assert np.array_equal(s.counters[k], b.counters[k]), k
        np.testing.assert_array_equal(s.comp_flow, b.comp_flow)
        np.testing.assert_array_equal(s.comp_sz, b.comp_sz)
        np.testing.assert_allclose(s.counters["c_lat_sum"],
                                   b.counters["c_lat_sum"], rtol=1e-6)


def test_batch_heterogeneous_registers():
    """Each batch element honours its own TBState registers."""
    flows, accels, link, cfg, _, arr = _scenario(n_ticks=20_000)
    tb_a = tb.pack([tb.params_for_gbps(5.0), tb.params_for_gbps(5.0)])
    tb_b = tb.pack([tb.params_for_gbps(20.0), tb.params_for_gbps(20.0)])
    res = simulate_batch(flows, accels, link, cfg, [tb_a, tb_b],
                         *stack_arrivals([arr, arr]))
    for b, slo in ((0, 5.0), (1, 20.0)):
        got = res[b].mean_ingress_gbps(0, flows)
        assert abs(got - slo) / slo < 0.1, (b, got)


def test_run_managed_compiles_once():
    """10 managed windows (register write each window) hit one engine entry
    with exactly one XLA trace — zero recompiles after window 0."""
    rt = ArcusRuntime([CATALOG["synthetic50"]])
    for i, slo in enumerate((10.0, 20.0)):
        rt.register(FlowSpec(i, i, Path.FUNCTION_CALL, 0,
                             TrafficPattern(1024, load=0.45), SLO.gbps(slo)))
    engine.cache_clear()          # registration profiling uses its own sims
    _, reports = rt.run_managed(total_ticks=30_000, window_ticks=3_000,
                                load_ref_gbps={0: 32.0, 1: 32.0})
    assert len(reports) == 10
    info = engine.cache_info()
    assert info["entries"] == 1, info
    assert info["traces"] == 1, info


def test_live_reconfiguration_cache_hit():
    """A mid-flight register rewrite (new TBState + resumed carry) reuses
    the compiled engine and still changes the shaped rate."""
    flows, accels, link, cfg, _, _ = _scenario(n_flows=1, n_ticks=40_000)
    full = dataclasses.replace(cfg, n_ticks=80_000)
    arr = gen_arrivals(flows, full, load_ref_gbps={0: 50.0})
    engine.cache_clear()
    res1, carry = simulate(flows, accels, link, cfg,
                           tb.pack([tb.params_for_gbps(10)]), *arr,
                           return_carry=True)
    res2 = simulate(flows, accels, link, cfg,
                    tb.pack([tb.params_for_gbps(20)]), *arr,
                    t0_ticks=40_000, carry=carry)
    info = engine.cache_info()
    assert info["entries"] == 1 and info["traces"] == 1, info
    window_s = cfg.n_ticks * cfg.tick_cycles / cfg.clock_hz
    n1 = res1.counters["c_done_msgs"][0]
    n2 = res2.counters["c_done_msgs"][0] - n1
    assert abs(n1 * 1024 * 8 / window_s / 1e9 - 10) < 1.5
    assert abs(n2 * 1024 * 8 / window_s / 1e9 - 20) < 2.0


def test_vectorized_grants_match_sequential():
    """The RR fast path (masked key sort + prefix sums) produces the same
    counters as the sequential argmin loop, shaped and unshaped, at both
    low and high contention."""
    for n_flows, shaping in ((2, SHAPING_HW), (8, SHAPING_HW),
                             (8, SHAPING_NONE)):
        f, a, l, cfg, t, arr = _scenario(n_flows=n_flows, n_ticks=10_000,
                                         shaping=shaping, k_grant=8,
                                         grant_fast=True)
        cfg_seq = dataclasses.replace(cfg, grant_fast=False)
        r_fast = simulate(f, a, l, cfg, t, *arr)
        r_seq = simulate(f, a, l, cfg_seq, t, *arr)
        for k in _COUNTER_KEYS + ("c_adm_bytes", "c_done_bytes"):
            assert np.array_equal(r_fast.counters[k], r_seq.counters[k]), \
                (n_flows, shaping, k)


def _ragged_scenario(n_flows, n_ticks=6_000, seed=None):
    """One batch element with its own flow count / SLOs / registers."""
    specs = [FlowSpec(i, i, Path.FUNCTION_CALL, 0,
                      TrafficPattern(1024, load=0.8 / n_flows,
                                     process="poisson"),
                      SLO.gbps(5.0 + 3.0 * i))
             for i in range(n_flows)]
    flows = FlowSet.build(specs)
    cfg = SimConfig(n_ticks=n_ticks, shaping=SHAPING_HW)
    arr = gen_arrivals(flows, cfg, seed=seed if seed is not None else n_flows,
                       load_ref_gbps={i: 50.0 for i in range(n_flows)})
    tbs = tb.pack([tb.params_for_gbps(5.0 + 3.0 * i)
                   for i in range(n_flows)])
    return flows, cfg, arr, tbs


def test_ragged_batch_matches_serial_bitwise():
    """simulate_batch over FlowSets with DIFFERENT flow counts (padded +
    flow-masked) returns counters bitwise-equal to unpadded serial runs —
    the tentpole acceptance criterion."""
    accels = AccelTable.build([CATALOG["synthetic50"]])
    link = LinkSpec()
    els = [_ragged_scenario(n) for n in (1, 3, 2, 5)]
    serial = [simulate(f, accels, link, c, t, *a) for f, c, a, t in els]
    batch = simulate_batch([f for f, _, _, _ in els], accels, link,
                           els[0][1], [t for _, _, _, t in els],
                           *stack_arrivals([a for _, _, a, _ in els]))
    assert len(batch) == len(els)
    for s, b, (f, *_r) in zip(serial, batch, els):
        assert len(b.counters["c_adm_msgs"]) == f.n   # sliced to unpadded n
        _assert_results_equal(s, b, label=f"n={f.n}")


def test_heterogeneous_system_configs_batch_bitwise():
    """Arcus (HW shaping + RR) and Bypassed_noTS_panic (no shaping +
    priority arbiter) differ only in traced mode words: they run as lanes
    of ONE batched engine call, bitwise-equal to their serial runs."""
    flows, cfg, arr, tbs = _ragged_scenario(2, n_ticks=8_000)
    accels = AccelTable.build([CATALOG["synthetic50"]])
    link = LinkSpec()
    cfg_arcus = cfg
    cfg_panic = dataclasses.replace(cfg, shaping=SHAPING_NONE,
                                    arbiter=ARB_PRIORITY)
    tbs_panic = baselines.make_tb_state(baselines.BYPASSED_NO_TS_PANIC,
                                        [tb.TBParams(1, 1, 1)] * 2)
    s_arcus = simulate(flows, accels, link, cfg_arcus, tbs, *arr)
    s_panic = simulate(flows, accels, link, cfg_panic, tbs_panic, *arr)
    engine.cache_clear()
    batch = simulate_batch(flows, accels, link, [cfg_arcus, cfg_panic],
                           [tbs, tbs_panic], *stack_arrivals([arr, arr]))
    assert engine.cache_info()["entries"] == 1
    _assert_results_equal(s_arcus, batch[0], "arcus")
    _assert_results_equal(s_panic, batch[1], "panic")
    # the two modes really behaved differently (shaped vs free-for-all)
    assert (batch[1].counters["c_done_msgs"].sum()
            > batch[0].counters["c_done_msgs"].sum())


def test_batched_configs_reject_static_mismatch():
    flows, cfg, arr, tbs = _ragged_scenario(2, n_ticks=1_000)
    cfg2 = dataclasses.replace(cfg, k_grant=2)   # structural field differs
    with pytest.raises(ValueError, match="traced fields"):
        simulate_batch(flows, AccelTable.build([CATALOG["synthetic50"]]),
                       LinkSpec(), [cfg, cfg2], [tbs, tbs],
                       *stack_arrivals([arr, arr]))


def _sw_scenario(n_ticks=8_000):
    flows, cfg, arr, _ = _ragged_scenario(2, n_ticks=n_ticks)
    cfg = dataclasses.replace(cfg, shaping=SHAPING_SW)
    tbs = baselines.make_tb_state(baselines.HOST_TS_REFLEX,
                                  [tb.params_for_gbps(5.0),
                                   tb.params_for_gbps(8.0)])
    return flows, cfg, arr, tbs


def test_stall_mask_shared_vs_batched():
    """A shared [T] stall mask applies to every batch element; a [B, T]
    mask applies per element — both match serial runs bitwise (the
    docstring's promise, previously untested)."""
    flows, cfg, arr, tbs = _sw_scenario()
    accels = AccelTable.build([CATALOG["synthetic50"]])
    link = LinkSpec()
    # dense stall process (many events per window) so the two masks
    # observably diverge within a short test run
    m1 = gen_stall_mask(cfg, seed=1, stall_rate_hz=100_000.0,
                        stall_us=(10.0, 60.0))
    m2 = gen_stall_mask(cfg, seed=2, stall_rate_hz=100_000.0,
                        stall_us=(10.0, 60.0))
    assert m1.any() and m2.any() and not np.array_equal(m1, m2)
    s1 = simulate(flows, accels, link, cfg, tbs, *arr, stall_mask=m1)
    s2 = simulate(flows, accels, link, cfg, tbs, *arr, stall_mask=m2)
    # shared [T]: every element sees mask m1
    shared = simulate_batch(flows, accels, link, cfg, [tbs, tbs],
                            *stack_arrivals([arr, arr]), stall_mask=m1)
    _assert_results_equal(s1, shared[0], "shared0")
    _assert_results_equal(s1, shared[1], "shared1")
    # per-element [B, T]
    per_el = simulate_batch(flows, accels, link, cfg, [tbs, tbs],
                            *stack_arrivals([arr, arr]),
                            stall_mask=np.stack([m1, m2]))
    _assert_results_equal(s1, per_el[0], "batched0")
    _assert_results_equal(s2, per_el[1], "batched1")
    # the two masks produced genuinely different dataplanes
    assert not np.array_equal(per_el[0].comp_t_s, per_el[1].comp_t_s)


def test_vectorized_stages_match_sequential():
    """The vectorized accelerator-service + egress stages (prefix-sum slot
    assignment, with the sequential fallback for lane-chaining ticks)
    produce the same counters as the sequential loops — across shaping
    modes and in a chaining-heavy config (service shorter than a tick)."""
    cases = [
        dict(shaping=SHAPING_HW, tick_cycles=8),
        dict(shaping=SHAPING_NONE, tick_cycles=8),
        # tick_cycles=64 >> ~41-cycle service: lanes chain back-to-back
        # within one tick, forcing the sequential fallback path
        dict(shaping=SHAPING_NONE, tick_cycles=64),
        dict(shaping=SHAPING_SW, tick_cycles=8),
    ]
    accels = AccelTable.build([CATALOG["synthetic50"]])
    link = LinkSpec()
    for case in cases:
        n = 2 if case["shaping"] == SHAPING_SW else 4
        flows, cfg, arr, tbs = _ragged_scenario(n, n_ticks=5_000)
        # k_srv=8 (A=1) crosses the service-vectorization width threshold
        cfg = dataclasses.replace(cfg, k_srv=8, k_eg=8, **case)
        if case["shaping"] == SHAPING_SW:
            tbs = baselines.make_tb_state(
                baselines.HOST_TS_REFLEX,
                [tb.params_for_gbps(5.0), tb.params_for_gbps(8.0)])
        cfg_seq = dataclasses.replace(cfg, stage_fast=False)
        r_vec = simulate(flows, accels, link, cfg, tbs, *arr)
        r_seq = simulate(flows, accels, link, cfg_seq, tbs, *arr)
        for k in _EXACT_KEYS:
            assert np.array_equal(r_vec.counters[k], r_seq.counters[k]), \
                (case, k, r_vec.counters[k], r_seq.counters[k])
        np.testing.assert_array_equal(r_vec.comp_flow, r_seq.comp_flow)
        np.testing.assert_array_equal(r_vec.comp_t_s, r_seq.comp_t_s)


def test_distinct_configs_get_distinct_cache_entries():
    flows, accels, link, cfg, tbs, arr = _scenario(n_ticks=2_000)
    engine.cache_clear()
    simulate(flows, accels, link, cfg, tbs, *arr)
    assert engine.cache_info()["entries"] == 1
    cfg2 = dataclasses.replace(cfg, k_grant=2)
    simulate(flows, accels, link, cfg2, tbs, *arr)
    assert engine.cache_info()["entries"] == 2
    # same configs again: no growth
    simulate(flows, accels, link, cfg, tbs, *arr)
    simulate(flows, accels, link, cfg2, tbs, *arr)
    assert engine.cache_info() == {"entries": 2, "traces": 2}


def test_donated_carry_not_reused_by_engine():
    """The caller's TBState survives simulate() (the engine copies register
    arrays into the donated carry instead of aliasing them)."""
    flows, accels, link, cfg, tbs, arr = _scenario(n_ticks=2_000)
    simulate(flows, accels, link, cfg, tbs, *arr)
    # would raise on a deleted (donated) buffer
    assert int(np.asarray(tbs.tokens).sum()) >= 0
    simulate(flows, accels, link, cfg, tbs, *arr)
