"""Compiled-engine tests: cache hits, donated-carry resumption, vmap batch
equivalence, and vectorized-grant fidelity."""
import dataclasses

import numpy as np

from repro.core import engine, token_bucket as tb
from repro.core.accelerator import CATALOG, AccelTable
from repro.core.flow import SLO, FlowSet, FlowSpec, Path, TrafficPattern
from repro.core.interconnect import LinkSpec
from repro.core.runtime import ArcusRuntime
from repro.core.sim import (SHAPING_HW, SHAPING_NONE, SimConfig,
                            gen_arrivals, simulate, simulate_batch,
                            stack_arrivals)

_COUNTER_KEYS = ("c_adm_msgs", "c_done_msgs", "c_drops")


def _scenario(n_flows=2, n_ticks=15_000, shaping=SHAPING_HW, k_grant=4,
              grant_fast=True, seed=0):
    slos = [10.0 + 5.0 * i for i in range(n_flows)]
    specs = [FlowSpec(i, i, Path.FUNCTION_CALL, 0,
                      TrafficPattern(1024, load=0.8 / n_flows,
                                     process="poisson"), SLO.gbps(s))
             for i, s in enumerate(slos)]
    flows = FlowSet.build(specs)
    cfg = SimConfig(n_ticks=n_ticks, shaping=shaping, k_grant=k_grant,
                    grant_fast=grant_fast)
    arr = gen_arrivals(flows, cfg, seed=seed,
                       load_ref_gbps={i: 55.0 for i in range(n_flows)})
    if shaping == SHAPING_HW:
        tbs = tb.pack([tb.params_for_gbps(s) for s in slos])
    else:
        big = np.full(n_flows, 2**30, np.int32)
        tbs = tb.init(big, big, np.ones(n_flows, np.int32),
                      np.zeros(n_flows, np.int32))
    accels = AccelTable.build([CATALOG["synthetic50"]])
    return flows, accels, LinkSpec(), cfg, tbs, arr


def test_batch_matches_serial_bitwise():
    """simulate_batch over 8 seeds == 8 serial simulate() calls, counter for
    counter (the engine acceptance criterion)."""
    flows, accels, link, cfg, tbs, _ = _scenario(n_ticks=8_000)
    arrs = [gen_arrivals(flows, cfg, seed=s,
                         load_ref_gbps={0: 55.0, 1: 55.0})
            for s in range(8)]
    serial = [simulate(flows, accels, link, cfg, tbs, *a) for a in arrs]
    batch = simulate_batch(flows, accels, link, cfg, [tbs] * 8,
                           *stack_arrivals(arrs))
    assert len(batch) == 8
    for s, b in zip(serial, batch):
        for k in _COUNTER_KEYS + ("c_adm_bytes", "c_done_bytes"):
            assert np.array_equal(s.counters[k], b.counters[k]), k
        np.testing.assert_array_equal(s.comp_flow, b.comp_flow)
        np.testing.assert_array_equal(s.comp_sz, b.comp_sz)
        np.testing.assert_allclose(s.counters["c_lat_sum"],
                                   b.counters["c_lat_sum"], rtol=1e-6)


def test_batch_heterogeneous_registers():
    """Each batch element honours its own TBState registers."""
    flows, accels, link, cfg, _, arr = _scenario(n_ticks=20_000)
    tb_a = tb.pack([tb.params_for_gbps(5.0), tb.params_for_gbps(5.0)])
    tb_b = tb.pack([tb.params_for_gbps(20.0), tb.params_for_gbps(20.0)])
    res = simulate_batch(flows, accels, link, cfg, [tb_a, tb_b],
                         *stack_arrivals([arr, arr]))
    for b, slo in ((0, 5.0), (1, 20.0)):
        got = res[b].mean_ingress_gbps(0, flows)
        assert abs(got - slo) / slo < 0.1, (b, got)


def test_run_managed_compiles_once():
    """10 managed windows (register write each window) hit one engine entry
    with exactly one XLA trace — zero recompiles after window 0."""
    rt = ArcusRuntime([CATALOG["synthetic50"]])
    for i, slo in enumerate((10.0, 20.0)):
        rt.register(FlowSpec(i, i, Path.FUNCTION_CALL, 0,
                             TrafficPattern(1024, load=0.45), SLO.gbps(slo)))
    engine.cache_clear()          # registration profiling uses its own sims
    _, reports = rt.run_managed(total_ticks=30_000, window_ticks=3_000,
                                load_ref_gbps={0: 32.0, 1: 32.0})
    assert len(reports) == 10
    info = engine.cache_info()
    assert info["entries"] == 1, info
    assert info["traces"] == 1, info


def test_live_reconfiguration_cache_hit():
    """A mid-flight register rewrite (new TBState + resumed carry) reuses
    the compiled engine and still changes the shaped rate."""
    flows, accels, link, cfg, _, _ = _scenario(n_flows=1, n_ticks=40_000)
    full = dataclasses.replace(cfg, n_ticks=80_000)
    arr = gen_arrivals(flows, full, load_ref_gbps={0: 50.0})
    engine.cache_clear()
    res1, carry = simulate(flows, accels, link, cfg,
                           tb.pack([tb.params_for_gbps(10)]), *arr,
                           return_carry=True)
    res2 = simulate(flows, accels, link, cfg,
                    tb.pack([tb.params_for_gbps(20)]), *arr,
                    t0_ticks=40_000, carry=carry)
    info = engine.cache_info()
    assert info["entries"] == 1 and info["traces"] == 1, info
    window_s = cfg.n_ticks * cfg.tick_cycles / cfg.clock_hz
    n1 = res1.counters["c_done_msgs"][0]
    n2 = res2.counters["c_done_msgs"][0] - n1
    assert abs(n1 * 1024 * 8 / window_s / 1e9 - 10) < 1.5
    assert abs(n2 * 1024 * 8 / window_s / 1e9 - 20) < 2.0


def test_vectorized_grants_match_sequential():
    """The RR fast path (masked key sort + prefix sums) produces the same
    counters as the sequential argmin loop, shaped and unshaped, at both
    low and high contention."""
    for n_flows, shaping in ((2, SHAPING_HW), (8, SHAPING_HW),
                             (8, SHAPING_NONE)):
        f, a, l, cfg, t, arr = _scenario(n_flows=n_flows, n_ticks=10_000,
                                         shaping=shaping, k_grant=8,
                                         grant_fast=True)
        cfg_seq = dataclasses.replace(cfg, grant_fast=False)
        r_fast = simulate(f, a, l, cfg, t, *arr)
        r_seq = simulate(f, a, l, cfg_seq, t, *arr)
        for k in _COUNTER_KEYS + ("c_adm_bytes", "c_done_bytes"):
            assert np.array_equal(r_fast.counters[k], r_seq.counters[k]), \
                (n_flows, shaping, k)


def test_distinct_configs_get_distinct_cache_entries():
    flows, accels, link, cfg, tbs, arr = _scenario(n_ticks=2_000)
    engine.cache_clear()
    simulate(flows, accels, link, cfg, tbs, *arr)
    assert engine.cache_info()["entries"] == 1
    cfg2 = dataclasses.replace(cfg, k_grant=2)
    simulate(flows, accels, link, cfg2, tbs, *arr)
    assert engine.cache_info()["entries"] == 2
    # same configs again: no growth
    simulate(flows, accels, link, cfg, tbs, *arr)
    simulate(flows, accels, link, cfg2, tbs, *arr)
    assert engine.cache_info() == {"entries": 2, "traces": 2}


def test_donated_carry_not_reused_by_engine():
    """The caller's TBState survives simulate() (the engine copies register
    arrays into the donated carry instead of aliasing them)."""
    flows, accels, link, cfg, tbs, arr = _scenario(n_ticks=2_000)
    simulate(flows, accels, link, cfg, tbs, *arr)
    # would raise on a deleted (donated) buffer
    assert int(np.asarray(tbs.tokens).sum()) >= 0
    simulate(flows, accels, link, cfg, tbs, *arr)
