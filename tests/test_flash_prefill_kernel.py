"""Shape/dtype sweep: flash-prefill Pallas kernel vs naive oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_prefill import ops, ref

RNG = np.random.default_rng(3)

CASES = [
    # B, S, H, KvH, D, window, chunk, bq, bk, dtype
    (2, 128, 4, 2, 64, 0, 0, 64, 64, jnp.float32),
    (1, 256, 8, 8, 128, 0, 0, 128, 128, jnp.float32),
    (1, 200, 4, 1, 80, 0, 0, 64, 64, jnp.float32),     # ragged + MQA
    (2, 256, 4, 2, 64, 64, 0, 64, 64, jnp.float32),    # sliding window
    (1, 256, 4, 2, 64, 0, 64, 64, 64, jnp.float32),    # chunked local
    (1, 256, 8, 4, 128, 128, 0, 128, 128, jnp.bfloat16),
]


@pytest.mark.parametrize("case", CASES)
def test_flash_prefill_matches_oracle(case):
    B, S, H, KvH, D, w, ck, bq, bk, dt = case
    q = jnp.asarray(RNG.standard_normal((B, S, H, D)), dt)
    k = jnp.asarray(RNG.standard_normal((B, S, KvH, D)), dt)
    v = jnp.asarray(RNG.standard_normal((B, S, KvH, D)), dt)
    got = ops.flash_prefill(q, k, v, window=w, chunk_size=ck, bq=bq, bk=bk)
    want = ref.flash_prefill(q, k, v, window=w, chunk_size=ck)
    tol = 3e-2 if dt == jnp.bfloat16 else 3e-5
    err = np.max(np.abs(np.asarray(got, np.float32)
                        - np.asarray(want, np.float32)))
    assert err < tol, (case, err)


def test_flash_prefill_matches_model_flash():
    """The kernel agrees with the model's jnp flash implementation."""
    from repro.models import layers as L
    B, S, H, KvH, D = 1, 192, 4, 2, 64
    q = jnp.asarray(RNG.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, KvH, D)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, KvH, D)), jnp.float32)
    got = ops.flash_prefill(q, k, v, bq=64, bk=64)
    want = L.flash_attention(q, k, v, mask_kind="causal", kv_chunk=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)
