"""End-to-end behaviour tests: the paper's headline claims in miniature.

Full-scale numbers live in benchmarks/ + EXPERIMENTS.md; these assert the
*direction and mechanism* of each claim quickly enough for CI.
"""

import numpy as np

from repro.core import baselines, token_bucket as tb
from repro.core.accelerator import CATALOG, AccelTable
from repro.core.flow import SLO, FlowSet, FlowSpec, Path, TrafficPattern
from repro.core.interconnect import LinkSpec
from repro.core.sim import gen_arrivals, simulate


def _fig6_mini(sys_name: str, load_x: float = 1.5, n_ticks: int = 50_000):
    sys_cfg = baselines.ALL[sys_name]
    nvme = CATALOG["nvme_raid0"]
    slo1, slo2 = 300e3, 200e3
    specs = [
        FlowSpec(0, 0, Path.FUNCTION_CALL, 0,
                 TrafficPattern(4096, rate_mps=slo1 * load_x,
                                process="poisson"), SLO.iops(slo1)),
        FlowSpec(1, 1, Path.FUNCTION_CALL, 0,
                 TrafficPattern(4096, rate_mps=slo2 * load_x,
                                process="poisson"), SLO.iops(slo2)),
    ]
    flows = FlowSet.build(specs)
    cfg = baselines.make_sim_config(sys_cfg, n_ticks, tick_cycles=64,
                                    comp_cap=1 << 16, k_grant=8, k_srv=8,
                                    k_eg=8, qlen=512, lmax=64)
    arr = gen_arrivals(flows, cfg, seed=3)
    plans = [tb.params_for_iops(slo1), tb.params_for_iops(slo2)]
    tbs = baselines.make_tb_state(sys_cfg, plans)
    stall = baselines.make_stall_mask(sys_cfg, cfg)
    res = simulate(flows, AccelTable.build([nvme]), LinkSpec(credits=256),
                   cfg, tbs, *arr, stall_mask=stall)
    return res


def test_claim_arcus_slo_accuracy():
    """Arcus holds both users within ~2% of 300K/200K IOPS."""
    res = _fig6_mini("Arcus")
    warm = 0.2 * res.seconds
    r1 = res.mean_rate(0, "iops", warmup_s=warm)
    r2 = res.mean_rate(1, "iops", warmup_s=warm)
    assert abs(r1 - 300e3) / 300e3 < 0.02
    assert abs(r2 - 200e3) / 200e3 < 0.02


def test_claim_tail_latency_reduction():
    """Arcus cuts 99.9th% latency vs software shaping (paper: up to 45%)."""
    arcus = _fig6_mini("Arcus", load_x=0.9)
    reflex = _fig6_mini("Host_TS_reflex", load_x=0.9)
    la = arcus.latency_percentiles(0, (99.9,))[99.9]
    lr = reflex.latency_percentiles(0, (99.9,))[99.9]
    assert la < lr, (la, lr)
    assert 1 - la / lr > 0.2   # at least 20% reduction in miniature


def test_claim_throughput_variance():
    """Arcus per-window throughput variance is far below software shaping
    (paper: <1% vs 6.5-24.3%)."""
    arcus = _fig6_mini("Arcus")
    fc = _fig6_mini("Host_TS_firecracker")
    wa = arcus.throughput_samples(0, 500, "iops",
                                  warmup_s=0.2 * arcus.seconds)
    wf = fc.throughput_samples(0, 500, "iops", warmup_s=0.2 * fc.seconds)
    cv_a = wa.std() / wa.mean()
    cv_f = wf.std() / wf.mean()
    assert cv_a < 0.02
    assert cv_f > 2 * cv_a


def test_claim_use_case2_tiny_messages():
    """Shaping the MTU stream protects the 64B flow's tail latency (both
    systems run as one batched engine call)."""
    from benchmarks.fig9_bursty_tiny import run_systems
    out = run_systems(("Arcus", "Bypassed_noTS_panic"), 50_000)
    arcus, bypassed = out["Arcus"], out["Bypassed_noTS_panic"]
    assert arcus["vm1_p99_us"] < bypassed["vm1_p99_us"] / 1.9
    assert abs(arcus["vm2_gbps"] - 32.0) < 3.0


def test_claim_heterogeneity_r_ratios():
    """Egress/ingress ratio classes (Sec 2.2) behave as specified."""
    m = np.array([4096.0])
    assert CATALOG["aes256"].egress_bytes(m)[0] == 4096          # R = 1
    assert CATALOG["decompress"].egress_bytes(m)[0] > 4096       # R > 1
    assert CATALOG["compress"].egress_bytes(m)[0] < 4096         # R < 1
    assert CATALOG["sha3_512"].egress_bytes(m)[0] == 64          # fixed


def test_dryrun_lowering_machinery_tiny_mesh():
    """The dry-run's sharding resolution lowers on a 1x1 dev mesh with a
    reduced config (the 512-device run is exercised by launch/dryrun.py)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.registry import get_reduced_config
    from repro.distributed import sharding as SH
    from repro.launch.mesh import make_dev_mesh
    from repro.models import transformer as T

    cfg = get_reduced_config("mixtral-8x22b")
    mesh = make_dev_mesh(1, 1)
    rules = SH.rules_for_config(cfg)
    axes = T.init_model_axes(cfg)
    pshapes = jax.eval_shape(
        lambda: T.init_model_params_only(0, cfg, dtype=jnp.float32))
    pshard = SH.param_shardings(axes, pshapes, mesh, rules)
    cspecs = jax.eval_shape(
        lambda: T.init_cache(cfg, 4, 64, jnp.float32))
    cshard = SH.cache_shardings(cspecs, mesh, cfg)
    with mesh:
        fn = jax.jit(
            lambda p, t, l, c: T.decode_step(p, cfg, t, l, c),
            in_shardings=(pshard, NamedSharding(mesh, P()),
                          NamedSharding(mesh, P()), cshard),
            out_shardings=(None, cshard))
        lowered = fn.lower(pshapes,
                           jax.ShapeDtypeStruct((4, 1), jnp.int32),
                           jax.ShapeDtypeStruct((4,), jnp.int32), cspecs)
        compiled = lowered.compile()
        assert compiled.cost_analysis() is not None
