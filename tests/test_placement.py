"""Fleet admission placement: policy decisions, per-server parity,
determinism — plus the control-plane clock and rebuild-skip regressions
fixed alongside the placement subsystem.

The parity bar mirrors the rest of the fleet layer: placement must never
*change* a per-server decision, only widen the set of servers a tenant may
land on — pinned first-fit IS ``register_fleet``, bitwise."""
import dataclasses

import numpy as np
import pytest

from repro.core import placement, token_bucket as tb
from repro.core.accelerator import CATALOG
from repro.core.flow import SLO, FlowSpec, Path, TrafficPattern
from repro.core.profiler import (CapacityEntry, ProfileTable,
                                 profiling_stats)
from repro.core.runtime import (ArcusRuntime, place_fleet, register_fleet,
                                run_managed_batch)

_PROFILE_TICKS = 6_000


def _spec(fid, slo_gbps, accel_id=0, msg=1024, load=0.5):
    return FlowSpec(fid, fid, Path.FUNCTION_CALL, accel_id,
                    TrafficPattern(msg, load=load, process="poisson"),
                    SLO.gbps(slo_gbps))


def _mk_fleet(complements, profile=None):
    profile = profile or ProfileTable(n_ticks=_PROFILE_TICKS)
    return [ArcusRuntime([CATALOG[n] for n in names],
                         profile_table=profile)
            for names in complements]


# ---------------------------------------------------------------------------
# CapacityEntry margin / residual queries
# ---------------------------------------------------------------------------


def test_slo_margin_sign_matches_slo_tag():
    """slo_margin >= 0 must agree with slo_tag for every query shape:
    positional (len match), aggregate-style, and degenerate entries."""
    rng = np.random.default_rng(7)
    for _ in range(300):
        n = int(rng.integers(1, 5))
        per = [float(x) for x in rng.uniform(0.0, 20.0, n)]
        e = CapacityEntry(float(rng.uniform(1.0, 60.0)), per, 1.0)
        k = n if rng.random() < 0.7 else int(rng.integers(1, 4))
        slo = [float(x) for x in rng.uniform(0.0, 50.0, k)]
        assert (e.slo_margin(slo) >= 0) == e.slo_tag(slo), (e, slo)
    # residual is the aggregate headroom slo_tag's first clause checks
    e = CapacityEntry(50.0, [25.0, 25.0], 1.0)
    assert e.residual_gbps([10.0, 20.0]) == pytest.approx(50.0 * 0.98 - 30)
    assert e.residual_gbps([50.0, 20.0]) < 0


# ---------------------------------------------------------------------------
# Parity: pinned first-fit == register_fleet, bitwise
# ---------------------------------------------------------------------------

_COMPLEMENTS = (["ipsec32"],
                ["ipsec32", "synthetic50"],
                ["synthetic50", "aes256", "ipsec32"])

#: per-server admission streams including rejections (ipsec32 profiles to
#: ~31 Gbps at 1500B: servers 0 and 2 each oversubscribe their ipsec32)
_FLEET_SLOS = ([(0, 10.0, 0), (1, 20.0, 0), (2, 10.0, 0)],
               [(3, 5.0, 0)],
               [(4, 12.0, 2), (5, 12.0, 2), (6, 12.0, 2)])


def _fleet_specs():
    return [[_spec(fid, s, accel_id=a, msg=1500, load=0.9)
             for fid, s, a in slos]
            for slos in _FLEET_SLOS]


def test_first_fit_pinned_reproduces_register_fleet():
    """place_fleet(FirstFit, pinned to each spec's original server) must
    reproduce register_fleet's accept/reject decisions exactly — mixed
    accel-count fleet, including rejections (the acceptance contract)."""
    base = register_fleet(_mk_fleet(_COMPLEMENTS), _fleet_specs())
    rts = _mk_fleet(_COMPLEMENTS)
    flat, pins = [], []
    for b, server_specs in enumerate(_fleet_specs()):
        flat.extend(server_specs)
        pins.extend([b] * len(server_specs))
    placed = place_fleet(rts, flat, policy=placement.FirstFit(),
                         pinned=pins)
    got = [[] for _ in _COMPLEMENTS]
    for p, b in zip(placed, pins):
        got[b].append(p.accepted)
        assert p.server == (b if p.accepted else None)
    assert got == base
    # the rejections really happened
    assert base[0] == [True, True, False]
    assert base[2] == [True, True, False]


def test_place_fleet_relocates_what_per_server_rejects():
    """The motivating scenario: a tenant stream pinned per-server dies on
    a loaded server while siblings idle; unpinned placement relocates it
    (and profiles each round's fleet-wide candidate set through ONE
    batched profiling call)."""
    profile = ProfileTable(n_ticks=_PROFILE_TICKS)
    comps = (["synthetic50"], ["synthetic50"], ["synthetic50"])
    specs = [_spec(i, 9.0) for i in range(8)]
    pinned = register_fleet(_mk_fleet(comps, profile),
                            [specs, [], []])[0]
    assert not all(pinned)                   # server 0 alone cannot host 8

    rts = _mk_fleet(comps, profile)
    before = profiling_stats()
    placed = place_fleet(rts, specs, policy=placement.SLOAware(),
                         accel_names=["synthetic50"] * len(specs))
    after = profiling_stats()
    assert all(p.accepted for p in placed)   # the fleet as a whole fits
    assert sum(placed[i].accepted for i in range(8)) > sum(pinned)
    # one profile_contexts_multi call per admission round
    assert after["calls"] - before["calls"] == len(specs)
    # every server ended up with at least one tenant (spreading happened)
    assert all(rt.table for rt in rts)


def test_place_fleet_rejects_only_when_no_server_fits():
    rts = _mk_fleet((["ipsec32"], ["ipsec32"]))
    big = _spec(0, 100.0, msg=1500, load=0.9)    # > any profiled capacity
    ok = _spec(1, 5.0, msg=1500, load=0.9)
    placed = place_fleet(rts, [big, ok], policy=placement.BestFit())
    assert not placed[0].accepted and placed[0].server is None
    assert placed[0].n_feasible == 0 and placed[0].n_candidates == 2
    assert placed[1].accepted                     # later rounds unaffected
    assert sum(len(rt.table) for rt in rts) == 1


def test_place_fleet_name_matching_rebinds_accel_id():
    """accel_names placement must rebind the spec to the matching accel's
    index on the landing server, wherever it sits in the complement."""
    rts = _mk_fleet((["aes256"], ["aes256", "synthetic50"]))
    placed = place_fleet(rts, [_spec(0, 9.0)],
                         policy=placement.FirstFit(),
                         accel_names=["synthetic50"])
    p = placed[0]
    assert p.accepted and p.server == 1 and p.accel_id == 1
    assert rts[1].table[0].spec.accel_id == 1
    # no server carries the name at all -> rejected with zero candidates
    none = place_fleet(rts, [_spec(1, 1.0)], accel_names=["nvme_raid0"])
    assert not none[0].accepted and none[0].n_candidates == 0


def test_slo_aware_deterministic_under_permuted_server_order():
    """SLO-aware scoring ties break on the canonical server key, so a
    permuted runtimes sequence places every tenant on the same physical
    server (mixed accel counts; several exact margin ties)."""
    comps = (["synthetic50"],
             ["synthetic50", "aes256"],
             ["aes256", "synthetic50", "ipsec32"],
             ["synthetic50", "ipsec32"])
    perm = [2, 0, 3, 1]
    specs = [_spec(i, 8.0) for i in range(6)]
    names = ["synthetic50"] * len(specs)
    profile = ProfileTable(n_ticks=_PROFILE_TICKS)

    rts_a = _mk_fleet(comps, profile)
    placed_a = place_fleet(rts_a, specs, policy=placement.SLOAware(),
                           accel_names=names)
    rts_b = _mk_fleet([comps[i] for i in perm], profile)
    placed_b = place_fleet(rts_b, specs, policy=placement.SLOAware(),
                           accel_names=names)
    for pa, pb in zip(placed_a, placed_b):
        assert pa.accepted and pb.accepted
        # same physical server: position b in the permuted fleet hosts
        # original server perm[b]
        assert perm[pb.server] == pa.server, (pa, pb)
        assert pa.accel_id is not None


def test_slo_aware_lands_on_most_headroom():
    """A loaded server and an idle twin: SLO-aware must pick the idle one
    (margin), while pinned first-fit would have stacked the loaded one."""
    profile = ProfileTable(n_ticks=_PROFILE_TICKS)
    rts = _mk_fleet((["synthetic50"], ["synthetic50"]), profile)
    assert rts[0].register(_spec(100, 20.0))
    placed = place_fleet(rts, [_spec(0, 9.0)],
                         policy=placement.SLOAware(),
                         accel_names=["synthetic50"])
    assert placed[0].server == 1


def test_place_fleet_validates_arguments():
    rts = _mk_fleet((["ipsec32"],))
    with pytest.raises(ValueError, match="one entry per spec"):
        place_fleet(rts, [_spec(0, 1.0)], pinned=[0, 1])
    with pytest.raises(ValueError, match="out of range"):
        place_fleet(rts, [_spec(0, 1.0)], pinned=[3])
    assert not rts[0].table                  # nothing was registered


# ---------------------------------------------------------------------------
# register_fleet argument validation (satellite)
# ---------------------------------------------------------------------------


def test_register_fleet_validates_before_any_work():
    rts = _mk_fleet(_COMPLEMENTS)
    with pytest.raises(ValueError, match="one spec list per server"):
        register_fleet(rts, [[_spec(0, 1.0)]])   # 1 list, 3 servers
    assert all(not rt.table for rt in rts)       # rejected up front
    assert all(not rt.profile.entries for rt in rts)


def test_register_fleet_allows_empty_server_list():
    rts = _mk_fleet(_COMPLEMENTS)
    out = register_fleet(rts, [[_spec(0, 5.0)], [], [_spec(1, 5.0)]])
    assert out[0] == [True] and out[1] == [] and out[2] == [True]
    assert not rts[1].table


# ---------------------------------------------------------------------------
# Control-plane clock threading (satellite)
# ---------------------------------------------------------------------------


def _clock_runtime(clock_hz, profile):
    rt = ArcusRuntime([CATALOG["synthetic50"]], profile_table=profile,
                      clock_hz=clock_hz)
    assert rt.register(_spec(0, 10.0))
    return rt


def test_run_managed_threads_runtime_clock_into_windows():
    """A runtime built with clock_hz=500e6 must run its dataplane, window
    measurement AND report timestamps on that clock (regression: the
    window SimConfig silently kept the 250 MHz default, skewing every
    measured rate by the clock ratio)."""
    profile = ProfileTable(n_ticks=4_000)
    rt = _clock_runtime(500e6, profile)
    res, reports = rt.run_managed(total_ticks=4_000, window_ticks=4_000,
                                  load_ref_gbps={0: 32.0})
    window_s = 4_000 * 8 / 500e6
    assert res.seconds == pytest.approx(window_s)
    assert reports[0].t_end_s == pytest.approx(window_s)
    # measured rate and timestamps now agree on ONE clock: the report's
    # Gbps is exactly the counter delta over the dataplane window
    want = float(res.counters["c_done_bytes"][0]) * 8 / res.seconds / 1e9
    assert reports[0].measured[0] == pytest.approx(want, rel=1e-12)

    # fleet path: bitwise-equal to the serial run at the same clock
    rt_b = _clock_runtime(500e6, profile)
    res_b, rep_b = run_managed_batch([rt_b], total_ticks=4_000,
                                     window_ticks=4_000,
                                     load_ref_gbps=[{0: 32.0}])
    assert res_b[0].seconds == res.seconds
    assert rep_b[0][0].t_end_s == reports[0].t_end_s
    assert rep_b[0][0].measured == reports[0].measured
    np.testing.assert_array_equal(res.counters["c_done_bytes"],
                                  res_b[0].counters["c_done_bytes"])


def test_run_managed_sim_kwargs_clock_override_wins():
    """An explicit sim_kwargs clock_hz beats the runtime clock (the
    documented escape hatch)."""
    profile = ProfileTable(n_ticks=4_000)
    rt = _clock_runtime(500e6, profile)
    res, _ = rt.run_managed(total_ticks=4_000, window_ticks=4_000,
                            load_ref_gbps={0: 32.0},
                            sim_kwargs={"clock_hz": 250e6})
    assert res.seconds == pytest.approx(4_000 * 8 / 250e6)


# ---------------------------------------------------------------------------
# Per-window rebuild skip (satellite)
# ---------------------------------------------------------------------------


def _rebuild_fleet(profile):
    """Two servers: server 0's 25 Gbps SLO is starved (violations, so
    reconfigs keep it dirty); server 1 comfortably meets 5 Gbps (clean
    after window 1 — its re-packs must be skipped)."""
    rts = _mk_fleet((["synthetic50"], ["synthetic50"]), profile)
    assert rts[0].register(_spec(0, 25.0, load=0.3))
    assert rts[1].register(_spec(1, 5.0, load=0.5))
    return rts


def test_fleet_window_rebuild_skipped_for_clean_servers(monkeypatch):
    """Servers whose window reported no reconfigured/path_changes must not
    re-pack registers or rebuild FlowSets — with counters, reports and
    control state bitwise-identical to the always-rebuild path."""
    profile = ProfileTable(n_ticks=4_000)
    kwargs = dict(total_ticks=16_000, window_ticks=4_000, seeds=[1, 2],
                  load_ref_gbps=[{0: 32.0}, {0: 32.0}])
    rts_f = _rebuild_fleet(profile)
    res_f, rep_f = run_managed_batch(rts_f, _force_rebuild=True, **kwargs)

    packs = []
    real_pack = tb.pack
    monkeypatch.setattr(tb, "pack", lambda ps: packs.append(1) or
                        real_pack(ps))
    rts_s = _rebuild_fleet(profile)
    res_s, rep_s = run_managed_batch(rts_s, **kwargs)
    # window 0 packs both servers; afterwards a server re-packs exactly
    # once per window that follows one of its reconfiguring windows —
    # strictly fewer than the 2 servers x 4 windows of the forced path
    want_packs = 2 + sum(
        bool(w.reconfigured or w.path_changes)
        for rep in rep_s for w in rep[:-1])
    assert len(packs) == want_packs < 8, (len(packs), want_packs)

    for b in range(2):
        assert len(rep_f[b]) == len(rep_s[b]) == 4
        for wf, ws in zip(rep_f[b], rep_s[b]):
            assert wf.measured == ws.measured
            assert wf.violated == ws.violated
            assert wf.reconfigured == ws.reconfigured
            assert wf.path_changes == ws.path_changes
        for k in ("c_adm_msgs", "c_done_msgs", "c_drops", "c_adm_bytes",
                  "c_done_bytes"):
            np.testing.assert_array_equal(res_f[b].counters[k],
                                          res_s[b].counters[k])
        for fid in rts_f[b].table:
            assert rts_f[b].table[fid].params == rts_s[b].table[fid].params
            assert (rts_f[b].table[fid].violations
                    == rts_s[b].table[fid].violations)
    # the starved flow really did reconfigure (the dirty path was hit)
    assert any(w.reconfigured for w in rep_s[0])


def test_fleet_all_clean_windows_skip_register_writes(monkeypatch):
    """A fleet with zero violations resumes every later window without any
    register rewrite (tb_states=None fast path), still bitwise-equal to
    the forced-rebuild run."""
    profile = ProfileTable(n_ticks=4_000)

    def mk():
        rts = _mk_fleet((["synthetic50"], ["synthetic50"]), profile)
        assert rts[0].register(_spec(0, 3.0, load=0.5))
        assert rts[1].register(_spec(1, 3.0, load=0.5))
        return rts

    kwargs = dict(total_ticks=12_000, window_ticks=4_000, seeds=[1, 2],
                  load_ref_gbps=[{0: 32.0}, {0: 32.0}])
    res_f, rep_f = run_managed_batch(mk(), _force_rebuild=True, **kwargs)
    packs = []
    real_pack = tb.pack
    monkeypatch.setattr(tb, "pack", lambda ps: packs.append(1) or
                        real_pack(ps))
    res_s, rep_s = run_managed_batch(mk(), **kwargs)
    assert len(packs) == 2                    # window 0 only
    assert all(not w.reconfigured for rep in rep_s for w in rep)
    for b in range(2):
        for wf, ws in zip(rep_f[b], rep_s[b]):
            assert wf.measured == ws.measured
        for k in ("c_adm_msgs", "c_done_msgs", "c_done_bytes"):
            np.testing.assert_array_equal(res_f[b].counters[k],
                                          res_s[b].counters[k])


# ---------------------------------------------------------------------------
# Policy selection unit behavior (no profiling needed)
# ---------------------------------------------------------------------------


def _cand(server, margin, residual, feasible=True, key=None):
    return placement.Candidate(
        server=server, accel_id=0,
        spec=_spec(0, 1.0), entry=CapacityEntry(50.0, [50.0], 1.0),
        slo_gbps=(1.0,), feasible=feasible, margin=margin,
        residual=residual, server_key=key or (("x",), ()))


def test_policy_selection_rules():
    cands = [_cand(0, margin=0.1, residual=5.0),
             _cand(1, margin=0.6, residual=20.0),
             _cand(2, margin=0.3, residual=1.0),
             _cand(3, margin=0.9, residual=30.0, feasible=False)]
    assert placement.FirstFit().select(cands).server == 0
    assert placement.BestFit().select(cands).server == 2    # min residual
    assert placement.SLOAware().select(cands).server == 1   # max margin
    infeasible = [dataclasses.replace(c, feasible=False) for c in cands]
    for pol in (placement.FirstFit(), placement.BestFit(),
                placement.SLOAware()):
        assert pol.select(infeasible) is None
    # exact ties resolve by canonical server key, not list position
    tied = [_cand(0, 0.5, 9.0, key=(("b",), ())),
            _cand(1, 0.5, 9.0, key=(("a",), ()))]
    assert placement.SLOAware().select(tied).server == 1
    assert placement.BestFit().select(tied).server == 1
