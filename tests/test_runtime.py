"""Control-plane tests: Algorithm 1, profiler, admission, policies."""
import numpy as np
import pytest

from repro.core import engine, policies, token_bucket as tb
from repro.core.accelerator import CATALOG
from repro.core.flow import SLO, FlowSpec, Path, TrafficPattern
from repro.core.profiler import CapacityEntry, ProfileTable, context_key
from repro.core.runtime import ArcusRuntime
from repro.core.shaper import reshape_decision


def _spec(fid, slo_gbps, msg=1500, load=0.9):
    return FlowSpec(fid, fid, Path.FUNCTION_CALL, 0,
                    TrafficPattern(msg, load=load), SLO.gbps(slo_gbps))


def test_admission_control_accepts_then_rejects():
    rt = ArcusRuntime([CATALOG["ipsec32"]])
    assert rt.register(_spec(0, 10.0))
    assert rt.register(_spec(1, 20.0))
    assert not rt.register(_spec(2, 10.0))   # 40 > profiled ~31 Gbps
    assert len(rt.table) == 2


def test_managed_run_meets_slos():
    rt = ArcusRuntime([CATALOG["ipsec32"]])
    rt.register(_spec(0, 10.0))
    rt.register(_spec(1, 20.0))
    _, reports = rt.run_managed(total_ticks=90_000, window_ticks=30_000,
                                load_ref_gbps={0: 32.0, 1: 32.0})
    last = reports[-1]
    assert abs(last.measured[0] - 10.0) < 0.5
    assert abs(last.measured[1] - 20.0) < 1.0
    assert not last.violated


def test_profile_table_cache_and_serialization(tmp_path):
    pt = ProfileTable(n_ticks=20_000)
    ctx = [(Path.FUNCTION_CALL, 1500, 0.9)] * 2
    e1 = pt.profile_context(CATALOG["ipsec32"], ctx)
    e2 = pt.profile_context(CATALOG["ipsec32"], ctx)   # cached
    assert e1 is e2
    p = tmp_path / "profile.json"
    pt.to_json(str(p))
    pt2 = ProfileTable.from_json(str(p))
    k = context_key("ipsec32", ctx)
    assert abs(pt2.entries[k].capacity_gbps - e1.capacity_gbps) < 1e-6


def test_profiler_small_messages_collapse_capacity():
    pt = ProfileTable(n_ticks=20_000)
    big = pt.profile_context(CATALOG["ipsec32"],
                             [(Path.FUNCTION_CALL, 1500, 0.9)] * 2)
    small = pt.profile_context(CATALOG["ipsec32"],
                               [(Path.FUNCTION_CALL, 64, 0.9)] * 2)
    # Fig 3b: tiny-message mixtures deliver ~18-32% of peak
    assert small.capacity_gbps < 0.4 * big.capacity_gbps


def test_slo_tag_friendly_vs_violating():
    pt = ProfileTable(n_ticks=20_000)
    e = pt.profile_context(CATALOG["ipsec32"],
                           [(Path.FUNCTION_CALL, 1500, 0.9)] * 2)
    half = e.capacity_gbps / 2
    assert e.slo_tag([0.9 * half, 0.9 * half])
    assert not e.slo_tag([1.2 * half, 1.2 * half])


def test_slo_tag_rejects_oversized_per_flow_slo():
    """An SLO exceeding what contention lets ONE flow reach must tag
    SLO-Violating even when the aggregate fits the profiled capacity
    (regression: only the total used to be checked)."""
    # heterogeneous context in canonical order: the 64B flow first
    # (bucket 6), then the 1500B flow (bucket 10-11)
    e = CapacityEntry(27.0, [2.0, 25.0], fairness=0.6)
    # oversized SLO on the small-message flow: ceiling = 2 flows x 2 Gbps
    assert not e.slo_tag([10.0, 5.0])
    # same totals, but the big SLO rides on the big-message flow: friendly
    assert e.slo_tag([3.0, 12.0])
    # aggregate-style query (SLO count != profiled flow count) is bounded
    # by the best single-flow ceiling: here 2 flows x 3 Gbps
    e2 = CapacityEntry(27.0, [2.0, 3.0], fairness=0.9)
    assert e2.slo_tag([5.0])
    assert not e2.slo_tag([10.0])


def test_profile_contexts_batch_matches_serial():
    """profile_contexts pads heterogeneous contexts (ragged flow counts,
    mixed accelerators) into ONE compiled engine call and produces entries
    bitwise-identical to serial profile_context runs."""
    ctxs = [
        (CATALOG["ipsec32"], [(Path.FUNCTION_CALL, 64, 0.9)]),
        (CATALOG["ipsec32"], [(Path.FUNCTION_CALL, 1500, 0.9)] * 2),
        (CATALOG["synthetic50"], [(Path.FUNCTION_CALL, 512, 0.9)] * 3),
        (CATALOG["aes256"], [(Path.FUNCTION_CALL, 1024, 0.9),
                             (Path.FUNCTION_CALL, 64, 0.9)]),
    ]
    serial = ProfileTable(n_ticks=8_000)
    s_entries = [serial.profile_context(a, f) for a, f in ctxs]
    batched = ProfileTable(n_ticks=8_000)
    engine.cache_clear()
    b_entries = batched.profile_contexts(ctxs)
    assert engine.cache_info() == {"entries": 1, "traces": 1}
    for s, b in zip(s_entries, b_entries):
        assert s.capacity_gbps == b.capacity_gbps, s.ctx
        assert s.per_flow_gbps == b.per_flow_gbps, s.ctx
    # cache-hit path: re-querying (plus a permuted duplicate) simulates
    # nothing and returns the same entries
    before = engine.cache_info()
    again = batched.profile_contexts(ctxs + [
        (CATALOG["aes256"], [(Path.FUNCTION_CALL, 64, 0.9),
                             (Path.FUNCTION_CALL, 1024, 0.9)])])
    assert engine.cache_info() == before
    assert again[4] is b_entries[3]     # permuted context, same entry


def test_run_managed_partial_trailing_window():
    """total_ticks % window_ticks != 0 must run the remainder as a final
    short window, not silently drop it (regression)."""
    rt = ArcusRuntime([CATALOG["synthetic50"]])
    rt.register(_spec(0, 10.0, msg=1024))
    res_full, rep_full = rt.run_managed(total_ticks=40_000,
                                        window_ticks=15_000,
                                        load_ref_gbps={0: 32.0})
    # 2 full windows + one 10_000-tick remainder window
    assert len(rep_full) == 3
    window_s = 15_000 * 8 / rt.clock_hz
    assert rep_full[-1].t_end_s == pytest.approx(40_000 * 8 / rt.clock_hz)
    assert rep_full[1].t_end_s == pytest.approx(2 * window_s)
    # the tail was actually simulated: more completions than at 30k ticks
    rt2 = ArcusRuntime([CATALOG["synthetic50"]])
    rt2.register(_spec(0, 10.0, msg=1024))
    res_trunc, _ = rt2.run_managed(total_ticks=30_000, window_ticks=15_000,
                                   load_ref_gbps={0: 32.0})
    assert (res_full.counters["c_done_msgs"][0]
            > res_trunc.counters["c_done_msgs"][0])


def test_reshape_decision_heterogeneity():
    # compression: SLO on input stream -> ingress == SLO
    d = reshape_decision(CATALOG["compress"], SLO.gbps(5.0), 16384)
    assert d.params.mode == tb.MODE_GBPS
    # decompression (R>1): deliverable is expanded output -> ingress < SLO
    d2 = reshape_decision(CATALOG["decompress"], SLO.gbps(5.0), 16384)
    assert tb.achieved_rate(d2.params) * 8 / 1e9 < 5.0
    # giant messages get split
    d3 = reshape_decision(CATALOG["aes256"], SLO.gbps(5.0), 512 * 1024)
    assert d3.resize_to is not None and d3.resize_to < 512 * 1024


def test_policies():
    r = policies.plan_reserved(SLO.gbps(8.0))
    o = policies.plan_on_demand(SLO.gbps(8.0))
    b = policies.plan_managed_burst(SLO.gbps(8.0), burst_x=10.0)
    opp = policies.plan_opportunistic()
    assert r.admission_guaranteed and not o.admission_guaranteed
    assert b.params.bkt_size > r.params.bkt_size        # burst budget
    assert b.capacity_debit_gbps == pytest.approx(80.0)  # debit the burst
    assert opp.capacity_debit_gbps == 0.0 and opp.weight < 0.1


def test_path_selection_moves_saturated_flow():
    """A flow on a saturated ingress direction moves to an alternate path."""
    rt = ArcusRuntime([CATALOG["synthetic50"]],
                      alt_paths={0: [Path.INLINE_NIC_RX]})
    # saturate h2d: two big function-call flows
    assert rt.register(_spec(0, 20.0, msg=4096))
    st = rt.table[0]
    cur = {"c_adm_bytes": np.array([7e9]), "c_done_bytes": np.array([7e9]),
           "c_adm_msgs": np.array([1]), "c_done_msgs": np.array([1]),
           "c_drops": np.array([0]), "c_lat_sum": np.array([0.0])}
    prev = {k: np.zeros_like(v) for k, v in cur.items()}
    newp = rt._path_selection(st, cur, prev, window_s=1.0)
    assert newp == Path.INLINE_NIC_RX
