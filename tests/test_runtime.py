"""Control-plane tests: Algorithm 1, profiler, admission, policies."""
import numpy as np
import pytest

from repro.core import policies, token_bucket as tb
from repro.core.accelerator import CATALOG
from repro.core.flow import SLO, FlowSpec, Path, SLOKind, TrafficPattern
from repro.core.profiler import ProfileTable, context_key
from repro.core.runtime import ArcusRuntime
from repro.core.shaper import reshape_decision


def _spec(fid, slo_gbps, msg=1500, load=0.9):
    return FlowSpec(fid, fid, Path.FUNCTION_CALL, 0,
                    TrafficPattern(msg, load=load), SLO.gbps(slo_gbps))


def test_admission_control_accepts_then_rejects():
    rt = ArcusRuntime([CATALOG["ipsec32"]])
    assert rt.register(_spec(0, 10.0))
    assert rt.register(_spec(1, 20.0))
    assert not rt.register(_spec(2, 10.0))   # 40 > profiled ~31 Gbps
    assert len(rt.table) == 2


def test_managed_run_meets_slos():
    rt = ArcusRuntime([CATALOG["ipsec32"]])
    rt.register(_spec(0, 10.0))
    rt.register(_spec(1, 20.0))
    _, reports = rt.run_managed(total_ticks=90_000, window_ticks=30_000,
                                load_ref_gbps={0: 32.0, 1: 32.0})
    last = reports[-1]
    assert abs(last.measured[0] - 10.0) < 0.5
    assert abs(last.measured[1] - 20.0) < 1.0
    assert not last.violated


def test_profile_table_cache_and_serialization(tmp_path):
    pt = ProfileTable(n_ticks=20_000)
    ctx = [(Path.FUNCTION_CALL, 1500, 0.9)] * 2
    e1 = pt.profile_context(CATALOG["ipsec32"], ctx)
    e2 = pt.profile_context(CATALOG["ipsec32"], ctx)   # cached
    assert e1 is e2
    p = tmp_path / "profile.json"
    pt.to_json(str(p))
    pt2 = ProfileTable.from_json(str(p))
    k = context_key("ipsec32", ctx)
    assert abs(pt2.entries[k].capacity_gbps - e1.capacity_gbps) < 1e-6


def test_profiler_small_messages_collapse_capacity():
    pt = ProfileTable(n_ticks=20_000)
    big = pt.profile_context(CATALOG["ipsec32"],
                             [(Path.FUNCTION_CALL, 1500, 0.9)] * 2)
    small = pt.profile_context(CATALOG["ipsec32"],
                               [(Path.FUNCTION_CALL, 64, 0.9)] * 2)
    # Fig 3b: tiny-message mixtures deliver ~18-32% of peak
    assert small.capacity_gbps < 0.4 * big.capacity_gbps


def test_slo_tag_friendly_vs_violating():
    pt = ProfileTable(n_ticks=20_000)
    e = pt.profile_context(CATALOG["ipsec32"],
                           [(Path.FUNCTION_CALL, 1500, 0.9)] * 2)
    half = e.capacity_gbps / 2
    assert e.slo_tag([0.9 * half, 0.9 * half])
    assert not e.slo_tag([1.2 * half, 1.2 * half])


def test_reshape_decision_heterogeneity():
    # compression: SLO on input stream -> ingress == SLO
    d = reshape_decision(CATALOG["compress"], SLO.gbps(5.0), 16384)
    assert d.params.mode == tb.MODE_GBPS
    # decompression (R>1): deliverable is expanded output -> ingress < SLO
    d2 = reshape_decision(CATALOG["decompress"], SLO.gbps(5.0), 16384)
    assert tb.achieved_rate(d2.params) * 8 / 1e9 < 5.0
    # giant messages get split
    d3 = reshape_decision(CATALOG["aes256"], SLO.gbps(5.0), 512 * 1024)
    assert d3.resize_to is not None and d3.resize_to < 512 * 1024


def test_policies():
    r = policies.plan_reserved(SLO.gbps(8.0))
    o = policies.plan_on_demand(SLO.gbps(8.0))
    b = policies.plan_managed_burst(SLO.gbps(8.0), burst_x=10.0)
    opp = policies.plan_opportunistic()
    assert r.admission_guaranteed and not o.admission_guaranteed
    assert b.params.bkt_size > r.params.bkt_size        # burst budget
    assert b.capacity_debit_gbps == pytest.approx(80.0)  # debit the burst
    assert opp.capacity_debit_gbps == 0.0 and opp.weight < 0.1


def test_path_selection_moves_saturated_flow():
    """A flow on a saturated ingress direction moves to an alternate path."""
    rt = ArcusRuntime([CATALOG["synthetic50"]],
                      alt_paths={0: [Path.INLINE_NIC_RX]})
    # saturate h2d: two big function-call flows
    assert rt.register(_spec(0, 20.0, msg=4096))
    st = rt.table[0]
    cur = {"c_adm_bytes": np.array([7e9]), "c_done_bytes": np.array([7e9]),
           "c_adm_msgs": np.array([1]), "c_done_msgs": np.array([1]),
           "c_drops": np.array([0]), "c_lat_sum": np.array([0.0])}
    prev = {k: np.zeros_like(v) for k, v in cur.items()}
    newp = rt._path_selection(st, cur, prev, window_s=1.0)
    assert newp == Path.INLINE_NIC_RX
