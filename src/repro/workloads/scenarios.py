"""Named, replayable production-shaped fleet scenarios.

A ``ScenarioSpec`` composes the production-shaped generators
(``repro.workloads.generators``) with a tenant mix, churn events and
SLOs into one controller-ready package: ``build()`` returns a fresh
``FleetController`` with every tenant admitted, explicit lane-ordered
arrival traces over the full horizon, and the exact ``run_kwargs`` for
``FleetController.run`` — so a whole scenario (every tenant, every
window, any mid-run churn) still rides ONE compiled engine entry.

Replayability: the arrival traces returned by ``build()`` are the exact
rows ``run`` would generate itself (``FleetController.layout_arrivals``
— same rng stream, same lane order).  ``save_trace``/``load_trace``
round-trip them through JSON or npz bit-for-bit, and
``build(arrivals=...)`` swaps a loaded trace back in: replaying a saved
trace reproduces the run's counters exactly (pinned in tests).

The registry (``register_scenario`` / ``get_scenario`` /
``scenario_names``) is what ``benchmarks/scenarios.py`` drives: one
driver, many named scenarios, comparable outputs.

Scenario tuning convention: horizons are fixed and modest (churn-style
— quick and full benchmark modes run the SAME timeline, so committed
baselines gate CI smoke runs exactly), and every server carries a
compliant reference tenant (ids 1000+b, the paper's <1%
throughput-variance probe) plus a small-message latency tenant (ids
2000+b, the tail-latency probe) alongside the scenario traffic.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Callable

import numpy as np

from repro.core import token_bucket as tb
from repro.core.accelerator import CATALOG
from repro.core.controller import FleetController, TenantEvent
from repro.core.flow import SLO, FlowSpec, Path, TrafficPattern
from repro.core.interconnect import ARB_RR
from repro.core.profiler import ProfileTable
from repro.core.runtime import ArcusRuntime
from repro.core.sim import SHAPING_HW, SimConfig

import repro.workloads.generators  # noqa: F401  (registers processes)

#: scenario definitions assume the default runtime clock; ``build``
#: reads the actual clock off the runtimes it constructs
_CLOCK_HZ = 250e6

TenantFn = Callable[["ScenarioSpec"], "list[list[FlowSpec]]"]
EventFn = Callable[["ScenarioSpec"], "list[TenantEvent]"]


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One named scenario: generators x tenant mix x churn x SLOs.

    ``tenants`` maps the spec to per-server FlowSpec lists (admitted via
    ``admit_fleet`` — rejection at build is an error: scenarios are
    tuned to fit).  ``events`` (optional) maps the spec to the run's
    ``TenantEvent`` churn timeline.  Both are functions of the spec so a
    ``dataclasses.replace``'d variant (longer horizon, more servers)
    re-derives window-locked knobs like the adversarial burst period."""

    name: str
    description: str
    tenants: TenantFn
    events: EventFn | None = None
    servers: int = 2
    complements: tuple = (("synthetic50",),)
    window_ticks: int = 1_500
    n_windows: int = 8
    tick_cycles: int = 8
    seed: int = 17
    ref_gbps: float = 32.0
    #: mode-independent profiling horizon (see benchmarks/churn.py): the
    #: same admission decisions in quick and full benchmark runs
    profile_ticks: int = 8_000

    @property
    def total_ticks(self) -> int:
        return self.window_ticks * self.n_windows

    def window_s(self, clock_hz: float = _CLOCK_HZ) -> float:
        return self.window_ticks * self.tick_cycles / clock_hz

    def horizon_s(self, clock_hz: float = _CLOCK_HZ) -> float:
        return self.total_ticks * self.tick_cycles / clock_hz

    def build(self, *, control=None, profile: ProfileTable | None = None,
              arrivals=None) -> "BuiltScenario":
        """Materialize the scenario: fresh runtimes + controller, every
        tenant admitted, full-horizon lane-ordered arrival traces, and
        the ``run_kwargs`` that drive ``FleetController.run``.

        ``control`` is the between-window shaping policy under test
        (default ``StaticHold``); ``profile`` shares a warmed
        ``ProfileTable`` across builds so repeated builds (warm-up arm,
        timed arm) profile nothing; ``arrivals`` swaps in a replayed
        trace from ``load_trace`` instead of generating one."""
        profile = profile if profile is not None \
            else ProfileTable(n_ticks=self.profile_ticks)
        comps = self.complements
        rts = [ArcusRuntime([CATALOG[n] for n in comps[b % len(comps)]],
                            profile_table=profile)
               for b in range(self.servers)]
        ctrl = FleetController(rts, control=control)
        clock_hz = rts[0].clock_hz
        specs = self.tenants(self)
        acc = ctrl.admit_fleet(specs)
        rejected = [s.flow_id for lst, oks in zip(specs, acc)
                    for s, ok in zip(lst, oks) if not ok]
        if rejected:
            raise ValueError(
                f"scenario {self.name!r}: tenants {rejected} rejected at "
                "admission — scenarios must be tuned to fit their fleet")
        events = list(self.events(self)) if self.events is not None else []
        cfg = SimConfig(n_ticks=self.total_ticks,
                        tick_cycles=self.tick_cycles,
                        shaping=SHAPING_HW, arbiter=ARB_RR,
                        clock_hz=clock_hz)
        seeds = [self.seed * 7919 + b for b in range(self.servers)]
        refs = [{k: self.ref_gbps for k in range(len(ctrl.lane_map(b)))}
                for b in range(self.servers)]
        if arrivals is None:
            arrivals = [ctrl.layout_arrivals(b, cfg, seeds[b], refs[b])
                        for b in range(self.servers)]
        else:
            arrivals = [(np.asarray(t, np.int32), np.asarray(s, np.int32))
                        for t, s in arrivals]
        run_kwargs = dict(total_ticks=self.total_ticks,
                          window_ticks=self.window_ticks,
                          tick_cycles=self.tick_cycles,
                          seeds=seeds, load_ref_gbps=refs,
                          arrivals=arrivals, events=events)
        return BuiltScenario(spec=self, controller=ctrl, arrivals=arrivals,
                             run_kwargs=run_kwargs,
                             lane_maps=[ctrl.lane_map(b)
                                        for b in range(self.servers)],
                             clock_hz=clock_hz)


@dataclasses.dataclass
class BuiltScenario:
    """A materialized scenario, ready to run (or to save for replay)."""

    spec: ScenarioSpec
    controller: FleetController
    arrivals: list          # per server (times, sizes), lane order
    run_kwargs: dict[str, Any]
    lane_maps: list
    clock_hz: float

    def run(self):
        """Drive the scenario timeline; see ``FleetController.run``.
        One-shot: the controller's state advances, so build a fresh
        scenario per run (``run_kwargs``/``arrivals`` are reusable)."""
        return self.controller.run(**self.run_kwargs)


# ---------------------------------------------------------------------------
# Trace round-trip (replayable runs)
# ---------------------------------------------------------------------------


def save_trace(path, arrivals, *, meta: dict | None = None) -> None:
    """Persist per-server (times, sizes) traces to ``.json`` or ``.npz``.

    Both formats round-trip the int32 arrays exactly; ``meta`` (a
    JSON-serializable dict — scenario name, seed, ...) rides along."""
    path = os.fspath(path)
    meta = dict(meta or {})
    if path.endswith(".json"):
        payload = {"meta": meta,
                   "servers": [{"t": np.asarray(t).astype(int).tolist(),
                                "s": np.asarray(s).astype(int).tolist()}
                               for t, s in arrivals]}
        with open(path, "w") as f:
            json.dump(payload, f)
    elif path.endswith(".npz"):
        arrs: dict[str, np.ndarray] = {
            "n_servers": np.int64(len(arrivals)),
            "meta": np.asarray(json.dumps(meta))}
        for b, (t, s) in enumerate(arrivals):
            arrs[f"t{b}"] = np.asarray(t, np.int32)
            arrs[f"s{b}"] = np.asarray(s, np.int32)
        np.savez_compressed(path, **arrs)
    else:
        raise ValueError(
            f"unsupported trace format {path!r}; use .json or .npz")


def load_trace(path):
    """Inverse of ``save_trace``: returns ``(arrivals, meta)`` with
    per-server int32 (times, sizes) pairs, bit-identical to what was
    saved — feed them to ``ScenarioSpec.build(arrivals=...)``."""
    path = os.fspath(path)
    if path.endswith(".json"):
        with open(path) as f:
            payload = json.load(f)
        arrivals = [(np.asarray(sv["t"], np.int32),
                     np.asarray(sv["s"], np.int32))
                    for sv in payload["servers"]]
        return arrivals, payload.get("meta", {})
    if path.endswith(".npz"):
        with np.load(path) as z:
            meta = json.loads(str(z["meta"]))
            arrivals = [(z[f"t{b}"].astype(np.int32),
                         z[f"s{b}"].astype(np.int32))
                        for b in range(int(z["n_servers"]))]
        return arrivals, meta
    raise ValueError(f"unsupported trace format {path!r}; use .json or .npz")


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

SCENARIOS: dict[str, ScenarioSpec] = {}


def register_scenario(spec: ScenarioSpec, *,
                      replace: bool = False) -> ScenarioSpec:
    if spec.name in SCENARIOS and not replace:
        raise ValueError(
            f"scenario {spec.name!r} is already registered; pass "
            "replace=True to override")
    SCENARIOS[spec.name] = spec
    return spec


def scenario_names() -> tuple[str, ...]:
    return tuple(SCENARIOS)


def get_scenario(name: str) -> ScenarioSpec:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; registered: "
                       f"{sorted(SCENARIOS)}") from None


# ---------------------------------------------------------------------------
# The named scenarios
# ---------------------------------------------------------------------------

#: every server carries these probes alongside its scenario traffic
REF_SLO = 8.0       # ids 1000+b: compliant poisson, the variance probe
LAT_BOUND_S = 4e-6  # ids 2000+b: small-message latency probe


def _ref_spec(b: int) -> FlowSpec:
    return FlowSpec(1000 + b, 1000 + b, Path.FUNCTION_CALL, 0,
                    TrafficPattern(1024, load=0.35, process="poisson"),
                    SLO.gbps(REF_SLO))


def _lat_spec(b: int) -> FlowSpec:
    return FlowSpec(2000 + b, 2000 + b, Path.FUNCTION_CALL, 0,
                    TrafficPattern(128, rate_mps=1.0e6, process="poisson"),
                    SLO.latency(LAT_BOUND_S))


def _with_probes(spec: ScenarioSpec, per_server) -> list[list[FlowSpec]]:
    """[ref, latency, *scenario tenants] per server — lane order."""
    return [[_ref_spec(b), _lat_spec(b)] + list(per_server(b))
            for b in range(spec.servers)]


def _mmpp_tenants(spec: ScenarioSpec) -> list[list[FlowSpec]]:
    def per_server(b):
        return [FlowSpec(100 + 10 * b + i, 100 + 10 * b + i,
                         Path.FUNCTION_CALL, 0,
                         TrafficPattern(1024, load=0.2, process="mmpp",
                                        params=(("states", (0.25, 2.5)),)),
                         SLO.gbps(6.0))
                for i in range(2)]
    return _with_probes(spec, per_server)


def _heavytail_tenants(spec: ScenarioSpec) -> list[list[FlowSpec]]:
    def per_server(b):
        pareto = TrafficPattern(1024, load=0.2, process="heavytail",
                                params=(("dist", "pareto"),
                                        ("alpha", 1.4),
                                        ("max_bytes", 128 * 1024)))
        logn = TrafficPattern(1024, load=0.2, process="heavytail",
                              params=(("dist", "lognormal"),
                                      ("sigma", 1.2),
                                      ("max_bytes", 128 * 1024)))
        return [FlowSpec(100 + 10 * b, 100 + 10 * b, Path.FUNCTION_CALL, 0,
                         pareto, SLO.gbps(6.0)),
                FlowSpec(101 + 10 * b, 101 + 10 * b, Path.FUNCTION_CALL, 0,
                         logn, SLO.gbps(6.0))]
    return _with_probes(spec, per_server)


def _diurnal_tenants(spec: ScenarioSpec) -> list[list[FlowSpec]]:
    def per_server(b):
        # anti-phase day/night swing across servers, plus a corrburst
        # tenant per server sharing ONE epoch stream (group 7): the
        # bursts land at the same instants fleet-wide
        diurnal = TrafficPattern(1024, load=0.2, process="diurnal",
                                 params=(("amp", 0.9),
                                         ("phase", 0.5 * b)))
        corr = TrafficPattern(1024, load=0.25, process="corrburst",
                              params=(("group", 7),
                                      ("burst_hz", 40_000.0),
                                      ("burst_len", 16)))
        return [FlowSpec(100 + 10 * b, 100 + 10 * b, Path.FUNCTION_CALL, 0,
                         diurnal, SLO.gbps(6.0)),
                FlowSpec(101 + 10 * b, 101 + 10 * b, Path.FUNCTION_CALL, 0,
                         corr, SLO.gbps(7.0))]
    return _with_probes(spec, per_server)


def _flash_tenants(spec: ScenarioSpec) -> list[list[FlowSpec]]:
    def per_server(b):
        flash = TrafficPattern(1024, load=0.15, process="flash",
                               params=(("at", 0.25), ("mult", 6.0)))
        return [FlowSpec(100 + 10 * b, 100 + 10 * b, Path.FUNCTION_CALL, 0,
                         flash, SLO.gbps(5.0))]
    return _with_probes(spec, per_server)


def _flash_events(spec: ScenarioSpec) -> list[TenantEvent]:
    """Opportunist tenants arrive mid-storm (window 2 of the default
    8): admission + lane splice while the flash crowd is still hot."""
    return [TenantEvent.arrive(
        2,
        FlowSpec(300 + b, 300 + b, Path.FUNCTION_CALL, 0,
                 TrafficPattern(1024, load=0.3, process="poisson"),
                 SLO.gbps(4.0)),
        server=b, accel_name="synthetic50")
        for b in range(spec.servers)]


def _adversarial_tenants(spec: ScenarioSpec) -> list[list[FlowSpec]]:
    slo = 6.0
    depth = tb.params_for_gbps(slo).bkt_size
    window_s = spec.window_s()
    # the worst compliant probe: bursts of exactly the bucket depth,
    # phase-locked to window edges, spaced by the smallest whole number
    # of windows over which the refill fully replenishes the bucket —
    # every burst is admitted wholesale, yet the average rate stays
    # under the SLO
    period = float(np.ceil((depth * 8.0 / (slo * 1e9)) / window_s)
                   * window_s)
    nmsg = int(np.ceil(depth / 1024))
    adv = TrafficPattern(1024, rate_mps=nmsg / period, process="adversarial",
                         params=(("bucket_bytes", depth),
                                 ("period_s", period),
                                 ("phase_s", 0.0),
                                 ("line_gbps", 100.0)))

    def per_server(b):
        return [FlowSpec(100 + 10 * b, 100 + 10 * b, Path.FUNCTION_CALL, 0,
                         adv, SLO.gbps(slo))]
    return _with_probes(spec, per_server)


register_scenario(ScenarioSpec(
    name="mmpp_surge",
    description="Markov-modulated Poisson tenants cycling quiet/surge "
                "(10x relative swing) around a compliant mean",
    tenants=_mmpp_tenants))

register_scenario(ScenarioSpec(
    name="heavy_tail",
    description="Poisson arrivals with heavy-tailed message sizes "
                "(Pareto a=1.4 and lognormal s=1.2, mean 1 KiB)",
    tenants=_heavytail_tenants))

register_scenario(ScenarioSpec(
    name="diurnal_corr",
    description="Anti-phase diurnal load swing across servers plus "
                "cross-server correlated burst epochs (shared group)",
    tenants=_diurnal_tenants))

register_scenario(ScenarioSpec(
    name="flash_crowd",
    description="Flash crowd (6x surge, exponential decay) with "
                "opportunist tenants arriving mid-storm",
    tenants=_flash_tenants, events=_flash_events))

register_scenario(ScenarioSpec(
    name="adversarial_probe",
    description="Token-bucket boundary prober: bucket-depth bursts "
                "phase-locked to window edges, compliant on average",
    tenants=_adversarial_tenants))
