"""Production-shaped workload subsystem.

``repro.workloads.generators`` registers the production-shaped arrival
processes (MMPP, heavy-tailed sizes, diurnal, correlated cross-tenant
bursts, flash crowds, adversarial token-bucket probing) into the sim's
arrival-process registry; ``repro.workloads.scenarios`` composes them
into named, replayable fleet scenarios.

Importing this package is enough to make every generator available to
``TrafficPattern(process=...)`` across all existing entry points
(``gen_arrivals`` / ``stack_arrivals`` / ``run_system_batch`` /
``FleetController.run``).
"""
from repro.workloads import generators  # noqa: F401 (registers processes)
from repro.workloads.scenarios import (SCENARIOS,  # noqa: F401
                                       BuiltScenario, ScenarioSpec,
                                       get_scenario, load_trace,
                                       register_scenario, save_trace,
                                       scenario_names)
