"""Production-shaped arrival-trace generators.

The repo's historical timelines are synthetic rate sweeps over three
arrival processes (cbr / poisson / onoff).  This module opens the
scenario space with the processes production traffic actually exhibits —
the regimes where "SLO beyond the Hardware Isolation Limits" warns that
isolation which holds at steady state breaks:

* ``mmpp``       — Markov-modulated Poisson: the flow cycles through
                   rate states (e.g. quiet / surge) with exponential
                   sojourns; the long-run mean equals the nominal rate.
* ``heavytail``  — Poisson arrivals with heavy-tailed message sizes
                   (Pareto or lognormal, mean pinned to ``msg_bytes``).
* ``diurnal``    — nonhomogeneous Poisson with a sinusoidal rate curve
                   (the day/night load swing, squeezed into the horizon).
* ``corrburst``  — correlated cross-tenant bursts: every flow sharing a
                   ``group`` id bursts at the SAME epochs (a deploy, a
                   cache flush, a market open), plus base Poisson load.
* ``flash``      — flash crowd: baseline Poisson until ``at`` of the
                   horizon, then the rate jumps ``mult``x and decays
                   exponentially back to baseline.
* ``adversarial``— a tenant that probes token-bucket boundaries:
                   deterministic back-to-back bursts sized to the bucket
                   depth, phase-locked to window edges — the worst
                   compliant-on-average traffic a shaper admits.

All of them are registered into ``repro.core.sim``'s arrival-process
registry on import, so ``TrafficPattern(process="mmpp", params=...)``
flows through every existing trace consumer — ``gen_arrivals``,
``stack_arrivals``/``simulate_batch``, ``baselines.run_system_batch``
and ``FleetController.run`` — and a whole scenario still rides ONE
compiled engine entry.  Knobs ride ``TrafficPattern.params`` (a tuple of
``(name, value)`` pairs; see each handler's docstring).

Determinism: every handler draws only from ``gen_arrivals``'s shared,
seeded rng (``corrburst`` epochs intentionally come from the ``group``
id instead, so correlation survives across seeds and tenant subsets),
and handlers run in registration order — same seed, same trace,
byte-for-byte (digest-pinned in tests).
"""
from __future__ import annotations

import numpy as np

from repro.core.engine import SimConfig
from repro.core.flow import SLO, FlowSet, FlowSpec, Path, TrafficPattern
from repro.core.sim import gen_arrivals, register_process

#: inversion-grid resolution for nonhomogeneous-Poisson rate curves —
#: closed-form cumulative intensities sampled this finely keep the
#: interpolation error far below a tick
_NHPP_GRID = 4097


def _invert_nhpp(rng, t_grid: np.ndarray, lam_grid: np.ndarray,
                 M0: int) -> np.ndarray:
    """One nonhomogeneous-Poisson row by inversion: unit-rate exponential
    levels mapped through the inverse cumulative intensity Λ^-1 (linear
    interpolation on a monotone (t, Λ) grid).  Levels beyond Λ(horizon)
    clamp at the horizon — ``gen_arrivals`` trims them as invalid."""
    u = np.cumsum(rng.exponential(1.0, M0))
    t = np.interp(u, lam_grid, t_grid)
    return np.diff(t, prepend=0.0)


# ---------------------------------------------------------------------------
# mmpp
# ---------------------------------------------------------------------------


def _mmpp_weights(pat: TrafficPattern) -> tuple[np.ndarray, np.ndarray,
                                                float]:
    """(state multipliers, mean sojourn weights, weighted-mean multiplier)
    — the normalizer that pins the long-run mean to the nominal rate."""
    mults = np.asarray(pat.param("states", (0.25, 2.5)), float)
    soj = pat.param("sojourn_s", None)
    if soj is None:
        w = np.ones_like(mults)
    else:
        w = np.broadcast_to(np.asarray(soj, float), mults.shape)
    wmean = float((mults * w).sum() / w.sum())
    return mults, w, max(wmean, 1e-12)


def _mmpp_gaps(pats, rates, rng, M0, horizon_s):
    """Markov-modulated Poisson (cyclic state chain).

    params: ``states`` — per-state rate multipliers (default
    ``(0.25, 2.5)``: a quiet state and a 10x-relative surge);
    ``sojourn_s`` — mean sojourn per state in seconds (scalar or
    per-state; default ``horizon / 6`` so a run sees several
    transitions).  State rates are normalized by the sojourn-weighted
    mean multiplier, so the long-run mean rate equals the nominal
    pattern rate regardless of the state mix."""
    out = np.empty((len(pats), M0))
    for j, (pat, rate) in enumerate(zip(pats, rates)):
        mults, _w, wmean = _mmpp_weights(pat)
        soj = pat.param("sojourn_s", None)
        if soj is None:
            soj = horizon_s / 6.0
        soj = np.broadcast_to(np.asarray(soj, float), mults.shape)
        # state timeline: exponential sojourns, cyclic state order
        t_knots, lam_knots = [0.0], [0.0]
        s, t = 0, 0.0
        while t < horizon_s:
            dur = rng.exponential(soj[s])
            lam = rate * mults[s] / wmean
            t += dur
            t_knots.append(t)
            lam_knots.append(lam_knots[-1] + lam * dur)
            s = (s + 1) % len(mults)
        out[j] = _invert_nhpp(rng, np.asarray(t_knots),
                              np.asarray(lam_knots), M0)
    return out


def _mmpp_budget(pat, rate, horizon_s):
    mults, _w, wmean = _mmpp_weights(pat)
    # worst case: the realized timeline dwells in the hottest state
    return float(mults.max()) / wmean + 0.05


# ---------------------------------------------------------------------------
# heavytail
# ---------------------------------------------------------------------------


def _heavytail_gaps(pats, rates, rng, M0, horizon_s):
    """Poisson arrivals, heavy-tailed sizes with mean ``msg_bytes``.

    params: ``dist`` — ``"pareto"`` (default) or ``"lognormal"``;
    ``alpha`` — Pareto shape (> 1; default 1.5, infinite variance);
    ``sigma`` — lognormal shape (default 1.0); ``max_bytes`` — size cap
    (default 1 MiB, the engine's shared accel-buffer scale)."""
    k = len(pats)
    gaps = rng.exponential(1.0, (k, M0)) / rates[:, None]
    sizes = np.empty((k, M0), np.int64)
    for j, pat in enumerate(pats):
        dist = pat.param("dist", "pareto")
        cap = int(pat.param("max_bytes", 1 << 20))
        mean = float(max(pat.msg_bytes, 1))
        if dist == "pareto":
            alpha = float(pat.param("alpha", 1.5))
            if alpha <= 1.0:
                raise ValueError(
                    f"heavytail pareto needs alpha > 1 (got {alpha}) — "
                    "the mean diverges otherwise")
            xm = mean * (alpha - 1.0) / alpha
            raw = xm * (1.0 + rng.pareto(alpha, M0))
        elif dist == "lognormal":
            sigma = float(pat.param("sigma", 1.0))
            mu = np.log(mean) - sigma * sigma / 2.0
            raw = rng.lognormal(mu, sigma, M0)
        else:
            raise ValueError(
                f"unknown heavytail dist {dist!r}; expected 'pareto' or "
                "'lognormal'")
        sizes[j] = np.clip(raw, 1, cap).astype(np.int64)
    return gaps, sizes


# ---------------------------------------------------------------------------
# diurnal
# ---------------------------------------------------------------------------


def _diurnal_gaps(pats, rates, rng, M0, horizon_s):
    """Nonhomogeneous Poisson with a sinusoidal rate curve:
    ``rate(t) = rate * (1 + amp * sin(2π (t/period + phase)))``.

    params: ``period_s`` — curve period (default: the horizon, one full
    day squeezed into the run); ``amp`` — swing amplitude in [0, 1)
    (default 0.8); ``phase`` — phase offset in periods (default 0)."""
    out = np.empty((len(pats), M0))
    t_grid = np.linspace(0.0, horizon_s, _NHPP_GRID)
    for j, (pat, rate) in enumerate(zip(pats, rates)):
        period = float(pat.param("period_s", horizon_s))
        amp = float(np.clip(pat.param("amp", 0.8), 0.0, 0.999))
        phase = float(pat.param("phase", 0.0))
        w = 2.0 * np.pi / period
        # Λ(t) = r t - (r amp / w) (cos(w t + φ0) - cos φ0)
        phi0 = 2.0 * np.pi * phase
        lam_grid = rate * (t_grid - (amp / w)
                           * (np.cos(w * t_grid + phi0) - np.cos(phi0)))
        out[j] = _invert_nhpp(rng, t_grid, lam_grid, M0)
    return out


def _diurnal_budget(pat, rate, horizon_s):
    return 1.0 + float(np.clip(pat.param("amp", 0.8), 0.0, 0.999)) + 0.05


# ---------------------------------------------------------------------------
# corrburst
# ---------------------------------------------------------------------------


def _corrburst_gaps(pats, rates, rng, M0, horizon_s):
    """Correlated cross-tenant bursts on top of base Poisson load.

    Every flow sharing a ``group`` id bursts at the SAME epochs —
    drawn from a dedicated rng seeded by the group id, NOT the trace
    seed, so correlation holds across tenants generated in different
    ``gen_arrivals`` calls (different servers, different seeds).

    params: ``group`` — shared-epoch stream id (default 0);
    ``burst_hz`` — epoch rate (default 2000); ``burst_len`` — messages
    per burst (default 32); ``line_gbps`` — in-burst injection speed
    (default 100).  Base Poisson load runs at
    ``max(rate - burst_hz * burst_len, 0)`` so the mean stays ``rate``.
    """
    out = np.empty((len(pats), M0))
    epoch_cache: dict[tuple[int, float], np.ndarray] = {}
    for j, (pat, rate) in enumerate(zip(pats, rates)):
        group = int(pat.param("group", 0))
        burst_hz = float(pat.param("burst_hz", 2000.0))
        burst_len = int(pat.param("burst_len", pat.burst_len))
        line = float(pat.param("line_gbps", 100.0))
        key = (group, burst_hz)
        if key not in epoch_cache:
            grng = np.random.default_rng(0x5EED0000 + group)
            n_ep = int(round(burst_hz * horizon_s))
            epoch_cache[key] = np.sort(grng.uniform(0.0, horizon_s, n_ep))
        epochs = epoch_cache[key]
        intra = max(pat.msg_bytes, 1) * 8.0 / (line * 1e9)
        bursts = (epochs[:, None]
                  + np.arange(burst_len) * intra).ravel()
        base_rate = max(rate - burst_hz * burst_len, 0.0)
        base = np.cumsum(rng.exponential(1.0, M0)) \
            / max(base_rate, 1e-9)
        merged = np.sort(np.concatenate([bursts, base]))[:M0]
        out[j] = np.diff(merged, prepend=0.0)
    return out


def _corrburst_budget(pat, rate, horizon_s):
    burst_hz = float(pat.param("burst_hz", 2000.0))
    burst_len = int(pat.param("burst_len", pat.burst_len))
    # bursts are a fixed msgs/s floor even when the nominal rate is lower
    return max(1.0, burst_hz * burst_len / max(rate, 1e-9)) + 0.25


# ---------------------------------------------------------------------------
# flash
# ---------------------------------------------------------------------------


def _flash_gaps(pats, rates, rng, M0, horizon_s):
    """Flash crowd: baseline Poisson, then at ``at`` of the horizon the
    rate jumps ``mult``x and decays exponentially back to baseline.

    params: ``at`` — storm onset as a fraction of the horizon (default
    0.3); ``mult`` — peak rate multiplier (default 8.0); ``decay_s`` —
    decay time constant (default ``horizon / 8``)."""
    out = np.empty((len(pats), M0))
    t_grid = np.linspace(0.0, horizon_s, _NHPP_GRID)
    for j, (pat, rate) in enumerate(zip(pats, rates)):
        t0 = float(pat.param("at", 0.3)) * horizon_s
        mult = float(pat.param("mult", 8.0))
        tau = float(pat.param("decay_s", horizon_s / 8.0))
        # Λ(t) = r t + r (mult-1) τ (1 - exp(-(t-t0)/τ)) for t >= t0
        extra = np.where(
            t_grid >= t0,
            rate * (mult - 1.0) * tau
            * (1.0 - np.exp(-np.maximum(t_grid - t0, 0.0) / tau)),
            0.0)
        lam_grid = rate * t_grid + extra
        out[j] = _invert_nhpp(rng, t_grid, lam_grid, M0)
    return out


def _flash_budget(pat, rate, horizon_s):
    mult = float(pat.param("mult", 8.0))
    tau = float(pat.param("decay_s", horizon_s / 8.0))
    return 1.0 + (mult - 1.0) * min(tau / max(horizon_s, 1e-12), 1.0) + 0.1


# ---------------------------------------------------------------------------
# adversarial
# ---------------------------------------------------------------------------


def _adversarial_gaps(pats, rates, rng, M0, horizon_s):
    """Token-bucket boundary probing — deterministic, no rng.

    Every ``period_s`` (phase-lock it to the control loop's window) the
    tenant injects one back-to-back burst of exactly ``bucket_bytes``
    (the depth of its shaped bucket) at ``line_gbps``, then goes silent
    while the bucket refills.  On average the flow stays at
    ``bucket_bytes * 8 / period_s`` bits/s — compliant — while
    concentrating every byte into the instant the shaper can least
    smooth, maximizing the queueing it induces on co-located tenants.

    params: ``bucket_bytes`` — burst size, sized to the victim bucket's
    depth (default 64 KiB); ``period_s`` — burst period (default 48 us);
    ``phase_s`` — offset after each period edge (default 0);
    ``line_gbps`` — in-burst injection speed (default 100)."""
    out = np.empty((len(pats), M0))
    for j, pat in enumerate(pats):
        bucket = int(pat.param("bucket_bytes", 64 * 1024))
        period = float(pat.param("period_s", 48e-6))
        phase = float(pat.param("phase_s", 0.0))
        line = float(pat.param("line_gbps", 100.0))
        nmsg = max(1, int(np.ceil(bucket / max(pat.msg_bytes, 1))))
        intra = max(pat.msg_bytes, 1) * 8.0 / (line * 1e9)
        n_per = int(np.floor(horizon_s / period)) + 1
        times = (phase + period * np.arange(n_per)[:, None]
                 + np.arange(nmsg) * intra).ravel()[:M0]
        if times.size < M0:      # pad past the horizon (trimmed later)
            pad = horizon_s + period * (1.0 + np.arange(M0 - times.size))
            times = np.concatenate([times, pad])
        out[j] = np.diff(times, prepend=0.0)
    return out


def _adversarial_budget(pat, rate, horizon_s):
    bucket = int(pat.param("bucket_bytes", 64 * 1024))
    period = float(pat.param("period_s", 48e-6))
    nmsg = max(1, int(np.ceil(bucket / max(pat.msg_bytes, 1))))
    return max(1.0, nmsg / (period * max(rate, 1e-9))) + 0.1


register_process("mmpp", _mmpp_gaps, budget=_mmpp_budget)
register_process("heavytail", _heavytail_gaps)
register_process("diurnal", _diurnal_gaps, budget=_diurnal_budget)
register_process("corrburst", _corrburst_gaps, budget=_corrburst_budget)
register_process("flash", _flash_gaps, budget=_flash_budget)
register_process("adversarial", _adversarial_gaps,
                 budget=_adversarial_budget)


# ---------------------------------------------------------------------------
# Standalone trace emission
# ---------------------------------------------------------------------------


def make_trace(patterns: "TrafficPattern | list[TrafficPattern]",
               *, n_ticks: int, tick_cycles: int = 8,
               clock_hz: float = 250e6, seed: int = 0,
               ref_gbps: float = 32.0) -> tuple[np.ndarray, np.ndarray]:
    """Emit one (times, sizes) arrival trace for ad-hoc patterns.

    A thin wrapper over ``sim.gen_arrivals`` (the ONE trace code path —
    digests pinned there cover this too): builds throwaway FlowSpecs
    around the patterns and returns ``[N, M]`` int32 cycle times and
    byte sizes, ready for ``stack_arrivals`` / ``simulate_batch`` /
    ``run_system_batch``."""
    if isinstance(patterns, TrafficPattern):
        patterns = [patterns]
    specs = [FlowSpec(i, i, Path.FUNCTION_CALL, 0, p, SLO.gbps(1.0))
             for i, p in enumerate(patterns)]
    cfg = SimConfig(n_ticks=n_ticks, tick_cycles=tick_cycles,
                    clock_hz=clock_hz)
    return gen_arrivals(FlowSet.build(specs), cfg, seed=seed,
                        load_ref_gbps={i: ref_gbps
                                       for i in range(len(specs))})
