# Pallas TPU kernels for the perf-critical compute layers, each validated
# in interpret=True mode against its pure-jnp ref.py oracle:
#   token_bucket/     — the paper's hardware rate limiter, vectorized over
#                       flows (the Arcus offloaded mechanism, TPU-native)
#   decode_attention/ — GQA flash decode (one token vs a long KV cache)
#   flash_prefill/    — causal GQA flash attention for prefill/train
#                       (sliding-window + chunked-local masks, block-level
#                       short-circuit)
#   ssd_scan/         — Mamba2 SSD chunked scan (MXU-friendly chunk duality)
