"""Pallas TPU kernel: GQA decode attention (flash-style online softmax).

Serving hot-spot: one new token attends to a long KV cache (decode_32k /
long_500k shapes).  TPU adaptation of the usual GPU decode kernel:

  * grid = (B, KvH, S // S_BLOCK); the S dimension is the innermost,
    sequentially-iterated axis with running (m, l, acc) carried in VMEM
    scratch — HBM->VMEM streaming of K/V blocks, one pass, no S^2 memory.
  * the G = H/KvH query heads of one KV group form the sublane dimension of
    the MXU matmuls (padded to >= 8 sublanes by the ops wrapper), so the
    scores matmul is [G, D] x [D, S_BLOCK] — MXU-aligned when D, S_BLOCK are
    multiples of 128.
  * sliding windows mask whole blocks cheaply (block-level early-out via
    masking; positions outside [len - window, len) never contribute).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _decode_attn_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                        acc_ref, m_ref, l_ref, *, s_block: int, window: int,
                        scale: float, s_blocks: int):
    s_i = pl.program_id(2)
    length = len_ref[0]

    @pl.when(s_i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)             # [G, D]
    k = k_ref[0, :, 0].astype(jnp.float32)          # [S_BLOCK, D]
    v = v_ref[0, :, 0].astype(jnp.float32)          # [S_BLOCK, D]

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # [G, S_BLOCK]

    idx = s_i * s_block + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    valid = idx < length
    if window > 0:
        valid = jnp.logical_and(valid, idx >= length - window)
    scores = jnp.where(valid, scores, NEG_INF)

    m_prev = m_ref[:, 0]                             # [G]
    m_cur = jnp.maximum(m_prev, scores.max(axis=1))  # [G]
    alpha = jnp.exp(m_prev - m_cur)                  # [G]
    p = jnp.exp(scores - m_cur[:, None])             # [G, S_BLOCK]
    p = jnp.where(valid, p, 0.0)
    l_cur = l_ref[:, 0] * alpha + p.sum(axis=1)
    acc = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_ref[...] = jnp.broadcast_to(m_cur[:, None], m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_cur[:, None], l_ref.shape)
    acc_ref[...] = acc

    @pl.when(s_i == s_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("s_block", "window", "scale", "interpret"))
def decode_attention_grouped(q, k, v, lengths, *, s_block: int = 512,
                             window: int = 0, scale: float = 1.0,
                             interpret: bool = True):
    """q [B, KvH, G, D]; k, v [B, S, KvH, D]; lengths [B] -> [B, KvH, G, D].

    G must be a multiple of 8 and D a multiple of 128 (the ops wrapper
    pads); S must be a multiple of s_block."""
    B, KvH, G, D = q.shape
    S = k.shape[1]
    assert S % s_block == 0, (S, s_block)
    s_blocks = S // s_block
    grid = (B, KvH, s_blocks)
    kernel = functools.partial(_decode_attn_kernel, s_block=s_block,
                               window=window, scale=scale, s_blocks=s_blocks)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, n, s: (b,)),
            pl.BlockSpec((1, 1, G, D), lambda b, n, s: (b, n, 0, 0)),
            pl.BlockSpec((1, s_block, 1, D), lambda b, n, s: (b, s, n, 0)),
            pl.BlockSpec((1, s_block, 1, D), lambda b, n, s: (b, s, n, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, n, s: (b, n, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KvH, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths.astype(jnp.int32), q, k, v)
