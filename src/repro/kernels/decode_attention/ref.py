"""Pure-jnp oracle for GQA decode attention (one new token vs. a KV cache).

Shapes:
  q        [B, H, D]        — one query token per sequence
  k, v     [B, S, KvH, D]   — KV cache (padded to S)
  lengths  [B] int32        — valid cache length per sequence
  window   int              — 0 = full attention; w > 0 = sliding window
                              (attend to positions [len-w, len))
Returns [B, H, D].
"""
from __future__ import annotations

import jax.numpy as jnp


def decode_attention(q, k, v, lengths, *, window: int = 0,
                     scale: float | None = None):
    B, H, D = q.shape
    S, KvH = k.shape[1], k.shape[2]
    G = H // KvH
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, KvH, G, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bngd,bsnd->bngs", qg, kf) * scale
    idx = jnp.arange(S)[None, :]                      # [1, S]
    ln = lengths[:, None]                             # [B, 1]
    valid = idx < ln
    if window > 0:
        valid = jnp.logical_and(valid, idx >= ln - window)
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    probs = jnp.exp(scores - scores.max(-1, keepdims=True))
    probs = jnp.where(jnp.isfinite(scores), probs, 0.0)
    denom = jnp.maximum(probs.sum(-1, keepdims=True), 1e-30)
    out = jnp.einsum("bngs,bsnd->bngd", probs / denom, vf)
    return out.reshape(B, H, D).astype(q.dtype)
