"""Jitted public wrapper for the decode-attention Pallas kernel.

Handles GQA head grouping and TPU tile padding:
  * q [B, H, D] is regrouped to [B, KvH, G, D]; G padded to a multiple of 8,
  * D padded to a multiple of 128,
  * S padded to a multiple of the S block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_grouped


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit, static_argnames=("window", "s_block", "interpret"))
def decode_attention(q, k, v, lengths, *, window: int = 0,
                     s_block: int = 512, interpret: bool = True):
    """q [B, H, D]; k, v [B, S, KvH, D]; lengths [B] -> [B, H, D]."""
    B, H, D = q.shape
    S, KvH = k.shape[1], k.shape[2]
    assert H % KvH == 0
    G = H // KvH
    scale = D ** -0.5  # scale on the true head dim, not the padded one

    Gp = _round_up(max(G, 8), 8)
    Dp = _round_up(D, 128)
    s_block = min(s_block, _round_up(S, 128))
    Sp = _round_up(S, s_block)

    qg = q.reshape(B, KvH, G, D)
    qg = jnp.pad(qg, ((0, 0), (0, 0), (0, Gp - G), (0, Dp - D)))
    kp = jnp.pad(k, ((0, 0), (0, Sp - S), (0, 0), (0, Dp - D)))
    vp = jnp.pad(v, ((0, 0), (0, Sp - S), (0, 0), (0, Dp - D)))

    out = decode_attention_grouped(qg, kp, vp, lengths, s_block=s_block,
                                   window=window, scale=scale,
                                   interpret=interpret)
    return out[:, :, :G, :D].reshape(B, H, D)
