"""Jitted public wrapper for the token-bucket Pallas kernel.

Accepts flat [N] flow-state arrays (any N), pads to the kernel's
R x 128 tiling, dispatches, and unpads.  The Pallas execution mode is
auto-detected: compiled Pallas on TPU backends, ``interpret=True`` (kernel
body evaluated op-by-op) everywhere else.  Set ``REPRO_TB_INTERPRET=0``
or ``=1`` to force either mode, or pass ``interpret=`` explicitly;
``resolved_interpret()`` reports the effective choice.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.token_bucket import TBState
from repro.kernels.token_bucket.kernel import (FLOWS_PER_BLOCK, LANES,
                                               default_interpret,
                                               token_bucket_step_2d)


def resolved_interpret(interpret: bool | None = None) -> bool:
    """The Pallas mode ``token_bucket_step`` will actually run with."""
    return default_interpret() if interpret is None else interpret


def _pad2d(x: jax.Array, n_pad: int) -> jax.Array:
    x = jnp.pad(x.astype(jnp.int32), (0, n_pad - x.shape[0]))
    return x.reshape(-1, LANES)


def token_bucket_step(state: TBState, elapsed_cycles, msg_cost, want,
                      *, interpret: bool | None = None
                      ) -> tuple[TBState, jax.Array]:
    """Advance all buckets one shaping interval and admit head messages.

    Drop-in replacement for (tb.advance + tb.try_admit); same semantics,
    executed as a single fused on-device kernel.  The interpret mode is
    resolved *before* entering the jit so REPRO_TB_INTERPRET changes are
    honoured on every call, not frozen into the first trace."""
    return _token_bucket_step(state, elapsed_cycles, msg_cost, want,
                              interpret=resolved_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _token_bucket_step(state: TBState, elapsed_cycles, msg_cost, want,
                       *, interpret: bool) -> tuple[TBState, jax.Array]:
    n = state.tokens.shape[0]
    n_pad = -(-n // FLOWS_PER_BLOCK) * FLOWS_PER_BLOCK
    args = [_pad2d(a, n_pad) for a in
            (state.tokens, state.cyc, state.refill_rate, state.bkt_size,
             jnp.maximum(state.interval, 1), state.mode,
             jnp.asarray(msg_cost), jnp.asarray(want).astype(jnp.int32))]
    tokens, cyc, admit = token_bucket_step_2d(
        jnp.asarray(elapsed_cycles, jnp.int32), *args, interpret=interpret)
    tokens = tokens.reshape(-1)[:n]
    cyc = cyc.reshape(-1)[:n]
    admit = admit.reshape(-1)[:n].astype(bool)
    return state._replace(tokens=tokens, cyc=cyc), admit
