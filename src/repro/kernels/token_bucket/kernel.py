"""Pallas TPU kernel: vectorized per-flow token-bucket update + admission.

This is the TPU-native analogue of Arcus's offloaded hardware rate limiter
(Sec. 4.2): shaping state lives on-device and one kernel invocation advances
*all* per-flow buckets by one shaping interval and decides admissions —
no host round-trip, no CPU interference, exactly like the paper's FPGA
mechanism runs off the host critical path.

Layout: flows are padded to R rows x 128 lanes (int32).  The grid tiles rows
in blocks of 8 (native (8, 128) int32 VMEM tiles); all state arrays share one
BlockSpec so a block holds 1024 flows.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 8
LANES = 128
FLOWS_PER_BLOCK = ROW_BLOCK * LANES


def default_interpret() -> bool:
    """Resolve the Pallas execution mode for this process.

    Only TPU backends compile this kernel (the (8, 128) int32 tiling and
    SMEM scalar block are TPU-shaped); everything else — CPU, and GPU
    where the Triton lowering was never validated — runs the kernel body
    in interpret mode.  ``REPRO_TB_INTERPRET=0/1`` overrides the
    auto-detection either way."""
    env = os.environ.get("REPRO_TB_INTERPRET")
    if env is not None:
        return env.strip().lower() not in ("0", "false", "no")
    return jax.default_backend() != "tpu"


def _tb_kernel(elapsed_ref, tokens_ref, cyc_ref, refill_ref, bkt_ref,
               interval_ref, mode_ref, cost_ref, want_ref,
               out_tokens_ref, out_cyc_ref, admit_ref):
    """One (8, 128) block of flows: refill timers, then admission."""
    elapsed = elapsed_ref[0]                      # scalar int32 (SMEM)
    tokens = tokens_ref[...]
    cyc = cyc_ref[...]
    refill = refill_ref[...]
    bkt = bkt_ref[...]
    interval = interval_ref[...]
    mode = mode_ref[...]

    # --- hardware timers: catch-up refills -----------------------------
    total = cyc + elapsed
    k = total // interval
    new_cyc = total % interval
    # clamp k so k * refill cannot overflow int32 after long stalls
    k = jnp.minimum(k, bkt // jnp.maximum(refill, 1) + 1)
    tokens = jnp.minimum(tokens + k * refill, bkt)

    # --- admission ------------------------------------------------------
    cost = jnp.where(mode == 0, cost_ref[...], 1)  # GBPS: bytes, IOPS: msgs
    want = want_ref[...] != 0
    ok = jnp.logical_and(want, tokens >= cost)
    tokens = jnp.where(ok, tokens - cost, tokens)

    out_tokens_ref[...] = tokens
    out_cyc_ref[...] = new_cyc
    admit_ref[...] = ok.astype(jnp.int32)


def token_bucket_step_2d(elapsed, tokens, cyc, refill, bkt, interval, mode,
                         cost, want, *, interpret: bool | None = None):
    """All inputs [R, 128] int32 with R % 8 == 0; elapsed scalar int32.

    ``interpret=None`` auto-detects: compiled on TPU, interpret elsewhere
    (override with REPRO_TB_INTERPRET).  Resolution happens here, outside
    the jit, so an env-var change takes effect on the next call instead of
    being frozen into the first trace."""
    if interpret is None:
        interpret = default_interpret()
    return _token_bucket_step_2d(elapsed, tokens, cyc, refill, bkt,
                                 interval, mode, cost, want,
                                 interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _token_bucket_step_2d(elapsed, tokens, cyc, refill, bkt, interval, mode,
                          cost, want, *, interpret: bool):
    R = tokens.shape[0]
    assert R % ROW_BLOCK == 0 and tokens.shape[1] == LANES
    grid = (R // ROW_BLOCK,)
    block = pl.BlockSpec((ROW_BLOCK, LANES), lambda i: (i, 0))
    out_shape = [jax.ShapeDtypeStruct((R, LANES), jnp.int32)] * 3
    return pl.pallas_call(
        _tb_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1,), lambda i: (0,))] + [block] * 8,
        out_specs=[block] * 3,
        out_shape=out_shape,
        interpret=interpret,
    )(jnp.asarray([elapsed], jnp.int32), tokens, cyc, refill, bkt, interval,
      mode, cost, want)
