"""Pure-jnp oracle for the token-bucket kernel.

Delegates to `repro.core.token_bucket` — the simulator's reference
semantics — so the kernel, the simulator, and the serving scheduler all
share one definition of the mechanism.
"""
from __future__ import annotations

from repro.core import token_bucket as tb


def token_bucket_step(tokens, cyc, refill_rate, bkt_size, interval, mode,
                      elapsed_cycles, msg_cost_bytes, want):
    """One shaping interval for N flows (any shape; elementwise).

    Returns (new_tokens, new_cyc, admitted)."""
    state = tb.TBState(tokens, cyc, refill_rate, bkt_size, interval, mode)
    state = tb.advance(state, elapsed_cycles)
    state, admitted = tb.try_admit(state, msg_cost_bytes, want)
    return state.tokens, state.cyc, admitted
