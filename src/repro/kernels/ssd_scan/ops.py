"""Jitted public wrapper for the SSD-scan Pallas kernel.

Broadcasts the G state groups to H heads, pads L to a chunk multiple with
neutral elements (a=1, x=0 — keeps the carried state intact), dispatches,
and unpads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_headmajor


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, a, B, C, *, chunk: int = 128, interpret: bool = True):
    """x [Bsz,L,H,P]; a [Bsz,L,H]; B, C [Bsz,L,G,N] ->
    (y [Bsz,L,H,P], final_state [Bsz,H,P,N])."""
    Bsz, L, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    head_group = (jnp.arange(H) * G) // H
    Bh = B[:, :, head_group]
    Ch = C[:, :, head_group]
    chunk = min(chunk, max(8, 1 << (L - 1).bit_length()))
    Lp = -(-L // chunk) * chunk
    if Lp != L:
        pad = ((0, 0), (0, Lp - L), (0, 0), (0, 0))
        x = jnp.pad(x, pad)
        Bh = jnp.pad(Bh, pad)
        Ch = jnp.pad(Ch, pad)
        a = jnp.pad(a, ((0, 0), (0, Lp - L), (0, 0)), constant_values=1.0)
    y, s = ssd_scan_headmajor(x, a, Bh, Ch, chunk=chunk, interpret=interpret)
    return y[:, :L], s
