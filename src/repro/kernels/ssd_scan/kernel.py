"""Pallas TPU kernel: Mamba2 SSD chunked scan (state-space duality).

TPU adaptation of the SSD algorithm (arXiv:2405.21060): instead of a
token-by-token recurrence (VPU-bound, sequential), the sequence is split
into chunks of L_C tokens.  Within a chunk the output is a masked
"attention-like" matmul (MXU work); across chunks only the [P, N] state is
carried — in VMEM scratch, while the grid walks (batch, head, chunk) with
the chunk axis innermost/sequential.

Per chunk (ca = cumulative log-decay inside the chunk):
    y_intra[i] = sum_{j<=i} exp(ca_i - ca_j) (C_i . B_j) x_j     (MXU)
    y_inter[i] = exp(ca_i) * C_i . S_prev                        (MXU)
    S_next     = exp(ca_last) S_prev + sum_j exp(ca_last - ca_j) B_j (x) x_j
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, sfin_ref, s_ref,
                *, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    x = x_ref[0, :, 0].astype(jnp.float32)      # [Lc, P]
    a = a_ref[0, :, 0].astype(jnp.float32)      # [Lc]
    B = b_ref[0, :, 0].astype(jnp.float32)      # [Lc, N]
    C = c_ref[0, :, 0].astype(jnp.float32)      # [Lc, N]

    la = jnp.log(jnp.maximum(a, 1e-37))
    ca = jnp.cumsum(la)                          # [Lc] inclusive
    Lc = x.shape[0]

    # ---- intra-chunk (masked attention-like) -------------------------
    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Lc, Lc]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Lc, Lc), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Lc, Lc), 1)
    decay = jnp.exp(ca[:, None] - ca[None, :])
    scores = jnp.where(ii >= jj, cb * decay, 0.0)
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [Lc, P]

    # ---- inter-chunk (carried state) ----------------------------------
    s_prev = s_ref[...]                          # [P, N]
    y_inter = jax.lax.dot_general(C, s_prev, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y = y + y_inter * jnp.exp(ca)[:, None]

    # ---- state update ---------------------------------------------------
    w = jnp.exp(ca[-1] - ca)[:, None] * B        # [Lc, N]
    s_new = s_prev * jnp.exp(ca[-1]) + jax.lax.dot_general(
        x, w, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    s_ref[...] = s_new

    y_ref[0, :, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _fin():
        sfin_ref[0, 0] = s_new.astype(sfin_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_headmajor(x, a, B, C, *, chunk: int = 128,
                       interpret: bool = True):
    """x [Bsz, L, H, P]; a [Bsz, L, H]; B, C [Bsz, L, H, N] (pre-broadcast
    from G groups to H heads).  L % chunk == 0.

    Returns (y [Bsz, L, H, P], final_state [Bsz, H, P, N])."""
    Bsz, L, H, P = x.shape
    N = B.shape[3]
    assert L % chunk == 0
    n_chunks = L // chunk
    grid = (Bsz, H, n_chunks)
    kernel = functools.partial(_ssd_kernel, n_chunks=n_chunks)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, L, H, P), x.dtype),
            jax.ShapeDtypeStruct((Bsz, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, a, B, C)
