"""Pure-jnp oracle for the Mamba2 SSD (state-space duality) scan.

Discrete-time selective-SSM recurrence, per batch b and head h
(group g = h * G // H):

    S_t = a[t,h] * S_{t-1} + B[t,g,:] (outer) x[t,h,:]     S in R^{P x N}
    y[t,h,:] = S_t @ C[t,g,:]

Inputs:
  x [B, L, H, P]   (Delta-scaled inputs)
  a [B, L, H]      decay factors in (0, 1] (= exp(Delta * A))
  B [B, L, G, N], C [B, L, G, N]
Returns (y [B, L, H, P], final_state [B, H, P, N]).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan(x, a, B, C):
    Bsz, L, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    head_group = (jnp.arange(H) * G) // H

    Bh = B[:, :, head_group]          # [B, L, H, N]
    Ch = C[:, :, head_group]          # [B, L, H, N]

    def step(S, inp):
        xt, at, Bt, Ct = inp           # [B,H,P], [B,H], [B,H,N], [B,H,N]
        S = S * at[..., None, None] + xt[..., :, None] * Bt[..., None, :]
        y = jnp.einsum("bhpn,bhn->bhp", S, Ct)
        return S, y

    S0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(a, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Bh, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Ch, 1, 0).astype(jnp.float32))
    S, ys = jax.lax.scan(step, S0, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)
    return y, S


def ssd_decode_step(state, x_t, a_t, B_t, C_t):
    """Single-token recurrence for serving decode.

    state [B, H, P, N]; x_t [B, H, P]; a_t [B, H]; B_t/C_t [B, G, N]."""
    H = x_t.shape[1]
    G = B_t.shape[1]
    head_group = (jnp.arange(H) * G) // H
    Bh = B_t[:, head_group]
    Ch = C_t[:, head_group]
    state = state * a_t[..., None, None] + \
        x_t[..., :, None] * Bh[..., None, :]
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch)
    return state, y.astype(x_t.dtype)
