"""Pure-jnp oracle for the flash-prefill kernel: naive masked softmax
attention (materializes [Sq, Sk] — test sizes only)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_prefill(q, k, v, *, window: int = 0, chunk_size: int = 0,
                  causal: bool = True):
    """q [B, Sq, H, D]; k, v [B, Sk, KvH, D] -> [B, Sq, H, D]."""
    B, Sq, H, D = q.shape
    Sk, KvH = k.shape[1], k.shape[2]
    G = H // KvH
    qg = q.reshape(B, Sq, KvH, G, D).astype(jnp.float32)
    s = jnp.einsum("bqnhd,bknd->bqnhk", qg,
                   k.astype(jnp.float32)) * D ** -0.5
    qi = jnp.arange(Sq)[:, None]
    ki = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qi >= ki
        if window > 0:
            mask &= qi - ki < window
        if chunk_size > 0:
            mask &= (qi // chunk_size) == (ki // chunk_size)
    s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    o = jnp.einsum("bqnhk,bknd->bqnhd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)
