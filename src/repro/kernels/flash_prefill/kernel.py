"""Pallas TPU kernel: flash attention for prefill/train (causal GQA,
optional sliding-window / chunked-local masks).

Grid = (B * KvH, Sq // BQ, Sk // BK) with the KV axis innermost and
sequential: a [BQ, D] query tile stays resident in VMEM while [BK, D]
K/V tiles stream HBM->VMEM; running (m, l, acc) live in VMEM scratch.
Causal masking is block-level: fully-masked KV blocks short-circuit via
pl.when (no MXU work), the diagonal block applies the element mask.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bk: int, n_kv: int, window: int,
                  chunk_size: int, scale: float, causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * bq
    k_start = ki * bk
    # block-level reachability: skip blocks fully above the causal
    # diagonal or fully outside the window/chunk
    reachable = True
    if causal:
        reachable = k_start <= q_start + bq - 1
        if window > 0:
            reachable &= k_start + bk - 1 > q_start - window
        if chunk_size > 0:
            reachable &= (k_start // chunk_size) == \
                ((q_start + bq - 1) // chunk_size)

    @pl.when(reachable)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                 # [BQ, D]
        k = k_ref[0].astype(jnp.float32)                 # [BK, D]
        v = v_ref[0].astype(jnp.float32)                 # [BK, D]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (bq, bk), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (bq, bk), 1)
            ok = qpos >= kpos
            if window > 0:
                ok &= qpos - kpos < window
            if chunk_size > 0:
                ok &= (qpos // chunk_size) == (kpos // chunk_size)
            s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[:, 0]
        m_cur = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        if causal:
            p = jnp.where(ok, p, 0.0)
        l_ref[...] = jnp.broadcast_to(
            (l_ref[:, 0] * alpha + p.sum(axis=1))[:, None], l_ref.shape)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_cur[:, None], m_ref.shape)

    @pl.when(ki == n_kv - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bk", "window",
                                             "chunk_size", "scale",
                                             "causal", "interpret"))
def flash_prefill_flat(q, k, v, *, bq: int = 128, bk: int = 128,
                       window: int = 0, chunk_size: int = 0,
                       scale: float = 1.0, causal: bool = True,
                       interpret: bool = True):
    """q [N, Sq, D]; k, v [N, Sk, D] with N = B * KvH * G query streams
    already matched to their KV stream -> [N, Sq, D].
    Sq % bq == 0, Sk % bk == 0, D % 128 == 0 (ops.py pads)."""
    N, Sq, D = q.shape
    Sk = k.shape[1]
    assert Sq % bq == 0 and Sk % bk == 0
    grid = (N, Sq // bq, Sk // bk)
    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, n_kv=Sk // bk, window=window,
        chunk_size=chunk_size, scale=scale, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda n, i, j: (n, i, 0)),
            pl.BlockSpec((1, bk, D), lambda n, i, j: (n, j, 0)),
            pl.BlockSpec((1, bk, D), lambda n, i, j: (n, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda n, i, j: (n, i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
