"""Jitted public wrapper for the flash-prefill Pallas kernel.

GQA head matching (each query head streams against its KV group's cache),
plus TPU tile padding: Sq/Sk to block multiples, D to 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_prefill.kernel import flash_prefill_flat


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit, static_argnames=("window", "chunk_size",
                                             "causal", "bq", "bk",
                                             "interpret"))
def flash_prefill(q, k, v, *, window: int = 0, chunk_size: int = 0,
                  causal: bool = True, bq: int = 128, bk: int = 128,
                  interpret: bool = True):
    """q [B, Sq, H, D]; k, v [B, Sk, KvH, D] -> [B, Sq, H, D]."""
    B, Sq, H, D = q.shape
    Sk, KvH = k.shape[1], k.shape[2]
    assert H % KvH == 0
    G = H // KvH
    scale = D ** -0.5

    bq = min(bq, _round_up(Sq, 8))
    bk = min(bk, _round_up(Sk, 8))
    Sqp, Skp, Dp = _round_up(Sq, bq), _round_up(Sk, bk), _round_up(D, 128)

    qp = jnp.pad(q, ((0, 0), (0, Sqp - Sq), (0, 0), (0, Dp - D)))
    kp = jnp.pad(k, ((0, 0), (0, Skp - Sk), (0, 0), (0, Dp - D)))
    vp = jnp.pad(v, ((0, 0), (0, Skp - Sk), (0, 0), (0, Dp - D)))

    # flatten: query stream n = (b, kvh, g); its KV stream is (b, kvh).
    # qp transpose gives (b, h) order = (b, kvh, g) because heads are laid
    # out kv-major in the model (h = kvh * G + g)
    qf = qp.transpose(0, 2, 1, 3).reshape(B * H, Sqp, Dp)
    kf = jnp.repeat(kp.transpose(0, 2, 1, 3), G, axis=1) \
        .reshape(B * H, Skp, Dp)
    vf = jnp.repeat(vp.transpose(0, 2, 1, 3), G, axis=1) \
        .reshape(B * H, Skp, Dp)

    out = flash_prefill_flat(qf, kf, vf, bq=bq, bk=bk, window=window,
                             chunk_size=chunk_size, scale=scale,
                             causal=causal, interpret=interpret)
    out = out.reshape(B, H, Sqp, Dp).transpose(0, 2, 1, 3)
    return out[:, :Sq, :, :D]


# padded KV columns are only excluded by the causal mask; non-causal use
# requires exact tiling (encoder paths use the jnp flash implementation)
