"""Batched serving engine: slot-managed KV cache + prefill/decode steps.

The engine is the "accelerator" of the TPU adaptation: tenants' request
streams are the flows, and the Arcus scheduler (scheduler.py) shapes what
enters each engine step.  Continuous batching: prefill one request at a
time into a free slot, decode all active slots together.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ArchConfig
from repro.serving.request import Request


def _scatter_cache(batch_cache, one_cache, slot: int):
    """Write a B=1 prefill cache into batch slot `slot`.
    blocks leaves: [reps, B, ...] (batch axis 1); tail leaves: [B, ...]."""
    def blocks_leaf(cb, c1):
        return cb.at[:, slot].set(c1[:, 0].astype(cb.dtype))

    def tail_leaf(cb, c1):
        return cb.at[slot].set(c1[0].astype(cb.dtype))

    new_blocks = jax.tree.map(blocks_leaf, batch_cache["blocks"],
                              one_cache["blocks"])
    new_tail = jax.tree.map(tail_leaf, batch_cache["tail"],
                            one_cache["tail"])
    return {"blocks": new_blocks, "tail": new_tail}


@dataclasses.dataclass
class ServingEngine:
    cfg: ArchConfig
    params: Any
    max_batch: int
    max_len: int
    cache_dtype: Any = jnp.float32
    greedy: bool = True

    def __post_init__(self):
        self.cache = T.init_cache(self.cfg, self.max_batch, self.max_len,
                                  self.cache_dtype)
        self.lengths = np.zeros(self.max_batch, np.int32)
        self.active = np.zeros(self.max_batch, bool)
        self.requests: dict[int, Request] = {}
        self._decode = jax.jit(
            lambda p, tok, ln, cache: T.decode_step(p, self.cfg, tok, ln,
                                                    cache))
        self._prefill = jax.jit(
            lambda p, tok, cache, fe: T.prefill(p, self.cfg, tok, cache, fe))

    # ------------------------------------------------------------------
    def free_slots(self) -> list[int]:
        return [i for i in range(self.max_batch) if not self.active[i]]

    def admit(self, req: Request, frontend=None) -> int:
        """Prefill one request into a free slot. Returns the slot."""
        slot = self.free_slots()[0]
        tokens = jnp.asarray(np.asarray(req.prompt, np.int32)[None, :])
        one = T.init_cache(self.cfg, 1, self.max_len, self.cache_dtype)
        logits, one, _ = self._prefill(self.params, tokens, one, frontend)
        tok = int(jnp.argmax(logits[0]))
        self.cache = _scatter_cache(self.cache, one, slot)
        self.lengths[slot] = len(req.prompt)
        self.active[slot] = True
        req.slot = slot
        req.generated.append(tok)
        self.requests[req.req_id] = req
        # account the first generated token's cache entry on next decode
        return slot

    def step(self) -> dict[int, int]:
        """One decode step over all active slots.
        Returns {req_id: new_token}."""
        if not self.active.any():
            return {}
        last = np.zeros((self.max_batch, 1), np.int32)
        for r in self.requests.values():
            if r.slot >= 0 and r.generated:
                last[r.slot, 0] = r.generated[-1]
        logits, self.cache = self._decode(
            self.params, jnp.asarray(last),
            jnp.asarray(self.lengths), self.cache)
        toks = np.asarray(jnp.argmax(logits, -1))
        out = {}
        for rid, r in list(self.requests.items()):
            if r.slot < 0:
                continue
            self.lengths[r.slot] += 1
            tok = int(toks[r.slot])
            r.generated.append(tok)
            out[rid] = tok
            if r.done:
                self.active[r.slot] = False
                r.slot = -1
                del self.requests[rid]
        return out

    @property
    def active_count(self) -> int:
        return int(self.active.sum())
