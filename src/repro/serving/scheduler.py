"""Arcus-shaped continuous-batching scheduler.

The paper's protocol mapped onto serving (DESIGN.md §2):

  * flow         = one tenant's request stream into one engine
  * PatternA     = tenant-chosen submission times (untrusted)
  * PatternA'    = what actually enters engine steps — decided here, by
                   per-tenant token buckets (tokens/s = the SLO), exactly
                   the paper's proactive "rate transformation"
  * hardware mechanism = vectorized token buckets advanced on the virtual
                   clock; state can also be stepped by the Pallas kernel
                   (kernels.token_bucket) as the on-device analogue
  * per-flow counters = tokens served / latency per tenant, read by the
                   SLO monitor which re-writes bucket registers.

Baselines: an unshaped FCFS scheduler (head-of-line large tenants steal
decode slots — the serving analogue of Host_noTS).
The clock is the roofline StepCostModel (CPU wall time is meaningless for
the TPU target).
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.core import token_bucket as tb
from repro.core.flow import SLOKind
from repro.serving.costmodel import StepCostModel
from repro.serving.engine import ServingEngine
from repro.serving.request import Request, Tenant

CLOCK_HZ = 1e9  # virtual bucket clock: 1 cycle = 1 ns


@dataclasses.dataclass
class TenantStats:
    served_tokens: int = 0
    finished: int = 0
    ttft: list = dataclasses.field(default_factory=list)
    tpot: list = dataclasses.field(default_factory=list)  # per-token latency
    window_tps: list = dataclasses.field(default_factory=list)


class ArcusScheduler:
    """Shaped continuous batching with per-tenant SLO buckets."""

    def __init__(self, engine: ServingEngine, tenants: list[Tenant],
                 cost_model: StepCostModel, *, shaped: bool = True,
                 monitor_window_s: float = 0.25, use_kernel: bool = False):
        self.engine = engine
        self.tenants = {t.tenant_id: t for t in tenants}
        self.cost = cost_model
        self.shaped = shaped
        self.use_kernel = use_kernel
        self.queues: dict[int, deque[Request]] = \
            {t.tenant_id: deque() for t in tenants}
        self.now_s = 0.0
        plans = []
        for t in tenants:
            if shaped and t.slo.kind == SLOKind.IOPS:
                # SLO is tokens/s; the bucket is denominated in tokens
                # (GBPS-mode semantics: admission cost = prompt tokens).
                p = tb.params_for_iops(t.slo.target, CLOCK_HZ)
                plans.append(tb.TBParams(p.refill_rate,
                                         max(4096, 8 * p.refill_rate),
                                         p.interval, tb.MODE_GBPS))
            else:
                big = 2 ** 30
                plans.append(tb.TBParams(big, big, 1, tb.MODE_GBPS))
        self._tenant_order = [t.tenant_id for t in tenants]
        self.buckets = tb.pack(plans)
        self.stats = {t.tenant_id: TenantStats() for t in tenants}
        self.all_reqs: dict[int, Request] = {}
        self._last_monitor = 0.0
        self._last_served = np.zeros(len(tenants), np.int64)

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        req.arrive_s = max(req.arrive_s, self.now_s)
        self.queues[req.tenant_id].append(req)
        self.all_reqs[req.req_id] = req

    def _advance_buckets(self, dt_s: float):
        cycles = int(dt_s * CLOCK_HZ)
        if self.use_kernel:
            from repro.kernels.token_bucket import ops as tb_ops
            n = self.buckets.tokens.shape[0]
            self.buckets, _ = tb_ops.token_bucket_step(
                self.buckets, cycles, np.zeros(n, np.int32),
                np.zeros(n, bool))
        else:
            self.buckets = tb.advance(self.buckets, cycles)

    def _try_consume(self, tenant_idx: int, tokens: int) -> bool:
        toks = np.asarray(self.buckets.tokens)
        if not self.shaped:
            return True
        if toks[tenant_idx] >= tokens:
            self.buckets = self.buckets._replace(
                tokens=self.buckets.tokens.at[tenant_idx].add(-tokens))
            return True
        return False

    # ------------------------------------------------------------------
    def step(self) -> float:
        """One scheduling round: admit prefills (shaped), one decode step.
        Returns the virtual time consumed."""
        t0 = self.now_s
        # --- admission: shaped prefill entry ---------------------------
        # Arcus: tenant-ordered, gated by each tenant's bucket.
        # Unshaped (FCFS): strict global arrival order — an early greedy
        # tenant's backlog runs first.
        if self.shaped:
            order = [(i, tid) for i, tid in enumerate(self._tenant_order)]
        else:
            heads = [(self.queues[tid][0].arrive_s, i, tid)
                     for i, tid in enumerate(self._tenant_order)
                     if self.queues[tid]]
            order = [(i, tid) for _, i, tid in sorted(heads)]
        for i, tid in order:
            q = self.queues[tid]
            while q and self.engine.free_slots():
                req = q[0]
                if req.arrive_s > self.now_s:
                    break  # not yet arrived (queues are FIFO per tenant)
                need = len(req.prompt)
                if not self._try_consume(i, need):
                    break
                q.popleft()
                self.engine.admit(req)
                dt = self.cost.prefill_s(1, need)
                self.now_s += dt
                self._advance_buckets(dt)
                req.prefill_done_s = self.now_s
                req.first_token_s = self.now_s
                st = self.stats[tid]
                st.ttft.append(self.now_s - req.arrive_s)
                st.served_tokens += 1  # first token from prefill

        # --- decode ------------------------------------------------------
        if self.engine.active_count:
            ctx = int(np.max(self.engine.lengths[self.engine.active])) \
                if self.engine.active.any() else 0
            produced = self.engine.step()
            dt = self.cost.decode_s(max(self.engine.active_count, 1), ctx)
            self.now_s += dt
            self._advance_buckets(dt)
            by_tenant: dict[int, int] = {}
            for rid in produced:
                req = self.all_reqs.get(rid)
                if req is None:
                    continue
                by_tenant[req.tenant_id] = by_tenant.get(req.tenant_id, 0) + 1
                if req.done and not np.isfinite(req.finish_s):
                    req.finish_s = self.now_s
                    self.stats[req.tenant_id].finished += 1
            for tid, n in by_tenant.items():
                st = self.stats[tid]
                st.served_tokens += n
                st.tpot.append(dt)
        else:
            self.now_s += 1e-4
            self._advance_buckets(1e-4)

        self._monitor()
        return self.now_s - t0

    def _monitor(self):
        """The Algorithm-1 loop: read counters each window, check SLOs,
        re-write bucket registers if violated."""
        if self.now_s - self._last_monitor < 0.25:
            return
        window = self.now_s - self._last_monitor
        served = np.asarray([self.stats[t].served_tokens
                             for t in self._tenant_order], np.int64)
        rate = (served - self._last_served) / window
        for i, tid in enumerate(self._tenant_order):
            self.stats[tid].window_tps.append(float(rate[i]))
        self._last_served = served
        self._last_monitor = self.now_s

    # ------------------------------------------------------------------
    def run(self, duration_s: float, *, max_rounds: int = 100_000):
        rounds = 0
        while self.now_s < duration_s and rounds < max_rounds:
            self.step()
            rounds += 1
        return self.stats


class FCFSScheduler(ArcusScheduler):
    """Unshaped baseline (Host_noTS analogue): admission is first-come
    first-served; an aggressive tenant's long prompts monopolize slots."""

    def __init__(self, engine, tenants, cost_model, **kw):
        super().__init__(engine, tenants, cost_model, shaped=False, **kw)
