"""Serving request/tenant structures."""
from __future__ import annotations

import dataclasses

from repro.core.flow import SLO


@dataclasses.dataclass
class Tenant:
    tenant_id: int
    slo: SLO                      # tokens/s (IOPS kind) guarantee
    policy: str = "reserved"      # reserved | on_demand | managed_burst | opportunistic
    weight: float = 1.0


@dataclasses.dataclass
class Request:
    req_id: int
    tenant_id: int
    prompt: "list[int]"
    max_new_tokens: int
    arrive_s: float = 0.0
    # runtime state
    slot: int = -1
    generated: "list[int]" = dataclasses.field(default_factory=list)
    prefill_done_s: float = float("nan")
    finish_s: float = float("nan")
    first_token_s: float = float("nan")

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens
