"""Roofline-based step-cost model: the serving scheduler's virtual clock.

This container is CPU-only, so wall-clock timing of an engine step says
nothing about the TPU target.  Instead the scheduler advances time by a
roofline estimate — max(compute, memory) term per step on the target
hardware (per-chip v5e numbers, scaled by chip count).  This mirrors how
Arcus's profiler learns accelerator service curves offline: here the
"accelerator" is the TPU model executor and the curve is analytic.
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ArchConfig

TPU_V5E = dict(flops=197e12, hbm=819e9, ici=50e9)  # per chip, bf16


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    chips: int = 1
    flops: float = TPU_V5E["flops"]
    hbm: float = TPU_V5E["hbm"]
    mfu: float = 0.5      # attainable fraction of peak compute
    mbu: float = 0.7      # attainable fraction of peak bandwidth


def param_bytes(cfg: ArchConfig, active_only: bool = True) -> float:
    """Approximate (active) parameter bytes touched per token (bf16)."""
    E, F, L, V = cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.vocab
    H, KvH, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    per_layer = 0.0
    kinds = cfg.layer_kinds()
    for i, kind in enumerate(kinds):
        if kind in ("global", "local", "chunk", "cross"):
            per_layer += E * H * Dh + 2 * E * KvH * Dh + H * Dh * E
        elif kind == "rglru":
            W = cfg.lru_width or E
            per_layer += 2 * E * W + 2 * W * W + W * E
        elif kind == "ssd":
            Din = cfg.d_inner_mult * E
            G, N = cfg.ssm_groups, cfg.ssm_state
            per_layer += E * (2 * Din + 2 * G * N + Din // cfg.ssm_head_dim) \
                + Din * E
        if cfg.d_ff > 0:
            g = 3 if cfg.gated_mlp else 2
            if cfg.is_moe_layer(i):
                k = max(cfg.top_k, 1) if active_only else cfg.n_experts
                per_layer += k * g * E * F
            else:
                per_layer += g * E * F
    # + unembedding matrix (touched once per step)
    return 2.0 * per_layer + 2.0 * E * V


def flops_per_token(cfg: ArchConfig, context: int) -> float:
    """~2 * active-params + attention FLOPs at the given KV context."""
    base = param_bytes(cfg)  # bf16 bytes = 2*params -> FLOPs = 2*params
    attn = 0.0
    for kind in cfg.layer_kinds():
        if kind == "global":
            attn += 2 * 2 * cfg.n_heads * cfg.head_dim_ * context
        elif kind in ("local", "chunk"):
            attn += 2 * 2 * cfg.n_heads * cfg.head_dim_ * \
                min(context, cfg.window)
    return base + attn


def kv_bytes_per_token(cfg: ArchConfig, context: int) -> float:
    """KV-cache bytes read per decoded token."""
    b = 0.0
    for kind in cfg.layer_kinds():
        if kind == "global":
            b += 2 * cfg.n_kv_heads * cfg.head_dim_ * context * 2
        elif kind in ("local", "chunk"):
            b += 2 * cfg.n_kv_heads * cfg.head_dim_ * \
                min(context, cfg.window) * 2
        elif kind == "ssd":
            Din = cfg.d_inner_mult * cfg.d_model
            b += (Din // cfg.ssm_head_dim) * cfg.ssm_head_dim \
                * cfg.ssm_state * 4
        elif kind == "rglru":
            b += (cfg.lru_width or cfg.d_model) * 4
    return b


@dataclasses.dataclass(frozen=True)
class StepCostModel:
    cfg: ArchConfig
    hw: HardwareSpec = HardwareSpec()

    def prefill_s(self, batch: int, seq: int) -> float:
        fl = flops_per_token(self.cfg, seq // 2) * batch * seq
        t_c = fl / (self.hw.chips * self.hw.flops * self.hw.mfu)
        wb = param_bytes(self.cfg)
        t_m = wb / (self.hw.chips * self.hw.hbm * self.hw.mbu)
        return max(t_c, t_m)

    def decode_s(self, batch: int, context: int) -> float:
        fl = flops_per_token(self.cfg, context) * batch
        t_c = fl / (self.hw.chips * self.hw.flops * self.hw.mfu)
        bytes_ = param_bytes(self.cfg) \
            + kv_bytes_per_token(self.cfg, context) * batch
        t_m = bytes_ / (self.hw.chips * self.hw.hbm * self.hw.mbu)
        return max(t_c, t_m)
