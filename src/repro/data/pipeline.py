"""Deterministic synthetic LM data pipeline.

Generates a seeded Zipf-distributed token stream with injected local
structure (repeated n-grams) so the loss is learnable, packs it into
[global_batch, seq_len] examples with masks, and iterates host-side numpy
batches (device placement is the trainer's job).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    ngram_frac: float = 0.3    # fraction of positions covered by n-grams
    ngram_len: int = 8
    n_ngrams: int = 256


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self._ngrams = rng.integers(
            2, cfg.vocab, (cfg.n_ngrams, cfg.ngram_len)).astype(np.int32)

    def _sample_tokens(self, rng: np.random.Generator, n: int) -> np.ndarray:
        c = self.cfg
        toks = rng.zipf(c.zipf_a, n).astype(np.int64) % (c.vocab - 2) + 2
        # paste n-grams over random spans: learnable local structure
        n_spans = int(n * c.ngram_frac / c.ngram_len)
        if n_spans:
            starts = rng.integers(0, max(n - c.ngram_len, 1), n_spans)
            which = rng.integers(0, c.n_ngrams, n_spans)
            for s, w in zip(starts, which):
                toks[s:s + c.ngram_len] = self._ngrams[w]
        return toks.astype(np.int32)

    def batches(self, *, start_step: int = 0) -> Iterator[dict]:
        c = self.cfg
        step = start_step
        while True:
            rng = np.random.default_rng((c.seed, step))
            n = c.global_batch * c.seq_len
            toks = self._sample_tokens(rng, n)
            tokens = toks.reshape(c.global_batch, c.seq_len)
            mask = np.ones_like(tokens, np.int32)
            yield {"tokens": tokens, "mask": mask, "step": step}
            step += 1


def frontend_stub(kind: str, batch: int, length: int, dim: int,
                  seed: int = 0) -> np.ndarray:
    """Precomputed frame/patch embeddings for [audio]/[vlm] frontends —
    the one sanctioned stub: deterministic pseudo-embeddings with realistic
    scale and smoothness."""
    rng = np.random.default_rng((hash(kind) & 0xFFFF, seed))
    x = rng.standard_normal((batch, length, dim)).astype(np.float32)
    # temporal smoothing: neighboring frames/patches correlate
    k = 5
    kern = np.hanning(k)[None, :, None]
    kern = kern / kern.sum()
    pad = np.pad(x, ((0, 0), (k // 2, k // 2), (0, 0)), mode="edge")
    sm = sum(pad[:, i:i + length] * kern[:, i] for i in range(k))
    return sm.astype(np.float32)
