"""Optional activation-sharding constraints (perf pass).

XLA's SPMD propagation sometimes prefers all-reducing a multi-GB
activation over all-gathering a few-MB weight shard (observed on llama4
prefill: f32[1M, 8192] MLP hiddens all-reduced across "data", 128 GiB per
layer, because the FSDP-sharded contracting dim conflicts with the
batch-sharded output).  Layers consult this module and, when enabled,
pin their hidden activations to P(batch_axes, ..., "model") so the
partitioner gathers weights instead.

Disabled by default so models stay mesh-agnostic (CPU tests run without
any mesh).
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

_STATE = {"enabled": False, "dp": ("data",)}


def enable(dp=("data",)) -> None:
    _STATE["enabled"] = True
    _STATE["dp"] = tuple(dp)


def disable() -> None:
    _STATE["enabled"] = False


def constrain_hidden(h, *, batch_dims: int = 2, model_dim: bool = True):
    """h [B, S, ..., F]: pin batch to dp axes and the trailing (FFN/head)
    dim to "model"; middle dims replicated."""
    if not _STATE["enabled"]:
        return h
    spec = [None] * h.ndim
    spec[0] = _STATE["dp"]
    if model_dim:
        spec[-1] = "model"
    return jax.lax.with_sharding_constraint(h, P(*spec))


def gathered_weight(w, *, model_dim: int | None = -1):
    """Pin a weight to its all-gathered form (FSDP dims replicated, TP dim
    kept on "model") at the use site: a few-MB weight gather beats a
    multi-GB activation all-reduce when the FSDP-sharded contracting dim
    collides with the batch-sharded output."""
    if not _STATE["enabled"]:
        return w
    spec = [None] * w.ndim
    if model_dim is not None:
        spec[model_dim] = "model"
    return jax.lax.with_sharding_constraint(w, P(*spec))
