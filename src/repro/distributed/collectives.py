"""Hand-scheduled collectives for the perf pass (beyond-paper).

`seq_sharded_decode_attention`: decode attention with the KV cache
sequence dimension sharded across a mesh axis.  Each shard computes a
partial flash-softmax over its local KV slice; partials combine with one
pmax + two psums of [B, H(, D)] — instead of letting XLA's SPMD
partitioner all-gather (or "involuntarily fully rematerialize") the
multi-GB KV cache.  Used for long_500k global-attention layers
(batch = 1 leaves no batch axis to shard).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def make_seq_sharded_decode_attn(mesh: Mesh, axis: str = "data",
                                 batch_axis: str | None = None,
                                 d_axis: str | None = None):
    """Returns fn(q, k, v, lengths, *, window=0) -> [B, H, D].

    k, v [B, S, KvH, D] sharded on S over `axis` (and on B over
    `batch_axis` if given — decode_32k shards B over "data" while S rides
    "model"); q [B, H, D] and lengths [B] follow the batch sharding.

    d_axis: additionally shard head_dim over that axis (used when
    batch_axis is free, e.g. long_500k's batch=1): each shard computes a
    D-partial score contribution, psum(scores, d_axis) completes them,
    then the usual partial-softmax combine runs over `axis`.  Removes the
    d_axis-fold compute redundancy of the 1D version.
    """
    bp = batch_axis

    def local_fn(q, k, v, lengths, *, window: int):
        B, H, D_loc = q.shape
        S_loc, KvH = k.shape[1], k.shape[2]
        G = H // KvH
        full_d = D_loc * (mesh.shape[d_axis] if d_axis else 1)
        scale = full_d ** -0.5
        shard = jax.lax.axis_index(axis)
        offset = shard * S_loc

        qg = q.reshape(B, KvH, G, D_loc).astype(jnp.float32)
        s = jnp.einsum("bngd,bsnd->bngs", qg,
                       k.astype(jnp.float32)) * scale    # [B,KvH,G,S_loc]
        if d_axis:
            s = jax.lax.psum(s, d_axis)                   # complete scores
        idx = offset + jnp.arange(S_loc)
        ln = lengths[:, None]
        valid = idx[None, :] < ln
        if window > 0:
            valid &= idx[None, :] >= ln - window
        s = jnp.where(valid[:, None, None, :], s, -1e30)
        m_loc = s.max(-1)                                 # [B,KvH,G]
        p = jnp.exp(s - m_loc[..., None])
        p = jnp.where(valid[:, None, None, :], p, 0.0)
        l_loc = p.sum(-1)
        acc = jnp.einsum("bngs,bsnd->bngd", p, v.astype(jnp.float32))

        # partial-softmax combine across seq shards (acc stays D-sharded)
        m_glob = jax.lax.pmax(m_loc, axis)
        corr = jnp.exp(m_loc - m_glob)
        l = jax.lax.psum(l_loc * corr, axis)
        acc = jax.lax.psum(acc * corr[..., None], axis)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.reshape(B, H, D_loc).astype(q.dtype)

    def fn(q, k, v, lengths, *, window: int = 0):
        f = functools.partial(local_fn, window=window)
        dsp = d_axis  # None -> replicated D
        return shard_map(
            f, mesh=mesh,
            in_specs=(P(bp, None, dsp), P(bp, axis, None, dsp),
                      P(bp, axis, None, dsp), P(bp)),
            out_specs=P(bp, None, dsp),
            check_rep=False,
        )(q, k, v, lengths)

    return fn


def make_seq_sharded_cache_update(mesh: Mesh, axis: str = "data",
                                  batch_axis: str | None = None,
                                  d_axis: str | None = None):
    """Scatter one new K/V token into the seq-sharded cache without
    gathering it: only the owning shard writes."""
    bp = batch_axis

    def local_fn(cache_k, cache_v, k_new, v_new, slot):
        S_loc = cache_k.shape[1]
        shard = jax.lax.axis_index(axis)
        local_slot = slot - shard * S_loc
        in_range = (local_slot >= 0) & (local_slot < S_loc)
        idx = jnp.clip(local_slot, 0, S_loc - 1)
        B = cache_k.shape[0]
        b = jnp.arange(B)
        ck = cache_k.at[b, idx].set(
            jnp.where(in_range[:, None, None],
                      k_new.astype(cache_k.dtype), cache_k[b, idx]))
        cv = cache_v.at[b, idx].set(
            jnp.where(in_range[:, None, None],
                      v_new.astype(cache_v.dtype), cache_v[b, idx]))
        return ck, cv

    def fn(cache_k, cache_v, k_new, v_new, slot):
        dsp = d_axis
        return shard_map(
            local_fn, mesh=mesh,
            in_specs=(P(bp, axis, None, dsp), P(bp, axis, None, dsp),
                      P(bp, None, dsp), P(bp, None, dsp), P(bp)),
            out_specs=(P(bp, axis, None, dsp),
                       P(bp, axis, None, dsp)),
            check_rep=False,
        )(cache_k, cache_v, k_new, v_new, slot)

    return fn
