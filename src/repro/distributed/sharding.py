"""Sharding rules: logical param axes -> mesh axes.

Every parameter leaf carries a tuple of logical axis names (see
repro.models.module).  `param_shardings` resolves them to NamedShardings
under a rules dict, *dropping* any assignment whose dimension is not
divisible by the mesh axis size (e.g. seamless-m4t's vocab 256206 on a
16-way model axis falls back to replication) — mixed-divisibility
architectures therefore always lower.

Default placement (single-pod mesh ("data", "model")):
  * "embed" (d_model dims of weights)          -> "data"   (FSDP-style)
  * "vocab" / "heads" / "mlp" / "head_dim"     -> "model"  (megatron TP)
  * experts: llama4 (128) shards experts on "model"; mixtral (8 < 16)
    shards the expert FFN dim instead (see rules_for_config).
Multi-pod mesh ("pod", "data", "model"): weights are replicated across
pods (pure data parallelism on the "pod" axis); the batch shards over
("pod", "data").
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ArchConfig

BASE_RULES: dict[str, Any] = {
    "embed": "data",
    "vocab": "model",
    "heads": "model",
    "heads_flat": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "mlp2": None,
    "gate": None,
    "experts": "model",
    "expert_mlp": None,
    "conv": None,
    "layers": None,
    "frontend": None,
}


def rules_for_config(cfg: ArchConfig) -> dict[str, Any]:
    rules = dict(BASE_RULES)
    if cfg.n_experts:
        # moe weights use ("experts", "embed", ..., "mlp"); pick the axis
        # that divides: many-expert models shard experts, few-expert models
        # shard the expert FFN dim (handled generically by the divisibility
        # fallback, but made explicit here so both never collide on "model")
        if cfg.n_experts >= 16:
            rules["experts"] = "model"
            rules["expert_mlp"] = None
        else:
            rules["experts"] = None
            rules["expert_mlp"] = "model"
    return rules


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def spec_for(axes: tuple, shape: tuple, mesh: Mesh, rules: dict) -> P:
    parts = []
    for name, dim in zip(axes, shape):
        ax = rules.get(name)
        if ax is not None and dim % _axis_size(mesh, ax) != 0:
            ax = None  # divisibility fallback -> replicate this dim
        parts.append(ax)
    return P(*parts)


def param_shardings(axes_tree, shapes_tree, mesh: Mesh,
                    rules: dict) -> Any:
    """axes_tree: twin tree of logical-axis tuples; shapes_tree: twin tree
    of jax.ShapeDtypeStruct (or arrays)."""
    def leaf(axes, arr):
        return NamedSharding(mesh, spec_for(axes, arr.shape, mesh, rules))
    return jax.tree.map(leaf, axes_tree, shapes_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def batch_axes(mesh: Mesh):
    """Mesh axes carrying the global batch."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def data_spec(mesh: Mesh, ndim: int, batch: int | None = None) -> P:
    """[B, ...] arrays: batch over (pod, data); replicated if indivisible
    (e.g. long_500k's global_batch=1)."""
    axes = batch_axes(mesh)
    if batch is not None:
        n = int(np.prod([mesh.shape[a] for a in axes]))
        if batch % n != 0:
            return P(*([None] * ndim))
    return P(axes, *([None] * (ndim - 1)))


def cache_spec(mesh: Mesh, path_keys: tuple[str, ...], shape: tuple,
               cfg: ArchConfig, *, stacked: bool,
               seq_axis: str | None = None) -> P:
    """Sharding for one serving-cache leaf, identified by its dict key.

    KV caches [.., B, S, KvH, Dh]: default — batch over (pod,data),
    kv-heads over "model" when divisible else head_dim over "model".
    seq_axis = "data": long-context (batch=1) shards S over data.
    seq_axis = "model": perf variant — S over model, batch over data
    (pairs with distributed partial-softmax decode attention).
    States: batch over (pod,data); wide dims over "model" when divisible.
    """
    name = path_keys[-1]
    lead = (None,) if stacked else ()
    dp = batch_axes(mesh)
    model_n = mesh.shape["model"]
    no_batch = seq_axis == "data"   # batch=1 long-context regime
    if name in ("k", "v", "xk", "xv"):
        B, S, KvH, Dh = shape[-4:]
        if seq_axis and S % mesh.shape[seq_axis] == 0:
            b_ax = None if no_batch else dp
            hd_ax = "model" if (seq_axis != "model"
                                and Dh % model_n == 0) else None
            return P(*lead, b_ax, seq_axis, None, hd_ax)
        kv_ax = "model" if KvH % model_n == 0 else None
        hd_ax = None if kv_ax else ("model" if Dh % model_n == 0 else None)
        return P(*lead, None if no_batch else dp, None, kv_ax, hd_ax)
    if name == "state":   # ssd state [.., B, H, P, N]
        H = shape[-3]
        h_ax = "model" if H % model_n == 0 else None
        return P(*lead, None if no_batch else dp, h_ax, None, None)
    if name == "h":       # rglru hidden [.., B, W]
        W = shape[-1]
        return P(*lead, None if no_batch else dp,
                 "model" if W % model_n == 0 else None)
    if name == "conv":    # conv state [.., B, K-1, W]
        W = shape[-1]
        return P(*lead, None if no_batch else dp, None,
                 "model" if W % model_n == 0 else None)
    return P(*lead, *([None] * (len(shape) - len(lead))))


def cache_shardings(cache_tree, mesh: Mesh, cfg: ArchConfig, *,
                    seq_shard: bool = False, seq_axis: str | None = None):
    """Build NamedShardings for a serving cache pytree (as produced by
    transformer.init_cache): 'blocks' leaves are stacked [reps, B, ...],
    'tail' leaves are [B, ...].  seq_shard=True is shorthand for
    seq_axis="data" (long-context)."""
    if seq_shard and seq_axis is None:
        seq_axis = "data"

    def walk(node, keys, stacked):
        if isinstance(node, dict):
            return {k: walk(v, keys + (k,),
                            stacked if k not in ("blocks", "tail")
                            else (k == "blocks"))
                    for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v, keys + (str(i),), False)
                    for i, v in enumerate(node)]
        spec = cache_spec(mesh, keys, node.shape, cfg, stacked=stacked,
                          seq_axis=seq_axis)
        return NamedSharding(mesh, spec)
    return walk(cache_tree, (), False)
