"""Serving launcher: multi-tenant Arcus-shaped model serving.

Dev mode (default, CPU): reduced variant of the selected arch, real token
generation through the continuous-batching engine, virtual-clocked by the
FULL config's roofline cost model — per-tenant SLOs enforced by the Arcus
token buckets.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b \\
        --tenants 1200,800 --duration 3
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs.registry import ARCH_IDS, get_config, get_reduced_config
from repro.core.flow import SLO
from repro.models import transformer as T
from repro.serving.costmodel import HardwareSpec, StepCostModel
from repro.serving.engine import ServingEngine
from repro.serving.request import Request, Tenant
from repro.serving.scheduler import ArcusScheduler, FCFSScheduler


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma3-12b")
    ap.add_argument("--tenants", default="1200,800",
                    help="comma-separated tokens/s SLOs")
    ap.add_argument("--background", action="store_true", default=True,
                    help="add an opportunistic background tenant")
    ap.add_argument("--duration", type=float, default=3.0)
    ap.add_argument("--chips", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--unshaped", action="store_true",
                    help="FCFS baseline instead of Arcus shaping")
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch)
    params, _ = T.init_model(0, cfg)
    engine = ServingEngine(cfg, params, max_batch=args.max_batch,
                           max_len=256)
    cost = StepCostModel(get_config(args.arch),
                         HardwareSpec(chips=args.chips))
    slos = [float(x) for x in args.tenants.split(",")]
    tenants = [Tenant(i, SLO.iops(s), "reserved")
               for i, s in enumerate(slos)]
    if args.background:
        tenants.append(Tenant(len(tenants), SLO.iops(1e9), "opportunistic"))
    cls = FCFSScheduler if args.unshaped else ArcusScheduler
    sched = cls(engine, tenants, cost)

    rng = np.random.default_rng(0)
    rid = 0
    if args.background:
        for _ in range(24):
            sched.submit(Request(rid, len(slos),
                                 list(rng.integers(0, cfg.vocab, 64)), 16))
            rid += 1
    for k in range(16):
        for tid in range(len(slos)):
            sched.submit(Request(rid, tid,
                                 list(rng.integers(0, cfg.vocab, 12)), 6,
                                 arrive_s=k * args.duration / 32))
            rid += 1

    stats = sched.run(args.duration, max_rounds=2000)
    mode = "FCFS (unshaped)" if args.unshaped else "Arcus"
    print(f"{mode} on {cfg.name} family, {args.chips} chips, "
          f"virtual time {sched.now_s:.2f}s")
    for tid, st in sorted(stats.items()):
        ttft = (f"{np.percentile(st.ttft, 99)*1e3:8.1f}ms p99"
                if st.ttft else "     n/a")
        print(f"  tenant{tid} [{tenants[tid].policy:13s}] "
              f"tokens={st.served_tokens:5d} finished={st.finished:3d} "
              f"ttft={ttft}")


if __name__ == "__main__":
    main()
