"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state.  Single pod = 256 v5e chips as
(data=16, model=16); two pods = 512 chips as (pod=2, data=16, model=16).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False,
                         shape: tuple | None = None):
    """Default 16x16 per pod; `shape` re-factors the same chips (e.g.
    (32, 8) so a 40-head model's heads divide the model axis)."""
    if shape is None:
        shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    assert len(shape) == len(axes)
    return jax.make_mesh(shape, axes)


def make_dev_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh for CPU tests (uses however many devices exist)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
