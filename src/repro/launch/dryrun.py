import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS",
                                         "--xla_force_host_platform_device_count=512")
"""Multi-pod dry run: lower + compile every (arch x input-shape x mesh).

The two lines above MUST run before any jax import (jax locks the device
count at first init).  For every combination this script:

  1. builds the production mesh (16x16 single-pod or 2x16x16 multi-pod),
  2. constructs the step function for the shape's mode
     (train_4k -> train_step; prefill_32k -> prefill; decode shapes ->
     serve_step = one-token decode against a seq_len KV cache),
  3. jit-lowers with explicit in/out shardings over ShapeDtypeStruct
     stand-ins (no allocation),
  4. compiles, prints memory_analysis() / cost_analysis(), parses the
     post-SPMD HLO for collective bytes, and
  5. writes benchmarks/results/dryrun/<arch>__<shape>__<mesh>.json
     (consumed by the roofline report).

Usage:
  python -m repro.launch.dryrun --arch gemma3-12b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all [--mesh pod|multipod|both]
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import (ARCH_IDS, SHAPES, get_config,
                                    shape_supported)
from repro.distributed import sharding as SH
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.models.config import ArchConfig
from repro.training import optimizer as opt, train as TR

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/results/dryrun")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no device allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, shape_name: str) -> dict:
    sh = SHAPES[shape_name]
    B, S = sh["global_batch"], sh["seq_len"]
    i32 = jnp.int32
    specs: dict = {}
    if sh["mode"] == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        specs["mask"] = jax.ShapeDtypeStruct((B, S), i32)
        if cfg.frontend:
            specs["frontend"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_len, cfg.frontend_dim), jnp.bfloat16)
    elif sh["mode"] == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        if cfg.frontend:
            specs["frontend"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_len, cfg.frontend_dim), jnp.bfloat16)
    else:  # decode: ONE new token against a seq_len cache
        specs["tokens"] = jax.ShapeDtypeStruct((B, 1), i32)
        specs["lengths"] = jax.ShapeDtypeStruct((B,), i32)
    return specs


def cache_specs(cfg: ArchConfig, batch: int, max_len: int):
    return jax.eval_shape(
        lambda: T.init_cache(cfg, batch, max_len, jnp.bfloat16))


# ---------------------------------------------------------------------------
# Lowering for each mode
# ---------------------------------------------------------------------------


def build_lowered(cfg: ArchConfig, shape_name: str, mesh, *,
                  param_dtype=jnp.bfloat16, unroll: int = 1,
                  attn_impl: str = "auto", act_sharding: bool = False):
    """Returns lowered jit artifact.

    unroll > 1 inlines the layer scan (unroll=reps removes the while loop)
    so cost_analysis counts per-layer FLOPs/collectives correctly — XLA's
    HLO cost analysis counts a while body once, not x trip-count.
    """
    sh = SHAPES[shape_name]
    B, S = sh["global_batch"], sh["seq_len"]
    rules = SH.rules_for_config(cfg)
    if "pod" in mesh.axis_names:
        rules = dict(rules)
        rules["embed"] = ("pod", "data")  # FSDP spans pods
    from repro.distributed import actsharding
    if act_sharding:
        actsharding.enable(SH.batch_axes(mesh))
    else:
        actsharding.disable()

    # axes tree comes from a real (host-level, cheap) structure pass
    axes = T.init_model_axes(cfg)
    pshapes = jax.eval_shape(
        lambda: T.init_model_params_only(0, cfg, dtype=param_dtype))
    pshard = SH.param_shardings(axes, pshapes, mesh, rules)
    dspec = lambda nd: NamedSharding(mesh, SH.data_spec(mesh, nd, batch=B))
    specs = input_specs(cfg, shape_name)

    if sh["mode"] == "train":
        ocfg = opt.AdamWConfig()
        step = TR.make_train_step(cfg, ocfg, remat=True, unroll=unroll)
        oshapes = jax.eval_shape(opt.init, pshapes)
        oshard = opt.OptState(
            NamedSharding(mesh, P()),
            jax.tree.map(lambda s: s, pshard),
            jax.tree.map(lambda s: s, pshard))
        batch_sh = {"tokens": dspec(2), "mask": dspec(2)}
        if cfg.frontend:
            batch_sh["frontend"] = dspec(3)
        batch_specs = {k: v for k, v in specs.items()}
        fn = jax.jit(step,
                     in_shardings=(pshard, oshard, batch_sh),
                     out_shardings=(pshard, oshard, None),
                     donate_argnums=(0, 1))
        lowered = fn.lower(pshapes, oshapes, batch_specs)
    elif sh["mode"] == "prefill":
        cspecs = cache_specs(cfg, B, S)
        cshard = SH.cache_shardings(cspecs, mesh, cfg)

        def prefill_fn(params, tokens, cache, frontend=None):
            return T.prefill(params, cfg, tokens, cache, frontend,
                             unroll=unroll)

        args = [pshapes, specs["tokens"], cspecs]
        in_sh = [pshard, dspec(2), cshard]
        if cfg.frontend:
            args.append(specs["frontend"])
            in_sh.append(dspec(3))
        fn = jax.jit(prefill_fn, in_shardings=tuple(in_sh),
                     out_shardings=(None, cshard, None),
                     donate_argnums=(2,))
        lowered = fn.lower(*args)
    else:  # decode
        seq_shard = shape_name == "long_500k"
        cspecs = cache_specs(cfg, B, S)
        cshard = SH.cache_shardings(cspecs, mesh, cfg, seq_shard=seq_shard)

        decode_attn_fn = decode_update_fn = None
        if attn_impl == "seq_sharded":
            # beyond-paper perf variant: KV sequence sharded over "data"
            # (long_500k, batch=1) or "model" (decode_32k) with explicit
            # partial-softmax combine + owned-shard cache writes
            from repro.distributed.collectives import (
                make_seq_sharded_cache_update, make_seq_sharded_decode_attn)
            axis = "data" if seq_shard else "model"
            b_ax = None if seq_shard else "data"
            d_ax = "model" if seq_shard else None
            decode_attn_fn = make_seq_sharded_decode_attn(mesh, axis, b_ax,
                                                          d_ax)
            decode_update_fn = make_seq_sharded_cache_update(mesh, axis,
                                                             b_ax, d_ax)
            cshard = SH.cache_shardings(cspecs, mesh, cfg, seq_axis=axis)

        def decode_fn(params, tokens, lengths, cache):
            return T.decode_step(params, cfg, tokens, lengths, cache,
                                 unroll=unroll,
                                 decode_attn_fn=decode_attn_fn,
                                 decode_update_fn=decode_update_fn)

        fn = jax.jit(decode_fn,
                     in_shardings=(pshard, dspec(2),
                                   NamedSharding(mesh, P()), cshard),
                     out_shardings=(None, cshard),
                     donate_argnums=(3,))
        lowered = fn.lower(pshapes, specs["tokens"], specs["lengths"],
                           cspecs)
    return lowered


# ---------------------------------------------------------------------------
# Analysis
# ---------------------------------------------------------------------------


def parse_collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum output-tensor bytes of every collective op in post-SPMD HLO."""
    out: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    shape_re = re.compile(r"=\s*\(?([a-z0-9]+)\[([\d,]*)\]")
    for line in hlo_text.splitlines():
        ls = line.lstrip()
        m = shape_re.search(ls)
        if m is None:
            continue
        op = None
        for c in _COLLECTIVES:
            if f" {c}(" in ls or f"{c}-start(" in ls or \
               f" {c}-start(" in ls or ls.startswith(c):
                op = c
                break
        if op is None:
            continue
        dt, dims = m.group(1), m.group(2)
        nbytes = _DTYPE_BYTES.get(dt, 4)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out[op] += n * nbytes
        counts[op] += 1
    out_counts = {f"n_{k}": counts[k] for k in counts}
    return {**out, **out_counts}


def analyze(lowered, compiled, *, parse_hlo: bool = True) -> dict:
    res: dict = {}
    try:
        ma = compiled.memory_analysis()
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            v = getattr(ma, f, None)
            if v is not None:
                res[f] = int(v)
    except Exception as e:  # pragma: no cover - backend dependent
        res["memory_analysis_error"] = str(e)
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        res["flops"] = float(ca.get("flops", -1))
        res["bytes_accessed"] = float(ca.get("bytes accessed", -1))
        res["optimal_seconds"] = float(ca.get("optimal_seconds", -1))
    except Exception as e:  # pragma: no cover
        res["cost_analysis_error"] = str(e)
    if parse_hlo:
        try:
            res["collectives"] = parse_collective_bytes(
                compiled.as_text())
        except Exception as e:  # pragma: no cover
            res["collectives_error"] = str(e)
    return res


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def run_one(arch: str, shape_name: str, mesh_kind: str, *,
            out_dir: str = RESULTS_DIR, force: bool = False,
            parse_hlo: bool = True, unrolled_pass: bool = False,
            variant: str = "", build_kwargs: dict | None = None,
            mesh_shape: tuple | None = None) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}__{shape_name}__{mesh_kind}"
    if variant:
        tag += f"__{variant}"
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    cfg = get_config(arch)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "status": "skipped"}
    if not shape_supported(arch, shape_name):
        rec["reason"] = "full-attention arch: long_500k skipped (DESIGN.md)"
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"),
                                shape=mesh_shape)
    t0 = time.time()
    bk = build_kwargs or {}
    try:
        with mesh:
            lowered = build_lowered(cfg, shape_name, mesh, **bk)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            rec.update(analyze(lowered, compiled, parse_hlo=parse_hlo))
            rec.update(status="ok", lower_s=round(t_lower, 1),
                       compile_s=round(t_compile, 1),
                       n_devices=mesh.size)
            if unrolled_pass and SHAPES[shape_name]["mode"] == "train":
                # unrolled backward graphs take tens of minutes to compile
                # on one CPU core; train_4k and prefill_32k carry the SAME
                # token count (256x4096 == 32x32768), so the roofline
                # derives train FLOPs as 4x the prefill-unrolled count
                # (fwd + bwd(2x) + remat fwd).  Marked for transparency.
                rec["unrolled"] = {"derive": "4x_prefill", "approx": True,
                                   "reps": cfg.n_layers // cfg.period}
            elif unrolled_pass:
                reps = cfg.n_layers // cfg.period
                try:
                    lo_u = build_lowered(cfg, shape_name, mesh, unroll=reps,
                                         **bk)
                    co_u = lo_u.compile()
                    rec["unrolled"] = analyze(lo_u, co_u,
                                              parse_hlo=parse_hlo)
                    rec["unrolled"]["reps"] = reps
                except Exception as e:  # fallback: x reps correction
                    rec["unrolled_error"] = f"{type(e).__name__}: {e}"
                    rec["unrolled"] = {"flops": rec.get("flops", 0) * reps,
                                       "approx": True, "reps": reps}
            print(f"[dryrun] {tag}: OK lower={t_lower:.0f}s "
                  f"compile={t_compile:.0f}s flops={rec.get('flops'):.3g}")
            print(f"[dryrun] {tag} memory: "
                  f"args={rec.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
                  f"temp={rec.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
                  f"out={rec.get('output_size_in_bytes', 0)/2**30:.2f}GiB")
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[dryrun] {tag}: FAILED {type(e).__name__}: {e}")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("pod", "multipod", "both"),
                    default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-hlo", action="store_true",
                    help="skip collective parsing (faster)")
    ap.add_argument("--unrolled", action="store_true",
                    help="extra unrolled lowering for exact FLOP counts")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    combos = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                for m in meshes:
                    combos.append((a, s, m))
    else:
        assert args.arch and args.shape
        combos = [(args.arch, args.shape, m) for m in meshes]
    ok = err = skip = 0
    for a, s, m in combos:
        rec = run_one(a, s, m, out_dir=args.out, force=args.force,
                      parse_hlo=not args.no_hlo,
                      unrolled_pass=args.unrolled)
        ok += rec["status"] == "ok"
        err += rec["status"] == "error"
        skip += rec["status"] == "skipped"
    print(f"[dryrun] done: {ok} ok, {err} failed, {skip} skipped")


if __name__ == "__main__":
    main()
