"""Training launcher.

Dev mode (default, CPU): trains a reduced variant of the selected arch on
the synthetic pipeline with the same step function the dry run lowers at
pod scale.

Production mode (--production, requires a real 256/512-chip platform):
builds the production mesh, shards params/optimizer with the same rules as
the dry run, and runs the pjit'd step.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-14b --steps 50
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs.registry import ARCH_IDS, get_config, get_reduced_config
from repro.data.pipeline import DataConfig, SyntheticLM, frontend_stub
from repro.distributed import sharding as SH
from repro.launch.mesh import make_dev_mesh, make_production_mesh
from repro.models import module as nn, transformer as T
from repro.training import checkpoint as ckpt, optimizer as opt, train as TR


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2.5-14b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--production", action="store_true",
                    help="full config on the production mesh")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    if args.production:
        cfg = get_config(args.arch)
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    else:
        cfg = get_reduced_config(args.arch)
        mesh = make_dev_mesh(1, 1)

    rules = SH.rules_for_config(cfg)
    axes = T.init_model_axes(cfg)
    ocfg = opt.AdamWConfig(lr=3e-4, warmup_steps=10, total_steps=args.steps)
    step = TR.make_train_step(cfg, ocfg, remat=args.production)

    with mesh:
        pshapes = jax.eval_shape(lambda: T.init_model(0, cfg)[0])
        pshard = SH.param_shardings(axes, pshapes, mesh, rules)
        params = jax.jit(lambda: T.init_model(0, cfg)[0],
                         out_shardings=pshard)()
        print(f"{cfg.name}: {nn.param_count(params)/1e6:.1f}M params, "
              f"mesh={dict(mesh.shape)}")
        ost = opt.init(params)
        dspec = NamedSharding(mesh, SH.data_spec(mesh, 2, batch=args.batch))
        jstep = jax.jit(step, donate_argnums=(0, 1))
        data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                      global_batch=args.batch))
        t0 = time.time()
        for i, b in zip(range(args.steps), data.batches()):
            batch = {"tokens": jax.device_put(b["tokens"], dspec),
                     "mask": jax.device_put(b["mask"], dspec)}
            if cfg.frontend:
                batch["frontend"] = jnp.asarray(frontend_stub(
                    cfg.frontend, args.batch, cfg.frontend_len,
                    cfg.frontend_dim))
            params, ost, m = jstep(params, ost, batch)
            if i % 10 == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss={float(m['loss']):.4f} "
                      f"({(time.time()-t0)/(i+1):.2f}s/step)")
    if args.ckpt:
        ckpt.save(args.ckpt, params, ost, step=args.steps)
        print("saved", args.ckpt)


if __name__ == "__main__":
    main()
