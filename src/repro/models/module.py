"""Minimal pytree parameter system (no flax dependency).

Params are nested dicts of jnp arrays.  A parallel tree of *logical axis
tuples* (same structure, one tuple per leaf) drives sharding: logical names
("embed", "vocab", "heads", "mlp", "experts", ...) are resolved to mesh axes
through a rules dict (see repro.distributed.sharding).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

Params = dict
Axes = dict


class KeyGen:
    """Deterministic PRNG key dispenser."""

    def __init__(self, seed_or_key):
        if isinstance(seed_or_key, int):
            self._key = jax.random.PRNGKey(seed_or_key)
        else:
            self._key = seed_or_key

    def __call__(self) -> jax.Array:
        self._key, k = jax.random.split(self._key)
        return k


def dense(key, in_dim: int, out_dims, axes: tuple, *, dtype=jnp.float32,
          scale: float | None = None):
    """He/LeCun-style init for a dense weight [in_dim, *out_dims]."""
    out_dims = (out_dims,) if isinstance(out_dims, int) else tuple(out_dims)
    shape = (in_dim,) + out_dims
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    w = jax.random.normal(key, shape, dtype=jnp.float32) * scale
    assert len(axes) == len(shape), (axes, shape)
    return w.astype(dtype), axes


def embed(key, vocab: int, dim: int, *, dtype=jnp.float32):
    w = jax.random.normal(key, (vocab, dim), dtype=jnp.float32)
    return w.astype(dtype), ("vocab", "embed")


def zeros(shape, axes: tuple, *, dtype=jnp.float32):
    return jnp.zeros(shape, dtype), axes


def ones(shape, axes: tuple, *, dtype=jnp.float32):
    return jnp.ones(shape, dtype), axes


class ParamCollector:
    """Builds the (params, axes) twin trees."""

    def __init__(self):
        self.params: Params = {}
        self.axes: Axes = {}

    def add(self, name: str, value_axes: tuple[jax.Array, tuple]):
        value, axes = value_axes
        self.params[name] = value
        self.axes[name] = axes
        return value

    def sub(self, name: str) -> "ParamCollector":
        c = ParamCollector()
        self.params[name] = c.params
        self.axes[name] = c.axes
        return c


def stack_params(trees: list[Params]) -> Params:
    """Stack a list of identical param trees along a new leading axis
    (for scan-over-layers)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def stack_axes(axes: Axes) -> Axes:
    """Prepend the 'layers' logical axis to every leaf."""
    return jax.tree.map(lambda a: ("layers",) + a,
                        axes, is_leaf=lambda x: isinstance(x, tuple))


def param_count(params: Params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


def tree_cast(params: Params, dtype) -> Params:
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating)
        else x, params)
