"""Composable model: decoder-only / encoder-decoder / cross-attn VLM.

Layers are organized as a repeating *period* of block kinds
(cfg.layer_pattern x MoE flags).  Parameters for each period position are
stacked across repetitions and applied with jax.lax.scan, keeping the HLO
O(period) in depth (critical: one CPU core compiles 48-layer models here).

Three entry modes share the block code:
  * forward()      — full sequence, no cache (train / scoring)
  * prefill()      — full sequence, builds the serving cache
  * decode_step()  — one token per sequence against the cache
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import module as nn
from repro.models.config import ArchConfig

ATTN_KINDS = ("global", "local", "chunk", "cross")


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_block(key: nn.KeyGen, cfg: ArchConfig, kind: str, is_moe: bool):
    c = nn.ParamCollector()
    c.add("ln1", L.init_norm(cfg))
    if kind in ("global", "local", "chunk"):
        c.add("mixer", L.init_attention(key, cfg))
    elif kind == "cross":
        c.add("mixer", L.init_attention(key, cfg, cross=True))
        c.add("xgate", nn.zeros((), ()))
    elif kind == "rglru":
        c.add("mixer", L.init_rglru(key, cfg))
    elif kind == "ssd":
        c.add("mixer", L.init_mamba2(key, cfg))
    else:
        raise ValueError(kind)
    if cfg.encoder_layers and kind in ATTN_KINDS:
        # encoder-decoder blocks: self-attn + cross-attn + FFN
        c.add("xattn", L.init_attention(key, cfg, cross=True))
        c.add("lnx", L.init_norm(cfg))
    if cfg.d_ff > 0:
        c.add("ln2", L.init_norm(cfg))
        c.add("ffn", L.init_moe(key, cfg) if is_moe else L.init_mlp(key, cfg))
    return c.params, c.axes


def _init_encoder_block(key: nn.KeyGen, cfg: ArchConfig):
    c = nn.ParamCollector()
    c.add("ln1", L.init_norm(cfg))
    c.add("mixer", L.init_attention(key, cfg))
    c.add("ln2", L.init_norm(cfg))
    c.add("ffn", L.init_mlp(key, cfg))
    return c.params, c.axes


def init_model(key_or_seed, cfg: ArchConfig):
    cfg.validate()
    key = nn.KeyGen(key_or_seed)
    c = nn.ParamCollector()
    c.add("embed", nn.embed(key(), cfg.vocab, cfg.d_model))
    if cfg.frontend:
        c.add("frontend_proj",
              nn.dense(key(), cfg.frontend_dim, cfg.d_model,
                       ("frontend", "embed")))
    kinds = cfg.layer_kinds()
    period, reps = cfg.period, cfg.n_layers // cfg.period
    blocks_p, blocks_a = {}, {}
    for j in range(period):
        per_rep = [
            _init_block(key, cfg, kinds[j], cfg.is_moe_layer(j))
            for _ in range(reps)
        ]
        blocks_p[f"pos{j}"] = nn.stack_params([p for p, _ in per_rep])
        blocks_a[f"pos{j}"] = nn.stack_axes(per_rep[0][1])
    c.params["blocks"] = blocks_p
    c.axes["blocks"] = blocks_a
    tail_p, tail_a = [], []
    for i in range(reps * period, cfg.n_layers):
        p, a = _init_block(key, cfg, kinds[i], cfg.is_moe_layer(i))
        tail_p.append(p)
        tail_a.append(a)
    c.params["tail"] = tail_p
    c.axes["tail"] = tail_a
    if cfg.encoder_layers:
        enc = [_init_encoder_block(key, cfg)
               for _ in range(cfg.encoder_layers)]
        c.params["encoder"] = nn.stack_params([p for p, _ in enc])
        c.axes["encoder"] = nn.stack_axes(enc[0][1])
        c.add("enc_norm", L.init_norm(cfg))
    c.add("final_norm", L.init_norm(cfg))
    if not cfg.tie_embeddings:
        c.add("lm_head", nn.dense(key(), cfg.d_model, cfg.vocab,
                                  ("embed", "vocab")))
    return c.params, c.axes


def init_model_params_only(seed, cfg: ArchConfig, dtype=jnp.bfloat16):
    """Params cast to `dtype` (axes discarded) — eval_shape friendly."""
    p, _ = init_model(seed, cfg)
    return nn.tree_cast(p, dtype)


def init_model_axes(cfg: ArchConfig):
    """Logical-axes twin tree, built without allocating any array."""
    box = {}

    def f():
        p, a = init_model(0, cfg)
        box["axes"] = a
        return p

    jax.eval_shape(f)
    return box["axes"]


# ---------------------------------------------------------------------------
# Block application (shared by train / prefill / decode)
# ---------------------------------------------------------------------------


def _apply_block(bp, x, cfg: ArchConfig, kind: str, is_moe: bool, *,
                 positions, frontend_kv=None, mode: str = "train",
                 cache=None, lengths=None, kv_chunk: int = 512,
                 decode_attn_fn=None, decode_update_fn=None):
    """Returns (x, new_cache, aux)."""
    new_cache = cache
    aux = 0.0
    h = L.norm(bp["ln1"], x, cfg)
    if kind in ("global", "local", "chunk"):
        if mode == "decode":
            y, new_cache = _decode_self_attention(
                bp["mixer"], h, cfg, kind, cache, lengths,
                decode_attn_fn=decode_attn_fn,
                decode_update_fn=decode_update_fn)
        else:
            y = L.attention_block(bp["mixer"], h, cfg, kind,
                                  positions=positions, kv_chunk=kv_chunk)
            if mode == "prefill":
                new_cache = _build_attn_cache(bp["mixer"], h, cfg, kind,
                                              cache, positions)
    elif kind == "cross":
        if mode == "decode":
            q, _, _ = L.attention_qkv(bp["mixer"], h, cfg, kv_src=h[:, :0])
            from repro.kernels.decode_attention import ref as da_ref
            o = da_ref.decode_attention(
                q[:, 0], cache["k"], cache["v"],
                jnp.full((x.shape[0],), cache["k"].shape[1], jnp.int32))
            y = L.attention_out(bp["mixer"], o[:, None], cfg)
        else:
            y = L.attention_block(bp["mixer"], h, cfg, "cross",
                                  positions=positions,
                                  frontend_kv=frontend_kv, kv_chunk=kv_chunk)
            if mode == "prefill":
                _, ck, cv = L.attention_qkv(bp["mixer"], h, cfg,
                                            kv_src=frontend_kv)
                new_cache = {"k": ck, "v": cv}
        y = jnp.tanh(bp["xgate"]).astype(y.dtype) * y
    elif kind == "rglru":
        state = None if mode == "train" else \
            ((cache["conv"], cache["h"]) if mode == "decode" else None)
        y, st = L.rglru_block(bp["mixer"], h, cfg, state)
        if mode != "train":
            new_cache = {"conv": st[0], "h": st[1]}
    elif kind == "ssd":
        state = None if mode == "train" else \
            ((cache["conv"], cache["state"]) if mode == "decode" else None)
        y, st = L.mamba2_block(bp["mixer"], h, cfg, state)
        if mode != "train":
            new_cache = {"conv": st[0], "state": st[1]}
    else:
        raise ValueError(kind)
    x = x + y

    if cfg.encoder_layers and kind in ATTN_KINDS and "xattn" in bp:
        hx = L.norm(bp["lnx"], x, cfg)
        if mode == "decode":
            q, _, _ = L.attention_qkv(bp["xattn"], hx, cfg)
            from repro.kernels.decode_attention import ref as da_ref
            o = da_ref.decode_attention(
                q[:, 0], cache["xk"], cache["xv"],
                jnp.full((x.shape[0],), cache["xk"].shape[1], jnp.int32))
            y = L.attention_out(bp["xattn"], o[:, None], cfg)
            # the encoder memory is static during decode: carry it through
            new_cache = dict(new_cache or {})
            new_cache.update({"xk": cache["xk"], "xv": cache["xv"]})
        else:
            y = L.attention_block(bp["xattn"], hx, cfg, "cross",
                                  positions=positions,
                                  frontend_kv=frontend_kv, kv_chunk=kv_chunk)
            if mode == "prefill":
                _, ck, cv = L.attention_qkv(bp["xattn"], hx, cfg,
                                            kv_src=frontend_kv)
                new_cache = dict(new_cache or {})
                new_cache.update({"xk": ck, "xv": cv})
        x = x + y

    if cfg.d_ff > 0:
        h2 = L.norm(bp["ln2"], x, cfg)
        if is_moe:
            y2, probs = L.moe_block(bp["ffn"], h2, cfg,
                                    dropless=(mode != "train"))
            aux = L.moe_aux_loss(probs)
        else:
            y2 = L.mlp_block(bp["ffn"], h2, cfg)
        x = x + y2
    return x, new_cache, aux


# --- attention cache helpers -------------------------------------------------


def _cache_window(cfg: ArchConfig, kind: str, max_len: int) -> int:
    if kind == "local":
        return min(cfg.window, max_len)
    if kind == "chunk":
        return min(cfg.window, max_len)
    return max_len


def _build_attn_cache(p, h, cfg: ArchConfig, kind: str, cache, positions):
    """Write prefilled K/V into the (possibly rolling) cache buffer."""
    _, k, v = L.attention_qkv(p, h, cfg)
    k = L.rope(k, positions, theta=cfg.rope_theta, fraction=cfg.rope_fraction)
    B, S = k.shape[0], k.shape[1]
    W = cache["k"].shape[1]
    if kind in ("local", "chunk") and S > W:
        k, v = k[:, -W:], v[:, -W:]
        pos = positions[..., -W:]
    else:
        pos = positions[..., :S]
    slots = (pos % W).astype(jnp.int32)
    slots = jnp.broadcast_to(slots, (B, k.shape[1]))
    bidx = jnp.arange(B)[:, None]
    ck = cache["k"].at[bidx, slots].set(k.astype(cache["k"].dtype))
    cv = cache["v"].at[bidx, slots].set(v.astype(cache["v"].dtype))
    return {"k": ck, "v": cv}


def _decode_self_attention(p, h, cfg: ArchConfig, kind: str, cache, lengths,
                           *, decode_attn_fn=None, decode_update_fn=None):
    """One-token attention against the cache; writes the new K/V first."""
    from repro.kernels.decode_attention import ref as da_ref
    B = h.shape[0]
    pos = lengths[:, None]                                  # [B, 1]
    q, k, v = L.attention_qkv(p, h, cfg)
    q = L.rope(q, pos, theta=cfg.rope_theta, fraction=cfg.rope_fraction)
    k = L.rope(k, pos, theta=cfg.rope_theta, fraction=cfg.rope_fraction)
    W = cache["k"].shape[1]
    slot = (lengths % W).astype(jnp.int32)
    if decode_update_fn is not None:
        # seq-sharded cache: only the owning shard writes (no resharding)
        ck, cv = decode_update_fn(cache["k"], cache["v"], k[:, 0], v[:, 0],
                                  slot)
    else:
        bidx = jnp.arange(B)
        ck = cache["k"].at[bidx, slot].set(k[:, 0].astype(cache["k"].dtype))
        cv = cache["v"].at[bidx, slot].set(v[:, 0].astype(cache["v"].dtype))
    if kind == "chunk":
        valid = (lengths % cfg.window) + 1
        window = 0
    elif kind == "local":
        valid = jnp.minimum(lengths + 1, W)
        window = 0
    else:
        valid = lengths + 1
        window = 0
    attn = decode_attn_fn or (lambda q_, k_, v_, l_, **kw:
                              da_ref.decode_attention(q_, k_, v_, l_, **kw))
    o = attn(q[:, 0], ck, cv, valid.astype(jnp.int32), window=window)
    y = L.attention_out(p, o[:, None], cfg)
    return y, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# Whole-model passes
# ---------------------------------------------------------------------------


def _embed_tokens(params, cfg: ArchConfig, tokens):
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x.astype(jnp.dtype(cfg.dtype))


def _frontend_kv(params, cfg: ArchConfig, frontend_emb):
    if frontend_emb is None:
        return None
    return jnp.einsum("bfe,ed->bfd", frontend_emb.astype(jnp.float32),
                      params["frontend_proj"].astype(jnp.float32)
                      ).astype(jnp.dtype(cfg.dtype))


def _encode(params, cfg: ArchConfig, frontend_kv, kv_chunk: int = 512):
    """Bidirectional encoder over frontend embeddings (audio)."""
    x = frontend_kv

    def body(x, bp):
        h = L.norm(bp["ln1"], x, cfg)
        y = L.attention_block(bp["mixer"], h, cfg, "encoder",
                              positions=jnp.arange(x.shape[1])[None, :],
                              kv_chunk=kv_chunk)
        x = x + y
        h = L.norm(bp["ln2"], x, cfg)
        return x + L.mlp_block(bp["ffn"], h, cfg), None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return L.norm(params["enc_norm"], x, cfg)


def _unembed(params, cfg: ArchConfig, x):
    x = L.norm(params["final_norm"], x, cfg)
    if cfg.tie_embeddings:
        # T5-style 1/sqrt(d) logit scaling: the tied embedding matrix has
        # unit-variance rows, so unscaled tied logits start with std
        # ~sqrt(d) and CE ~ d/2 — poison for early training.
        w = params["embed"].astype(x.dtype)
        return jnp.einsum("bse,ve->bsv", x, w) * (cfg.d_model ** -0.5)
    return jnp.einsum("bse,ev->bsv", x, params["lm_head"].astype(x.dtype))


def forward(params, cfg: ArchConfig, tokens, frontend_emb=None, *,
            remat: bool = False, kv_chunk: int = 512, unroll: int = 1):
    """Full-sequence forward -> (logits [B,S,V], aux_loss)."""
    x = _embed_tokens(params, cfg, tokens)
    positions = jnp.arange(tokens.shape[1])[None, :]
    fkv = _frontend_kv(params, cfg, frontend_emb)
    if cfg.encoder_layers:
        fkv = _encode(params, cfg, fkv, kv_chunk)
    kinds = cfg.layer_kinds()
    period, reps = cfg.period, cfg.n_layers // cfg.period

    def body(carry, rep_params):
        x, aux = carry
        for j in range(period):
            x, _, a = _apply_block(rep_params[f"pos{j}"], x, cfg, kinds[j],
                                   cfg.is_moe_layer(j), positions=positions,
                                   frontend_kv=fkv, mode="train",
                                   kv_chunk=kv_chunk)
            aux = aux + a
        return (x, aux), None

    body_fn = jax.checkpoint(body) if remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"], unroll=unroll)
    for i, bp in enumerate(params["tail"]):
        li = reps * period + i
        x, _, a = _apply_block(bp, x, cfg, kinds[li], cfg.is_moe_layer(li),
                               positions=positions, frontend_kv=fkv,
                               mode="train", kv_chunk=kv_chunk)
        aux = aux + a
    return _unembed(params, cfg, x), aux


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    """Stacked per-period-position cache pytree."""
    kinds = cfg.layer_kinds()
    period, reps = cfg.period, cfg.n_layers // cfg.period
    KvH, Dh = cfg.n_kv_heads, cfg.head_dim_

    def one(kind, n):
        if kind in ("global", "local", "chunk"):
            W = _cache_window(cfg, kind, max_len)
            c = {"k": jnp.zeros((n, batch, W, KvH, Dh), dtype),
                 "v": jnp.zeros((n, batch, W, KvH, Dh), dtype)}
        elif kind == "cross":
            c = {"k": jnp.zeros((n, batch, cfg.frontend_len, KvH, Dh), dtype),
                 "v": jnp.zeros((n, batch, cfg.frontend_len, KvH, Dh), dtype)}
        elif kind == "rglru":
            W = cfg.lru_width or cfg.d_model
            c = {"conv": jnp.zeros((n, batch, 3, W), dtype),
                 "h": jnp.zeros((n, batch, W), jnp.float32)}
        elif kind == "ssd":
            Din, H, G, N = L.mamba2_split(cfg)
            P = cfg.ssm_head_dim
            c = {"conv": jnp.zeros((n, batch, cfg.conv_kernel - 1,
                                    Din + 2 * G * N), dtype),
                 "state": jnp.zeros((n, batch, H, P, N), jnp.float32)}
        else:
            raise ValueError(kind)
        if cfg.encoder_layers and kind in ATTN_KINDS:
            c["xk"] = jnp.zeros((n, batch, cfg.frontend_len, KvH, Dh), dtype)
            c["xv"] = jnp.zeros((n, batch, cfg.frontend_len, KvH, Dh), dtype)
        return c

    cache = {"blocks": {f"pos{j}": one(kinds[j], reps)
                        for j in range(period)},
             "tail": [jax.tree.map(lambda y: y[0], one(kinds[i], 1))
                      for i in range(reps * period, cfg.n_layers)]}
    return cache


def prefill(params, cfg: ArchConfig, tokens, cache, frontend_emb=None, *,
            kv_chunk: int = 512, unroll: int = 1):
    """Equal-length batched prefill: runs the full sequence, fills the cache.
    Returns (last-token logits [B,V], cache, lengths [B])."""
    B, S = tokens.shape
    x = _embed_tokens(params, cfg, tokens)
    positions = jnp.arange(S)[None, :]
    fkv = _frontend_kv(params, cfg, frontend_emb)
    if cfg.encoder_layers:
        fkv = _encode(params, cfg, fkv, kv_chunk)
    kinds = cfg.layer_kinds()
    period, reps = cfg.period, cfg.n_layers // cfg.period

    def body(x, inp):
        rep_params, rep_cache = inp
        new_rep_cache = {}
        for j in range(period):
            x, nc, _ = _apply_block(rep_params[f"pos{j}"], x, cfg, kinds[j],
                                    cfg.is_moe_layer(j), positions=positions,
                                    frontend_kv=fkv, mode="prefill",
                                    cache=rep_cache[f"pos{j}"],
                                    kv_chunk=kv_chunk)
            new_rep_cache[f"pos{j}"] = nc
        return x, new_rep_cache

    x, new_blocks = jax.lax.scan(body, x,
                                 (params["blocks"], cache["blocks"]),
                                 unroll=unroll)
    new_tail = []
    for i, bp in enumerate(params["tail"]):
        li = reps * period + i
        x, nc, _ = _apply_block(bp, x, cfg, kinds[li], cfg.is_moe_layer(li),
                                positions=positions, frontend_kv=fkv,
                                mode="prefill", cache=cache["tail"][i],
                                kv_chunk=kv_chunk)
        new_tail.append(nc)
    logits = _unembed(params, cfg, x[:, -1:])[:, 0]
    lengths = jnp.full((B,), S, jnp.int32)
    return logits, {"blocks": new_blocks, "tail": new_tail}, lengths


def decode_step(params, cfg: ArchConfig, tokens, lengths, cache, *,
                decode_attn_fn=None, decode_update_fn=None,
                unroll: int = 1):
    """One decode step.  tokens [B, 1]; lengths [B] = current cache length.
    Returns (logits [B, V], new_cache)."""
    x = _embed_tokens(params, cfg, tokens)
    positions = lengths[:, None]
    kinds = cfg.layer_kinds()
    period, reps = cfg.period, cfg.n_layers // cfg.period

    def body(x, inp):
        rep_params, rep_cache = inp
        new_rep_cache = {}
        for j in range(period):
            x, nc, _ = _apply_block(rep_params[f"pos{j}"], x, cfg, kinds[j],
                                    cfg.is_moe_layer(j), positions=positions,
                                    mode="decode", cache=rep_cache[f"pos{j}"],
                                    lengths=lengths,
                                    decode_attn_fn=decode_attn_fn,
                                    decode_update_fn=decode_update_fn)
            new_rep_cache[f"pos{j}"] = nc
        return x, new_rep_cache

    x, new_blocks = jax.lax.scan(body, x,
                                 (params["blocks"], cache["blocks"]),
                                 unroll=unroll)
    new_tail = []
    for i, bp in enumerate(params["tail"]):
        li = reps * period + i
        x, nc, _ = _apply_block(bp, x, cfg, kinds[li], cfg.is_moe_layer(li),
                                positions=positions, mode="decode",
                                cache=cache["tail"][i], lengths=lengths,
                                decode_attn_fn=decode_attn_fn,
                                decode_update_fn=decode_update_fn)
        new_tail.append(nc)
    logits = _unembed(params, cfg, x)[:, 0]
    return logits, {"blocks": new_blocks, "tail": new_tail}
