"""Architecture configuration shared by all model families."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None    # default d_model // n_heads

    # --- per-layer kind pattern (cycled over depth) --------------------
    # kinds: "global" (full causal), "local" (sliding window), "chunk"
    # (chunked local attention, llama4-style), "rglru" (RG-LRU recurrent
    # block), "ssd" (Mamba2 SSD block), "cross" (cross-attention to
    # frontend embeddings)
    layer_pattern: tuple[str, ...] = ("global",)
    window: int = 0                # sliding/chunked attention window
    # --- positions / projections ---------------------------------------
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0     # chatglm applies RoPE to half the dims
    qkv_bias: bool = False
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    act: str = "silu"              # silu | gelu
    gated_mlp: bool = True         # SwiGLU/GeGLU vs. plain 2-matrix MLP
    embed_scale: bool = False      # gemma-style sqrt(d) embedding scaling
    tie_embeddings: bool = True
    # --- MoE ------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1             # MoE replaces MLP every k-th layer
    capacity_factor: float = 1.25
    # --- SSM (Mamba2) -----------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    conv_kernel: int = 4
    d_inner_mult: int = 2
    # --- RG-LRU (recurrentgemma) ----------------------------------------
    lru_width: int = 0
    # --- encoder-decoder / multimodal frontends ---------------------------
    encoder_layers: int = 0        # >0 => encoder-decoder (audio)
    frontend: str | None = None    # "audio" | "vision" embedding stub
    frontend_len: int = 0          # # stub embedding tokens
    frontend_dim: int = 0          # stub embedding dim (projected to d_model)
    # --- numerics ---------------------------------------------------------
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def layer_kinds(self) -> list[str]:
        p = self.layer_pattern
        return [p[i % len(p)] for i in range(self.n_layers)]

    def is_moe_layer(self, i: int) -> bool:
        return self.n_experts > 0 and (i % self.moe_every == self.moe_every - 1)

    @property
    def period(self) -> int:
        """Length of the repeating block pattern (layer kind x MoE flag)."""
        import math
        if self.n_experts > 0:
            return math.lcm(len(self.layer_pattern), self.moe_every)
        return len(self.layer_pattern)

    def validate(self) -> None:
        assert self.n_heads % self.n_kv_heads == 0 or self.arch_type == "ssm"
        for k in self.layer_pattern:
            assert k in ("global", "local", "chunk", "rglru", "ssd", "cross")
        if "local" in self.layer_pattern or "chunk" in self.layer_pattern:
            assert self.window > 0, "windowed kinds need cfg.window"
        if "cross" in self.layer_pattern:
            assert self.frontend is not None and self.frontend_len > 0
        if self.encoder_layers:
            assert self.frontend is not None


def reduced(cfg: ArchConfig, *, n_layers: int = 2, d_model: int = 256,
            n_heads: int = 4, d_ff: int = 512, vocab: int = 512,
            **kw) -> ArchConfig:
    """Reduced same-family variant for CPU smoke tests (2 layers,
    d_model <= 512, <= 4 experts)."""
    import dataclasses as dc
    # preserve the family's GQA ratio at reduced size
    ratio = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1))
    n_kv = max(1, n_heads // ratio)
    upd = dict(
        name=cfg.name + "-reduced",
        n_layers=max(n_layers, cfg.period) if cfg.period <= 8 else n_layers,
        d_model=d_model, n_heads=n_heads,
        n_kv_heads=min(n_kv, n_heads),
        d_ff=d_ff, vocab=vocab, head_dim=None,
        window=min(cfg.window, 64) if cfg.window else 0,
        n_experts=min(cfg.n_experts, 4),
        frontend_len=min(cfg.frontend_len, 16) if cfg.frontend_len else 0,
        frontend_dim=min(cfg.frontend_dim, 128) if cfg.frontend_dim else 0,
        encoder_layers=min(cfg.encoder_layers, 2),
        ssm_state=min(cfg.ssm_state, 64) if cfg.ssm_state else 0,
        ssm_head_dim=min(cfg.ssm_head_dim, 32),
        lru_width=min(cfg.lru_width, d_model) if cfg.lru_width else 0,
        dtype="float32",
    )
    upd.update(kw)
    return dc.replace(cfg, **upd)
