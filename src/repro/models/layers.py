"""Shared neural layers for all assigned architectures.

Everything is a pure function over (params dict, inputs); parameter
construction lives beside each forward function and returns (params, axes)
twin trees for sharding.

Attention is flash-style (lax.scan over KV chunks with online softmax) so
prefill_32k / train_4k never materialize [S, S] score matrices.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import module as nn
from repro.models.config import ArchConfig

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(cfg: ArchConfig):
    c = nn.ParamCollector()
    c.add("scale", nn.ones((cfg.d_model,), ("embed",)))
    if cfg.norm == "layernorm":
        c.add("bias", nn.zeros((cfg.d_model,), ("embed",)))
    return c.params, c.axes


def norm(p, x, cfg: ArchConfig):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
        y = y * p["scale"] + p["bias"]
    else:
        var = (xf ** 2).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + 1e-6) * p["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (full / partial-dim "2d" variant)
# ---------------------------------------------------------------------------


def rope(x, positions, *, theta: float, fraction: float = 1.0):
    """x [..., S, H, D]; positions [..., S] (broadcastable)."""
    D = x.shape[-1]
    rot = int(D * fraction) // 2 * 2
    xr, xp = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None, None].astype(jnp.float32) * freqs  # [...,S,1,half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = xr[..., :half], xr[..., half:]
    xr = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return jnp.concatenate([xr.astype(x.dtype), xp], -1)


# ---------------------------------------------------------------------------
# Flash attention (jnp; chunked over KV with online softmax)
# ---------------------------------------------------------------------------


def flash_attention(q, k, v, *, mask_kind: str = "causal", window: int = 0,
                    q_offset=0, kv_len=None, kv_chunk: int = 512,
                    chunk_size: int = 0):
    """q [B, Sq, H, D]; k, v [B, Sk, KvH, D] -> [B, Sq, H, D].

    mask_kind: "causal" | "full" (encoder / cross)
    window: >0 restricts to the last `window` positions (sliding window,
            with mask_kind="causal"); chunk_size >0 = llama4-style chunked
            local attention (tokens attend within their chunk only).
    q_offset: absolute position of q[0] (scalar or [B]).
    kv_len:   [B] valid KV length (None = all valid).
    """
    B, Sq, H, D = q.shape
    Sk, KvH = k.shape[1], k.shape[2]
    G = H // KvH
    scale = D ** -0.5
    kv_chunk = min(kv_chunk, Sk)
    n_chunks = -(-Sk // kv_chunk)
    Skp = n_chunks * kv_chunk
    if Skp != Sk:
        k = jnp.pad(k, ((0, 0), (0, Skp - Sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Skp - Sk), (0, 0), (0, 0)))

    qf = q.reshape(B, Sq, KvH, G, D).astype(jnp.float32) * scale
    q_pos = (jnp.asarray(q_offset).reshape(-1, 1)
             + jnp.arange(Sq)[None, :])                     # [B|1, Sq]
    valid_len = (jnp.full((B,), Sk) if kv_len is None
                 else jnp.asarray(kv_len)).reshape(B, 1)

    kc = k.reshape(B, n_chunks, kv_chunk, KvH, D)
    vc = v.reshape(B, n_chunks, kv_chunk, KvH, D)

    def step(carry, inp):
        m_prev, l_prev, acc = carry
        kb, vb, ci = inp                                     # [B,ck,KvH,D]
        kb = kb.astype(jnp.float32)
        s = jnp.einsum("bqnhd,bknd->bqnhk", qf, kb)         # [B,Sq,KvH,G,ck]
        kv_pos = ci * kv_chunk + jnp.arange(kv_chunk)        # [ck]
        ok = kv_pos[None, :] < valid_len                     # [B, ck]
        if mask_kind == "causal":
            cm = q_pos[:, :, None] >= kv_pos[None, None, :]  # [B,Sq,ck]
            if window > 0:
                cm &= q_pos[:, :, None] - kv_pos[None, None, :] < window
            if chunk_size > 0:
                cm &= (q_pos[:, :, None] // chunk_size) == \
                      (kv_pos[None, None, :] // chunk_size)
            ok = ok[:, None, :] & cm                         # [B,Sq,ck]
        else:
            ok = jnp.broadcast_to(ok[:, None, :], (B, Sq, kv_chunk))
        s = jnp.where(ok[:, :, None, None, :], s, -1e30)
        m_cur = jnp.maximum(m_prev, s.max(-1))               # [B,Sq,KvH,G]
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[..., None])
        p = jnp.where(ok[:, :, None, None, :], p, 0.0)
        l_cur = l_prev * alpha + p.sum(-1)
        pv = jnp.einsum("bqnhk,bknd->bqnhd", p, vb.astype(jnp.float32))
        acc = acc * alpha[..., None] + pv
        return (m_cur, l_cur, acc), None

    m0 = jnp.full((B, Sq, KvH, G), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Sq, KvH, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, KvH, G, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
         jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, Sq, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block (GQA; global / local / chunk / cross)
# ---------------------------------------------------------------------------


def init_attention(key: nn.KeyGen, cfg: ArchConfig, *, cross: bool = False):
    c = nn.ParamCollector()
    E, H, KvH, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    del cross  # frontend embeddings are projected to d_model upstream
    c.add("wq", nn.dense(key(), E, (H, Dh), ("embed", "heads", "head_dim")))
    c.add("wk", nn.dense(key(), E, (KvH, Dh),
                         ("embed", "kv_heads", "head_dim")))
    c.add("wv", nn.dense(key(), E, (KvH, Dh),
                         ("embed", "kv_heads", "head_dim")))
    c.add("wo", nn.dense(key(), H * Dh, E, ("heads_flat", "embed"),
                         scale=1.0 / math.sqrt(H * Dh)))
    if cfg.qkv_bias:
        c.add("bq", nn.zeros((H, Dh), ("heads", "head_dim")))
        c.add("bk", nn.zeros((KvH, Dh), ("kv_heads", "head_dim")))
        c.add("bv", nn.zeros((KvH, Dh), ("kv_heads", "head_dim")))
    return c.params, c.axes


def attention_qkv(p, x, cfg: ArchConfig, kv_src=None):
    """Project to q [B,S,H,D], k/v [B,Skv,KvH,D]."""
    dt = x.dtype
    kv_src = x if kv_src is None else kv_src
    q = jnp.einsum("bse,ehd->bshd", x, p["wq"].astype(dt))
    k = jnp.einsum("bse,ehd->bshd", kv_src, p["wk"].astype(kv_src.dtype))
    v = jnp.einsum("bse,ehd->bshd", kv_src, p["wv"].astype(kv_src.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(kv_src.dtype)
        v = v + p["bv"].astype(kv_src.dtype)
    return q, k, v


def attention_out(p, o, cfg: ArchConfig):
    B, S, H, Dh = o.shape
    return jnp.einsum("bsf,fe->bse", o.reshape(B, S, H * Dh),
                      p["wo"].astype(o.dtype))


def attention_block(p, x, cfg: ArchConfig, kind: str, *, positions,
                    frontend_kv=None, kv_chunk: int = 512):
    """Full-sequence attention (train / prefill)."""
    if kind == "cross":
        # no RoPE across modalities: q/kv have no shared position geometry
        q, k, v = attention_qkv(p, x, cfg, kv_src=frontend_kv)
        o = flash_attention(q, k, v, mask_kind="full", kv_chunk=kv_chunk)
    else:
        q, k, v = attention_qkv(p, x, cfg)
        q = rope(q, positions, theta=cfg.rope_theta,
                 fraction=cfg.rope_fraction)
        k = rope(k, positions, theta=cfg.rope_theta,
                 fraction=cfg.rope_fraction)
        window = cfg.window if kind == "local" else 0
        chunk = cfg.window if kind == "chunk" else 0
        mask = "full" if kind == "encoder" else "causal"
        o = flash_attention(q, k, v, mask_kind=mask, window=window,
                            chunk_size=chunk, q_offset=positions[..., 0],
                            kv_chunk=kv_chunk)
    return attention_out(p, o, cfg)


# ---------------------------------------------------------------------------
# MLP (gated SwiGLU / GeGLU)
# ---------------------------------------------------------------------------


def init_mlp(key: nn.KeyGen, cfg: ArchConfig):
    c = nn.ParamCollector()
    E, F = cfg.d_model, cfg.d_ff
    g = 2 if cfg.gated_mlp else 1
    c.add("wi", nn.dense(key(), E, (g, F), ("embed", "gate", "mlp")))
    c.add("wo", nn.dense(key(), F, E, ("mlp", "embed")))
    return c.params, c.axes


def _act(x, kind: str):
    return jax.nn.silu(x) if kind == "silu" else jax.nn.gelu(x)


def mlp_block(p, x, cfg: ArchConfig):
    from repro.distributed import actsharding
    dt = x.dtype
    wi = actsharding.gathered_weight(p["wi"].astype(dt), model_dim=-1)
    wo = actsharding.gathered_weight(p["wo"].astype(dt), model_dim=0)
    h = jnp.einsum("bse,egf->bsgf", x, wi)
    h = actsharding.constrain_hidden(h)
    if cfg.gated_mlp:
        h = _act(h[..., 0, :], cfg.act) * h[..., 1, :]
    else:
        h = _act(h[..., 0, :], cfg.act)
    return jnp.einsum("bsf,fe->bse", h, wo)


# ---------------------------------------------------------------------------
# MoE (top-k router, capacity-bounded sort-based dispatch)
# ---------------------------------------------------------------------------


def init_moe(key: nn.KeyGen, cfg: ArchConfig):
    c = nn.ParamCollector()
    E, F, X = cfg.d_model, cfg.d_ff, cfg.n_experts
    c.add("router", nn.dense(key(), E, X, ("embed", "experts")))
    c.add("wi", nn.dense(key(), X, (E, 2, F),
                         ("experts", "embed", "gate", "expert_mlp")))
    c.add("wo", nn.dense(key(), X, (F, E),
                         ("experts", "expert_mlp", "embed")))
    return c.params, c.axes


def moe_block(p, x, cfg: ArchConfig, *, dropless: bool = False):
    """Token-choice top-k MoE.

    Two dispatch strategies sharing the router:
      * capacity-bounded (default; SPMD-friendly): tokens sorted by expert
        are gathered into an [X, C, E] buffer (overflow dropped — standard
        capacity-factor semantics) and batch-matmul'd per expert.
      * dropless (serving): lax.ragged_dot over the expert-sorted tokens —
        exact, FLOPs proportional to routed tokens, no drops.
    """
    B, S, E = x.shape
    X, K = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, E)
    logits = jnp.einsum("te,ex->tx", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, K)                   # [T, K]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    flat_e = idx.reshape(-1)                              # [T*K]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(sorted_e, length=X)
    src_tok = order // K
    wi = p["wi"].astype(xt.dtype)
    wo = p["wo"].astype(xt.dtype)

    if dropless:
        xs = xt[src_tok]                                  # [T*K, E]
        gs = counts.astype(jnp.int32)
        h0 = jax.lax.ragged_dot(xs, wi[:, :, 0], gs)
        if cfg.gated_mlp:
            h1 = jax.lax.ragged_dot(xs, wi[:, :, 1], gs)
            h = _act(h0, cfg.act) * h1
        else:
            h = _act(h0, cfg.act)
        routed = jax.lax.ragged_dot(h, wo, gs)            # [T*K, E]
    else:
        C = int(cfg.capacity_factor * T * K / X) + 1
        starts = jnp.cumsum(counts) - counts
        pos_in_e = jnp.arange(T * K) - starts[sorted_e]
        keep = pos_in_e < C
        slot = jnp.where(keep, sorted_e * C + pos_in_e, X * C)
        buf = jnp.zeros((X * C + 1, E), xt.dtype).at[slot].set(xt[src_tok])
        buf = buf[:-1].reshape(X, C, E)
        h = jnp.einsum("xce,xegf->xcgf", buf, wi)
        h = _act(h[..., 0, :], cfg.act) * h[..., 1, :]
        out = jnp.einsum("xcf,xfe->xce", h, wo)
        out_flat = out.reshape(X * C, E)
        routed = jnp.where(keep[:, None],
                           out_flat[jnp.minimum(slot, X * C - 1)], 0.0)

    g = gate.reshape(-1)[order]
    y = jax.ops.segment_sum(routed * g[:, None], src_tok, num_segments=T)
    return y.reshape(B, S, E).astype(x.dtype), probs


def moe_aux_loss(probs, idx_unused=None):
    """Switch-style load-balance loss (mean prob * fraction routed)."""
    me = probs.mean(0)
    return (me * me * probs.shape[-1]).sum()


# ---------------------------------------------------------------------------
# RG-LRU recurrent block (RecurrentGemma, arXiv:2402.19427)
# ---------------------------------------------------------------------------


def init_rglru(key: nn.KeyGen, cfg: ArchConfig):
    c = nn.ParamCollector()
    E = cfg.d_model
    W = cfg.lru_width or E
    c.add("wx", nn.dense(key(), E, W, ("embed", "mlp")))       # input branch
    c.add("wy", nn.dense(key(), E, W, ("embed", "mlp")))       # gate branch
    c.add("conv_w", nn.zeros((4, W), ("conv", "mlp")))
    c.add("conv_b", nn.zeros((W,), ("mlp",)))
    c.add("wa", nn.dense(key(), W, W, ("mlp", "mlp2")))        # recurrence gate
    c.add("ba", nn.zeros((W,), ("mlp",)))
    c.add("wi", nn.dense(key(), W, W, ("mlp", "mlp2")))        # input gate
    c.add("bi", nn.zeros((W,), ("mlp",)))
    c.add("lam", nn.ones((W,), ("mlp",)))                      # Lambda param
    c.add("wo", nn.dense(key(), W, E, ("mlp", "embed")))
    return c.params, c.axes


def _causal_conv1d(x, w, b, state=None):
    """x [B, S, W]; w [K, W] depthwise; optional state [B, K-1, W]."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], 1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K)) + b
    new_state = xp[:, -(K - 1):] if K > 1 else None
    return out.astype(x.dtype), new_state


def rglru_scan(a, gx, h0=None):
    """h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * gx_t, via associative scan."""
    B, S, W = a.shape
    mult = jnp.sqrt(jnp.maximum(1.0 - a ** 2, 1e-9))
    b = mult * gx

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    af, bf = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = bf if h0 is None else bf + af * h0[:, None, :]
    return h, h[:, -1]


def rglru_block(p, x, cfg: ArchConfig, state=None):
    """Griffin recurrent block.  state = (conv_state, h_state) or None.
    Returns (y, new_state)."""
    dt = x.dtype
    gate = jax.nn.gelu(jnp.einsum("bse,ew->bsw", x, p["wy"].astype(dt)))
    u = jnp.einsum("bse,ew->bsw", x, p["wx"].astype(dt))
    conv_state = state[0] if state is not None else None
    u, new_conv = _causal_conv1d(u, p["conv_w"].astype(dt) + _conv_id(p),
                                 p["conv_b"].astype(dt), conv_state)
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["wa"].astype(jnp.float32) + p["ba"])
    i = jax.nn.sigmoid(uf @ p["wi"].astype(jnp.float32) + p["bi"])
    log_a = -8.0 * r * jax.nn.softplus(p["lam"])   # c=8 per the paper
    a = jnp.exp(log_a)
    gx = i * uf
    h0 = state[1] if state is not None else None
    h, h_last = rglru_scan(a, gx, h0)
    y = (h.astype(dt) * gate)
    y = jnp.einsum("bsw,we->bse", y, p["wo"].astype(dt))
    return y, (new_conv, h_last)


def _conv_id(p):
    """Identity kernel at the last tap so a zero-init conv passes input."""
    w = jnp.zeros_like(p["conv_w"])
    return w.at[-1].set(1.0)


# ---------------------------------------------------------------------------
# Mamba2 SSD block (arXiv:2405.21060)
# ---------------------------------------------------------------------------


def init_mamba2(key: nn.KeyGen, cfg: ArchConfig):
    c = nn.ParamCollector()
    E = cfg.d_model
    Din = cfg.d_inner_mult * E
    H = Din // cfg.ssm_head_dim
    G, N = cfg.ssm_groups, cfg.ssm_state
    c.add("in_proj", nn.dense(key(), E, 2 * Din + 2 * G * N + H,
                              ("embed", "mlp")))
    c.add("conv_w", nn.zeros((cfg.conv_kernel, Din + 2 * G * N),
                             ("conv", "mlp")))
    c.add("conv_b", nn.zeros((Din + 2 * G * N,), ("mlp",)))
    c.add("a_log", nn.zeros((H,), ("heads",)))
    c.add("dt_bias", nn.zeros((H,), ("heads",)))
    c.add("d_skip", nn.ones((H,), ("heads",)))
    c.add("norm_scale", nn.ones((Din,), ("mlp",)))
    c.add("out_proj", nn.dense(key(), Din, E, ("mlp", "embed")))
    return c.params, c.axes


def mamba2_split(cfg: ArchConfig):
    E = cfg.d_model
    Din = cfg.d_inner_mult * E
    H = Din // cfg.ssm_head_dim
    G, N = cfg.ssm_groups, cfg.ssm_state
    return Din, H, G, N


def mamba2_block(p, x, cfg: ArchConfig, state=None, *, use_kernel=False):
    """Mamba2 block. state = (conv_state, ssd_state [B,H,P,N]) or None.
    Returns (y, new_state)."""
    from repro.kernels.ssd_scan import ops as ssd_ops, ref as ssd_ref
    dt_ = x.dtype
    B_, S, E = x.shape
    Din, H, G, N = mamba2_split(cfg)
    P = cfg.ssm_head_dim
    zxbcdt = jnp.einsum("bse,ef->bsf", x, p["in_proj"].astype(dt_))
    z, xbc, dt = jnp.split(zxbcdt, [Din, 2 * Din + 2 * G * N], axis=-1)
    conv_state = state[0] if state is not None else None
    xbc, new_conv = _causal_conv1d(
        xbc, p["conv_w"].astype(dt_) + _conv_id_wide(p),
        p["conv_b"].astype(dt_), conv_state)
    xbc = jax.nn.silu(xbc)
    xs, Bc, Cc = jnp.split(xbc, [Din, Din + G * N], axis=-1)
    xs = xs.reshape(B_, S, H, P)
    Bc = Bc.reshape(B_, S, G, N)
    Cc = Cc.reshape(B_, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
    a = jnp.exp(-dt * jnp.exp(p["a_log"]))
    x_in = xs * dt[..., None].astype(dt_)
    ssd_state = state[1] if state is not None else None
    if S == 1 and ssd_state is not None:
        new_ssd, y = ssd_ref.ssd_decode_step(
            ssd_state, x_in[:, 0].astype(jnp.float32), a[:, 0],
            Bc[:, 0].astype(jnp.float32), Cc[:, 0].astype(jnp.float32))
        y = y[:, None]
    else:
        fn = ssd_ops.ssd_scan if use_kernel else ssd_ref.ssd_scan
        y, new_ssd = fn(x_in, a, Bc, Cc)
    y = y.reshape(B_, S, Din).astype(dt_) + \
        (xs * p["d_skip"][:, None].astype(dt_)).reshape(B_, S, Din)
    # gated RMSNorm then out-projection
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    yf = yf * jax.lax.rsqrt((yf ** 2).mean(-1, keepdims=True) + 1e-6)
    y = (yf * p["norm_scale"]).astype(dt_)
    out = jnp.einsum("bsf,fe->bse", y, p["out_proj"].astype(dt_))
    return out, (new_conv, new_ssd)


def _conv_id_wide(p):
    w = jnp.zeros_like(p["conv_w"])
    return w.at[-1].set(1.0)
