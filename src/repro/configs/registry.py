"""--arch registry: maps architecture ids to their assigned configs."""
from __future__ import annotations

import importlib

from repro.models.config import ArchConfig, reduced

_MODULES = {
    "gemma3-12b": "repro.configs.gemma3_12b",
    "llama-3.2-vision-11b": "repro.configs.llama_3_2_vision_11b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "starcoder2-3b": "repro.configs.starcoder2_3b",
    "chatglm3-6b": "repro.configs.chatglm3_6b",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick_400b_a17b",
    "qwen2.5-14b": "repro.configs.qwen2_5_14b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "mamba2-780m": "repro.configs.mamba2_780m",
}

ARCH_IDS = tuple(_MODULES)

#: input shapes assigned to this paper
SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, mode="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, mode="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, mode="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, mode="decode"),
}

#: archs with a sub-quadratic long-context story (see DESIGN.md) —
#: the only ones that run long_500k.
LONG_CONTEXT_ARCHS = (
    "gemma3-12b", "recurrentgemma-9b", "starcoder2-3b",
    "llama4-maverick-400b-a17b", "mixtral-8x22b", "mamba2-780m",
)


def get_config(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(_MODULES[arch_id])
    return mod.CONFIG


def get_reduced_config(arch_id: str, **kw) -> ArchConfig:
    return reduced(get_config(arch_id), **kw)


def shape_supported(arch_id: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch_id in LONG_CONTEXT_ARCHS
    return True
