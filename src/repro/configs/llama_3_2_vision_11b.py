"""llama-3.2-vision-11b [vlm] — 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256; cross-attention image layers every 5th layer.
[hf:meta-llama/Llama-3.2-11B-Vision]

The ViT vision encoder + projector is a STUB per the assignment carve-out:
``input_specs()`` provides precomputed patch embeddings [B, 1600, 1280]
(the transformer backbone implemented here consumes them via gated
cross-attention layers).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    arch_type="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    layer_pattern=("global", "global", "global", "global", "cross"),
    rope_theta=500_000.0,
    act="silu",
    tie_embeddings=False,
    frontend="vision",
    frontend_len=1600,
    frontend_dim=1280,
)
