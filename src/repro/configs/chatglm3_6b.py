"""chatglm3-6b [dense] — 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024; 2d/partial RoPE (rotary on half the head dims), GQA, QKV bias.
[arXiv:2406.12793]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b",
    arch_type="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab=65024,
    layer_pattern=("global",),
    rope_theta=10_000.0,
    rope_fraction=0.5,
    qkv_bias=True,
    act="silu",
    tie_embeddings=False,
)
