"""gemma3-12b [dense] — 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144; 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt family]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b",
    arch_type="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab=262144,
    layer_pattern=("local", "local", "local", "local", "local", "global"),
    window=1024,
    rope_theta=1_000_000.0,
    act="gelu",
    embed_scale=True,
    tie_embeddings=True,
)
