"""mixtral-8x22b [moe] — 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, 8 experts top-2 every layer, sliding-window attention (per
assignment). [arXiv:2401.04088]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    arch_type="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=32768,
    layer_pattern=("local",),
    window=4096,
    rope_theta=1_000_000.0,
    act="silu",
    tie_embeddings=False,
    n_experts=8,
    top_k=2,
    moe_every=1,
)
