"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000; RG-LRU + local attention, 2:1 recurrent:attention blocks
(Griffin). [arXiv:2402.19427]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    layer_pattern=("rglru", "rglru", "local"),
    window=2048,
    rope_theta=10_000.0,
    act="gelu",
    embed_scale=True,
    tie_embeddings=True,
    lru_width=4096,
)
