"""qwen2.5-14b [dense] — 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064; GQA with QKV bias. [hf:Qwen/Qwen2.5-0.5B family]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-14b",
    arch_type="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=13824,
    vocab=152064,
    layer_pattern=("global",),
    rope_theta=1_000_000.0,
    qkv_bias=True,
    act="silu",
    tie_embeddings=False,
)
