"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128 experts top-1 every 2nd layer (≈400B total
/ ≈17B active); chunked local attention (8192) with full-attention (NoPE)
layers every 4th. [hf:meta-llama/Llama-4-Scout-17B-16E family]

Early fusion: image tokens enter the shared token stream through the (stub)
frontend embedding path, so the backbone treats them as ordinary positions —
the assignment's frontend carve-out applies to the patch encoder only.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    arch_type="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202048,
    layer_pattern=("chunk", "chunk", "chunk", "global"),
    window=8192,
    rope_theta=500_000.0,
    act="silu",
    tie_embeddings=False,
    n_experts=128,
    top_k=1,
    moe_every=2,
)
