"""starcoder2-3b [dense] — 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152; GQA, RoPE, sliding-window 4096, LayerNorm + plain GeLU MLP.
[arXiv:2402.19173]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b",
    arch_type="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab=49152,
    layer_pattern=("local",),
    window=4096,
    rope_theta=100_000.0,
    norm="layernorm",
    act="gelu",
    gated_mlp=False,
    qkv_bias=True,
    tie_embeddings=True,
)
