"""mamba2-780m [ssm] — 48L d_model=1536 (attention-free) vocab=50280,
ssm_state=128, SSD (state-space duality). [arXiv:2405.21060]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-780m",
    arch_type="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=1,            # unused by the SSD mixer
    n_kv_heads=1,
    d_ff=0,               # Mamba2 blocks have no separate MLP
    vocab=50280,
    layer_pattern=("ssd",),
    ssm_state=128,
    ssm_head_dim=64,
    ssm_groups=1,
    conv_kernel=4,
    d_inner_mult=2,
    tie_embeddings=True,
)
