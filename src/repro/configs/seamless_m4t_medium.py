"""seamless-m4t-medium [audio] — 12L d_model=1024 16H (GQA kv=16) d_ff=4096
vocab=256206; encoder-decoder, multimodal. [arXiv:2308.11596]

The mel-spectrogram + conformer/conv feature frontend is a STUB per the
assignment carve-out: ``input_specs()`` provides precomputed frame
embeddings [B, 1024, 1024] consumed by the 12-layer bidirectional encoder;
the 12-layer text decoder (self + cross attention per block) is implemented
in full.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    arch_type="audio",
    n_layers=12,                   # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    layer_pattern=("global",),
    rope_theta=10_000.0,
    norm="layernorm",
    act="gelu",
    gated_mlp=False,
    qkv_bias=True,
    tie_embeddings=True,
    encoder_layers=12,
    frontend="audio",
    frontend_len=1024,
    frontend_dim=1024,
)
