"""Checkpointing: flattened-key npz for arrays + msgpack metadata.

Host-gather based (arrays are device_get before writing) — suitable for the
CPU/dev environment; on a real pod this would stream per-shard files, which
the format supports by writing one npz per process.
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, params, opt_state=None, *, step: int = 0,
         metadata: dict | None = None) -> None:
    os.makedirs(path, exist_ok=True)
    np.savez(os.path.join(path, "params.npz"), **_flatten(params))
    if opt_state is not None:
        np.savez(os.path.join(path, "opt_state.npz"), **_flatten(opt_state))
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump({"step": step, **(metadata or {})}, f)


def restore(path: str, params_like, opt_state_like=None):
    """Restore into the structure of `params_like` (shapes must match)."""
    def unflatten(like, file):
        flat = dict(np.load(file))
        leaves_with_path = jax.tree_util.tree_flatten_with_path(like)[0]
        treedef = jax.tree_util.tree_structure(like)
        out = []
        for path, leaf in leaves_with_path:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            arr = flat[key]
            assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
            out.append(arr.astype(leaf.dtype))
        return jax.tree_util.tree_unflatten(treedef, out)

    params = unflatten(params_like, os.path.join(path, "params.npz"))
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    if opt_state_like is not None:
        opt_state = unflatten(opt_state_like,
                              os.path.join(path, "opt_state.npz"))
        return params, opt_state, meta
    return params, meta
