"""Training step: loss, gradients, optimizer update (pjit-ready)."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ArchConfig
from repro.training import optimizer as opt


def cross_entropy(logits, targets, mask):
    """Token-mean CE with a numerically-stable logsumexp over the (possibly
    model-sharded) vocab axis."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)


def loss_fn(params, cfg: ArchConfig, batch, *, aux_weight: float = 0.01,
            remat: bool = True, unroll: int = 1):
    logits, aux = T.forward(params, cfg, batch["tokens"],
                            batch.get("frontend"), remat=remat,
                            unroll=unroll)
    ce = cross_entropy(logits[:, :-1], batch["tokens"][:, 1:],
                       batch["mask"][:, 1:].astype(jnp.float32))
    return ce + aux_weight * aux, {"ce": ce, "aux": aux}


def make_train_step(cfg: ArchConfig, opt_cfg: opt.AdamWConfig, *,
                    remat: bool = True, unroll: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  jit/pjit is applied by the caller (launcher / dry-run)."""

    def train_step(params, opt_state, batch):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, remat=remat, unroll=unroll),
            has_aux=True,
        )(params)
        params, opt_state, om = opt.apply(opt_cfg, params, grads, opt_state)
        metrics = {"loss": loss, **parts, **om}
        return params, opt_state, metrics

    return train_step
