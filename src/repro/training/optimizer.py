"""AdamW + global-norm clipping + cosine schedule (pure JAX, no optax)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init(params) -> OptState:
    zeros = jax.tree.map(lambda x: jnp.zeros_like(x, dtype=jnp.float32),
                         params)
    return OptState(jnp.zeros((), jnp.int32), zeros,
                    jax.tree.map(jnp.copy, zeros))


def schedule(cfg: AdamWConfig, step) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.asarray(leaves)))


def apply(cfg: AdamWConfig, params, grads, state: OptState):
    """Returns (new_params, new_state, metrics)."""
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gn + 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, OptState(step, new_m, new_v), \
        {"grad_norm": gn, "lr": lr}
