"""Window telemetry: donated-carry counter deltas -> per-tenant metrics.

The measurement layer of the closed control loop.  Every window the
control plane polls a handful of ``[B, n_max]`` cumulative hardware
counters off the donated engine carry (``FLEET_POLL_KEYS`` — the MMIO
poll; completion rings stay on device).  This module owns everything
derived from those counters:

* ``fleet_counters`` / ``measured_rates`` — the raw-delta helpers the
  serial (``ArcusRuntime._algorithm1_pass``) and batch
  (``FleetController._fleet_pass``) paths share.  Elementwise float64:
  one server's row is bitwise-identical whether computed serially
  (``[n]``) or as a fleet slab (``[B, n_max]``).
* ``WindowMetrics`` — the per-tenant digest a ``ControlPolicy``
  consumes: measured rate in the flow's own SLO unit, fractional SLO
  slack, violation streak, mean completion latency, and per-resource-
  axis utilization along the PR 6 shaped-resource vector.

Latency here is a *measured* quantity: the dataplane accumulates each
completion's queueing+service latency (in cycles) into ``c_lat_sum``,
so a window's mean latency is a pure counter-delta ratio — no
completion-ring readback.  Latency-SLO violations derived from it feed
ONLY ``WindowMetrics`` (and the policies riding on it); the legacy
``WindowReport.violated`` list keeps its rate-SLO-only semantics, which
is what keeps ``StaticHold`` runs bitwise-identical to the
pre-telemetry controller.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import sim
from repro.core.flow import PATH_EGRESS_DIR, PATH_INGRESS_DIR, SLOKind

#: per-window counter reads (the fleet MMIO poll) — the completion rings
#: stay on device until the final window, so the control plane's per-window
#: device_get is a few [B, n_max] arrays, not the multi-megabyte history
FLEET_POLL_KEYS = ("c_adm_msgs", "c_adm_b_lo", "c_adm_b_hi", "c_done_msgs",
                   "c_done_b_lo", "c_done_b_hi", "c_drops", "c_lat_sum")


def fleet_counters(host: dict) -> dict[str, np.ndarray]:
    """[B, n_max] counter arrays in the exact form serial ``SimResult``
    counters take (hi/lo byte counters recombined into int64)."""
    cur = {k: np.asarray(host[k])
           for k in ("c_adm_msgs", "c_done_msgs", "c_drops", "c_lat_sum")}
    cur["c_adm_bytes"] = sim.combine_byte_counters(host["c_adm_b_hi"],
                                                   host["c_adm_b_lo"])
    cur["c_done_bytes"] = sim.combine_byte_counters(host["c_done_b_hi"],
                                                    host["c_done_b_lo"])
    return cur


def measured_rates(cur: dict, prev: dict, kind: np.ndarray,
                   window_s: float) -> np.ndarray:
    """SLOViolationChecker measurement (Algorithm 1 lines 11-13),
    vectorized over trailing flow axes: per-flow achieved rate in the
    flow's own SLO unit (IOPS or Gbps of ingress payload).  Elementwise
    float64 — one server's row is bitwise-identical whether computed
    serially ([n]) or as a fleet slab ([B, n_max])."""
    meas_iops = (cur["c_done_msgs"] - prev["c_done_msgs"]) / window_s
    meas_gbps = ((cur["c_done_bytes"] - prev["c_done_bytes"])
                 * 8 / window_s / 1e9)
    return np.where(kind == int(SLOKind.IOPS), meas_iops, meas_gbps)


def mean_latency_s(cur: dict, prev: dict, clock_hz: float) -> np.ndarray:
    """Mean completion latency over the window, per flow lane, in seconds
    (NaN where the window completed nothing).  ``c_lat_sum`` accumulates
    per-completion latency in cycles, so this is a pure delta ratio."""
    d_msgs = np.asarray(cur["c_done_msgs"] - prev["c_done_msgs"], np.float64)
    d_lat = np.asarray(cur["c_lat_sum"] - prev["c_lat_sum"], np.float64)
    with np.errstate(invalid="ignore"):
        return np.where(d_msgs > 0, d_lat / np.maximum(d_msgs, 1.0)
                        / clock_hz, np.nan)


def admitted_gbps(cur: dict, prev: dict, window_s: float) -> np.ndarray:
    """Ingress payload the shaper admitted this window, in Gbps per lane
    (the demand side of the utilization vector — what the token buckets
    actually let through, as opposed to what completed)."""
    return (cur["c_adm_bytes"] - prev["c_adm_bytes"]) * 8 / window_s / 1e9


def _axis_coefs(spec, accel, rs) -> tuple[float, float]:
    """(ingress, egress) Gbps charged on resource axis ``rs`` per Gbps of
    flow traffic — the host-side mirror of ``engine._resource_tables``
    (same resolution order: flow ``res_demand`` hint, else the
    accelerator's, else 1/1; ``fabric_only`` axes charge nothing for
    off-fabric stage directions)."""
    ic = ec = None
    for nm, a, b in getattr(spec, "res_demand", ()):
        if nm == rs.name:
            ic, ec = float(a), float(b)
            break
    if ic is None:
        ic, ec = accel.resource_demand(rs.name)
    if rs.fabric_only:
        if PATH_INGRESS_DIR[spec.path] == 2:
            ic = 0.0
        if PATH_EGRESS_DIR[spec.path] == 2:
            ec = 0.0
    return max(ic, 0.0), max(ec, 0.0)


def flow_axis_util(spec, accel, link, adm_gbps: float) -> tuple[float, ...]:
    """One flow's utilization of every shaped resource axis.

    Axis 0 is the flow's ingress link direction (admitted Gbps over the
    direction's effective bandwidth; off-fabric paths use 0); each extra
    axis mirrors one ``LinkSpec.resources`` entry, charging admitted
    ingress plus the device's egress echo through the flow's demand
    coefficients.  Fractions of capacity, so a ``ControlPolicy`` can
    compare axes directly."""
    d = PATH_INGRESS_DIR[spec.path]
    caps = (link.h2d_gbps, link.d2h_gbps)
    link_cap = caps[d] * link.efficiency if d < 2 else 0.0
    out = [adm_gbps / link_cap if link_cap > 0 else 0.0]
    eg_ratio = (float(accel.egress_bytes(np.asarray(
        [float(spec.pattern.msg_bytes)]))[0]) / max(spec.pattern.msg_bytes, 1))
    for rs in getattr(link, "resources", ()):
        ic, ec = _axis_coefs(spec, accel, rs)
        charged = adm_gbps * (ic + ec * eg_ratio)
        out.append(charged / max(rs.capacity_gbps, 1e-12))
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class WindowMetrics:
    """One tenant's telemetry digest for one window — what a
    ``ControlPolicy`` sees.

    ``measured`` is always the rate in the flow's SLO unit (Gbps or
    IOPS; latency-SLO flows report their achieved Gbps here too, for
    continuity with ``WindowReport.measured``).  ``slack`` is fractional
    headroom against the SLO: positive = meeting it, negative = how far
    below (rate SLOs: measured/target - 1; latency SLOs:
    1 - lat_avg/bound; NaN when the target is degenerate or nothing
    completed).  ``streak`` counts consecutive violated windows.
    ``util`` is the per-resource-axis utilization vector from
    ``flow_axis_util``."""

    flow_id: int
    lane: int
    kind: int                  # SLOKind value
    target: float              # SLO target in its own unit
    measured: float            # achieved rate (SLO unit; Gbps for latency)
    slack: float               # + meeting SLO, - violating, NaN unknown
    violated: bool
    streak: int
    lat_avg_s: float           # mean completion latency (NaN if none)
    util: tuple[float, ...]    # per-resource-axis utilization fractions

    def to_json(self) -> dict:
        return {"flow_id": self.flow_id, "lane": self.lane,
                "kind": self.kind, "target": self.target,
                "measured": self.measured, "slack": self.slack,
                "violated": self.violated, "streak": self.streak,
                "lat_avg_s": self.lat_avg_s, "util": list(self.util)}

    @staticmethod
    def from_json(d: dict) -> "WindowMetrics":
        return WindowMetrics(
            flow_id=int(d["flow_id"]), lane=int(d["lane"]),
            kind=int(d["kind"]), target=float(d["target"]),
            measured=float(d["measured"]), slack=float(d["slack"]),
            violated=bool(d["violated"]), streak=int(d["streak"]),
            lat_avg_s=float(d["lat_avg_s"]),
            util=tuple(float(u) for u in d["util"]))


def flow_metrics(spec, lane: int, measured: float, lat_s: float,
                 streak_prev: int, util: tuple[float, ...],
                 slo_tol: float) -> WindowMetrics:
    """Fold one flow's window measurements into a ``WindowMetrics``.

    The violation rule matches ``ArcusRuntime._slo_ok`` for rate SLOs
    (measured under target by more than ``slo_tol``); latency SLOs —
    which ``_slo_ok`` always passes, preserving the legacy report — are
    judged here against their bound with the same tolerance, so policies
    can react to tail-latency pressure the legacy loop cannot see."""
    kind = spec.slo.kind
    target = float(spec.slo.target)
    if kind == SLOKind.LATENCY:
        violated = bool(np.isfinite(lat_s)
                        and lat_s > target * (1 + slo_tol))
        slack = 1.0 - lat_s / target if (np.isfinite(lat_s)
                                         and target > 0) else float("nan")
    else:
        violated = bool(measured < target * (1 - slo_tol))
        slack = (measured / target - 1.0) if target > 0 else float("nan")
    return WindowMetrics(
        flow_id=spec.flow_id, lane=lane, kind=int(kind), target=target,
        measured=float(measured), slack=float(slack), violated=violated,
        streak=streak_prev + 1 if violated else 0,
        lat_avg_s=float(lat_s), util=util)
