"""Compiled dataplane engine: cached jit, donated carries, vmap batching.

The cycle-accurate simulator in ``repro.core.sim`` used to close its jitted
``lax.scan`` over every input (arrival trace, stall mask, window start, flow
tables, link parameters), so *each* ``simulate()`` call re-traced and
re-compiled the whole tick loop.  The control plane (``ArcusRuntime.run_managed``,
Algorithm 1) reconfigures shaping registers every window, which made XLA
compile time — not simulated ticks — the dominant cost.

This module splits trace-time constants from runtime data:

* **static** (compile-cache key): the *structural* ``SimConfig`` fields (tick
  counts, queue depths, grant widths) plus the shapes of the flow set,
  accelerator tables, arrival traces and stall mask;
* **traced** (plain arguments): the arrival trace, stall mask, window start
  ``t0``, per-flow routing/weight tables, the **per-flow validity mask**,
  accelerator service tables, link rates, the shaping / arbiter mode words,
  the software-shaping delay model, and the full carry — including the
  TBState parameter "registers", so a live register write (Sec. 5.3.1
  "Dynamism") never retraces.

Because the shaping mode and arbiter are traced *mode words* rather than
compile-time constants, heterogeneous system configurations (Arcus vs the
Host/Bypassed baselines of Sec. 5.1) share one compiled engine and can run
as lanes of the same ``jax.vmap`` batch.

Compiled entry points are cached at module level (``_RUN_CACHE``); the carry
is donated (``donate_argnums``) so window-to-window resumption reuses device
buffers instead of copying the ~30-array carry each window.

``run_window_batch`` wraps the same core in ``jax.vmap`` over a leading batch
axis of (arrival trace, TBState registers, optionally flow tables, system
mode words, accelerator/link tables and stall masks).  Flow sets with
*different flow counts* are padded to a shared ``n_flows_max`` and masked
with ``fl_mask``: padded lanes never receive arrivals, are never eligible
for grants, and the arbiter keys are computed modulo the *active* flow
count, so every counter of an active lane is bitwise-identical to a serial
unpadded run.

Accelerator tables batch the same way: elements with *different accelerator
counts* are padded to a shared ``n_accels_max`` (``pad_accel_table``) with a
per-accelerator validity mask ``ac_mask`` threaded through the pipeline —
padded accelerators have every lane disabled, are never routed to (flow
tables only reference active accelerators), never start service, and the
software-shaping host-delay LCG advances once per *active* service
iteration only, so a padded element stays bitwise-identical to its serial
unpadded run in every shaping mode.

``run_window_batch`` also accepts a resumed ``carry`` (with fresh per-element
TBState registers applied, exactly like ``run_window``): this is what lets
``ArcusRuntime.run_managed_batch`` drive B client servers' control loops as
one compiled program, re-provisioning token buckets between windows.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import token_bucket as tb
from repro.core.accelerator import GRID_N, AccelTable, interp_grid
from repro.core.flow import FlowSet, Path
from repro.core.interconnect import (ARB_PRIORITY, ARB_RR, ARB_WFQ, ARB_WRR,
                                     LinkSpec)

SHAPING_NONE = 0
SHAPING_HW = 1
SHAPING_SW = 2

INF_I32 = np.int32(2**31 - 1)
_LCG_A = np.int32(1103515245)
_LCG_C = np.int32(12345)


def _own_tb(tb_state: tb.TBState) -> tb.TBState:
    """Copy TBState leaves into engine-owned buffers.

    The carry is donated to the compiled engine, so it must not alias the
    caller's arrays (donation would invalidate them) nor alias itself
    (``tb.init`` starts ``tokens`` as the very ``bkt_size`` buffer, and XLA
    rejects donating one buffer twice)."""
    return jax.tree_util.tree_map(lambda x: jnp.array(x, copy=True),
                                  tb_state)


@dataclasses.dataclass(frozen=True)
class SimConfig:
    n_ticks: int
    tick_cycles: int = 8
    clock_hz: float = 250e6
    qlen: int = 256            # per-flow queue slots
    aq_len: int = 256          # per-accelerator queue slots
    aq_byte_cap: int = 1 << 20  # shared accel input buffer (bytes) — large
                                # messages congest it (Sec. 3.1 / Fig. 8)
    eq_len: int = 2048         # per-direction egress queue slots
    comp_cap: int = 1 << 15    # completion record ring capacity
    k_arr: int = 4             # max arrivals drained per flow per tick
    k_grant: int = 4           # max arbiter grants per tick
    k_srv: int = 2             # service starts per accelerator per tick
    k_eg: int = 4              # egress pops per direction per tick
    lmax: int = 16             # max accelerator lanes
    shaping: int = SHAPING_HW   # traced mode word — NOT in the compile key
    arbiter: int = ARB_RR       # traced mode word — NOT in the compile key
    # software-shaping pathology model (traced — NOT in the compile key)
    sw_host_delay_cycles: int = 500      # ~2 us base host processing delay
    sw_jitter_cycles: int = 2500         # up to +10 us heavy-tail jitter
    # one-shot vectorized grant selection for uncontended RR ticks (falls
    # back to the sequential argmin loop whenever semantics require it)
    grant_fast: bool = True
    # one-shot vectorized accelerator-service and egress stages.  Egress is
    # always vectorized under this flag; the service stage additionally
    # requires A * k_srv >= 8 (below that the unrolled loop wins on CPU)
    # and falls back to the sequential loop whenever a lane could chain
    # back-to-back messages within one tick.
    stage_fast: bool = True
    # service-vectorization width threshold: the one-shot service stage
    # engages when A * k_srv >= service_vec_min (8 was measured on XLA-CPU;
    # other backends want other knees).  Structural — part of the compile
    # key, NOT traced.  Default comes from $REPRO_SERVICE_VEC_MIN.
    service_vec_min: int = dataclasses.field(
        default_factory=lambda: int(
            os.environ.get("REPRO_SERVICE_VEC_MIN", "8")))

    @property
    def seconds(self) -> float:
        return self.n_ticks * self.tick_cycles / self.clock_hz


#: SimConfig fields passed to the engine as traced values: two SimConfigs
#: differing only in these share one compiled executable (and may be lanes
#: of the same batch).
TRACED_CFG_FIELDS = ("shaping", "arbiter", "sw_host_delay_cycles",
                     "sw_jitter_cycles")


def _static_cfg(cfg: SimConfig) -> SimConfig:
    """Canonical compile-cache form of a SimConfig (traced fields zeroed)."""
    return dataclasses.replace(
        cfg, **{f: 0 for f in TRACED_CFG_FIELDS})


# ---------------------------------------------------------------------------
# Carry construction
# ---------------------------------------------------------------------------


def init_carry(flows: FlowSet, accels: AccelTable, cfg: SimConfig,
               tb_state: tb.TBState, *, n_flows: int | None = None,
               n_res: int = 0) -> dict[str, Any]:
    N, A = (n_flows or flows.n), accels.n
    lanes_busy = np.zeros((A, cfg.lmax), np.float32)
    for a in range(A):
        lanes_busy[a, accels.parallelism[a]:] = np.float32(3e38)  # lane disabled
    return dict(
        # per-flow ingress queues
        q_sz=jnp.zeros((N, cfg.qlen), jnp.int32),
        q_at=jnp.zeros((N, cfg.qlen), jnp.int32),
        q_head=jnp.zeros((N,), jnp.int32),
        q_cnt=jnp.zeros((N,), jnp.int32),
        arr_ptr=jnp.zeros((N,), jnp.int32),
        # shaper
        tb=_own_tb(tb_state),
        sw_pend=jnp.zeros((N,), jnp.int32),
        # arbiter
        rr_ptr=jnp.zeros((), jnp.int32),
        vft=jnp.zeros((N,), jnp.float32),
        # link / credits
        lres=jnp.zeros((2,), jnp.float32),
        # extra resource axes (token-bucket residue: unused budget up to
        # each axis' burst_bytes, or the serialization debt when negative)
        res_res=jnp.zeros((n_res,), jnp.float32),
        credits_used=jnp.zeros((), jnp.int32),
        # accelerator queues + lanes
        aq_sz=jnp.zeros((A, cfg.aq_len), jnp.int32),
        aq_fl=jnp.zeros((A, cfg.aq_len), jnp.int32),
        aq_at=jnp.zeros((A, cfg.aq_len), jnp.int32),
        aq_head=jnp.zeros((A,), jnp.int32),
        aq_cnt=jnp.zeros((A,), jnp.int32),
        aq_bytes=jnp.zeros((A,), jnp.int32),
        lanes=jnp.asarray(lanes_busy),
        # egress queues, one per direction (0 h2d, 1 d2h, 2 off-fabric)
        eq_sz=jnp.zeros((3, cfg.eq_len), jnp.int32),
        eq_isz=jnp.zeros((3, cfg.eq_len), jnp.int32),  # original ingress bytes
        eq_fl=jnp.zeros((3, cfg.eq_len), jnp.int32),
        eq_at=jnp.zeros((3, cfg.eq_len), jnp.int32),
        eq_rd=jnp.zeros((3, cfg.eq_len), jnp.int32),
        eq_head=jnp.zeros((3,), jnp.int32),
        eq_cnt=jnp.zeros((3,), jnp.int32),
        # telemetry ("hardware counters", Arcus step 7)
        c_adm_msgs=jnp.zeros((N,), jnp.int32),
        # exact byte counters, split lo (20 bits) / hi to stay in int32
        c_adm_b_lo=jnp.zeros((N,), jnp.int32),
        c_adm_b_hi=jnp.zeros((N,), jnp.int32),
        c_done_msgs=jnp.zeros((N,), jnp.int32),
        c_done_b_lo=jnp.zeros((N,), jnp.int32),
        c_done_b_hi=jnp.zeros((N,), jnp.int32),
        c_drops=jnp.zeros((N,), jnp.int32),
        c_lat_sum=jnp.zeros((N,), jnp.float32),
        # completion record ring (one scratch slot at index comp_cap)
        comp_fl=jnp.zeros((cfg.comp_cap + 1,), jnp.int32),
        comp_lat=jnp.zeros((cfg.comp_cap + 1,), jnp.int32),
        comp_t=jnp.zeros((cfg.comp_cap + 1,), jnp.int32),
        comp_sz=jnp.zeros((cfg.comp_cap + 1,), jnp.int32),
        comp_n=jnp.zeros((), jnp.int32),
        rng=jnp.asarray(np.int32(0x1234567)),
    )


def reconfigure_carry(carry: dict, tb_state: tb.TBState) -> dict:
    """Live reconfiguration: write only the parameter "registers"
    (Refill_Rate / Bkt_Size / Interval / mode); in-flight tokens and timers
    are hardware state and keep running."""
    carry = dict(carry)
    old = carry["tb"]
    new = _own_tb(tb_state)
    carry["tb"] = old._replace(
        refill_rate=new.refill_rate,
        bkt_size=new.bkt_size,
        interval=new.interval,
        mode=new.mode,
        tokens=jnp.minimum(old.tokens, new.bkt_size),
    )
    return carry


# ---------------------------------------------------------------------------
# Membership-change carry resumption (tenant lifecycle)
# ---------------------------------------------------------------------------


def release_flow_lane(carry: dict, b: int, lane: int) -> dict:
    """Depart: flush one flow lane of a resumed batched carry.

    Queued-but-unadmitted messages are discarded (their bytes were never
    counted — admission counters tick at grant time) and the lane stops
    being grant-eligible via the caller's ``fl_mask``; messages already
    admitted into accelerator/egress queues drain naturally.  Shapes are
    untouched, so resuming the carry stays on the same compiled engine."""
    carry = dict(carry)
    carry["q_cnt"] = carry["q_cnt"].at[b, lane].set(0)
    carry["sw_pend"] = carry["sw_pend"].at[b, lane].set(0)
    return carry


def recycle_flow_lane(carry: dict, b: int, lane: int) -> dict:
    """Arrive: reset a (possibly previously occupied) flow lane so no
    dataplane state leaks from an earlier tenant.

    The arrival pointer rewinds to the lane's (fresh) trace row, the
    ingress queue and arbiter virtual-finish-time reset, and the token
    count is pre-set to INF so the next register write's
    ``min(tokens, bkt_size)`` clamp hands the new tenant a full initial
    bucket (exactly what ``tb.init(start_full=True)`` grants a freshly
    built carry).

    The lane's cumulative hardware counters zero too — the measurement
    baseline reset.  The control plane measures per-window deltas, and a
    delta straddling the splice would mix the departed tenant's totals
    into the newcomer's first measured rate (callers must reset their
    host-side previous-counter snapshot for the lane as well — the
    controller does).  One residue is documented and accepted: messages
    the predecessor already pushed into the accelerator/egress queues
    drain naturally and their completions land on this lane's counters
    (at most the in-flight queue depth, the same tolerance the depart
    path's freeze tests allow)."""
    carry = dict(carry)
    for k in ("q_cnt", "q_head", "arr_ptr", "sw_pend",
              "c_adm_msgs", "c_adm_b_lo", "c_adm_b_hi", "c_done_msgs",
              "c_done_b_lo", "c_done_b_hi", "c_drops"):
        carry[k] = carry[k].at[b, lane].set(0)
    carry["vft"] = carry["vft"].at[b, lane].set(0.0)
    carry["c_lat_sum"] = carry["c_lat_sum"].at[b, lane].set(0.0)
    carry["tb"] = carry["tb"]._replace(
        tokens=carry["tb"].tokens.at[b, lane].set(INF_I32))
    return carry


# ---------------------------------------------------------------------------
# Flow / register padding (ragged multi-tenant batching)
# ---------------------------------------------------------------------------


def pad_tb_state(state: tb.TBState, n_max: int) -> tb.TBState:
    """Pad per-flow TB registers to ``n_max`` lanes with benign parameters
    (interval 1 avoids div-by-zero in the shared timer advance; padded lanes
    are never offered messages, so their token state is inert)."""
    n = int(np.asarray(state.tokens).shape[0])
    if n == n_max:
        return state
    if n > n_max:
        raise ValueError(f"TBState has {n} lanes > n_max={n_max}")
    pad = n_max - n

    def ext(x, fill):
        x = np.asarray(x)
        return np.concatenate([x, np.full((pad,), fill, x.dtype)])

    return tb.TBState(
        tokens=jnp.asarray(ext(state.tokens, 0)),
        cyc=jnp.asarray(ext(state.cyc, 0)),
        refill_rate=jnp.asarray(ext(state.refill_rate, 1)),
        bkt_size=jnp.asarray(ext(state.bkt_size, 1)),
        interval=jnp.asarray(ext(state.interval, 1)),
        mode=jnp.asarray(ext(state.mode, 0)),
    )


def _accel_mask(tab: AccelTable) -> np.ndarray:
    """Per-accelerator validity mask (active = has at least one lane).

    Active accelerators must occupy a prefix of the table: the service
    stage's closed-form LCG draw indexes iterations as ``k * n_active + a``,
    which equals the sequential walk only when every active row precedes
    every padded row (``pad_accel_table`` always appends padding; a
    hand-built table with a mid-table ``parallelism=0`` row would silently
    diverge, so reject it here)."""
    m = np.asarray(tab.parallelism) > 0
    if np.any(~m[:-1] & m[1:]):
        raise ValueError(
            "active accelerators (parallelism > 0) must form a prefix of "
            f"the AccelTable (got parallelism={list(tab.parallelism)})")
    return m


def pad_accel_table(tab: AccelTable, a_max: int) -> AccelTable:
    """Pad an accelerator table to ``a_max`` rows (ragged accel batching).

    Padded accelerators carry benign service/egress curves (never read:
    no flow routes to them) and ``parallelism=0``, which disables every
    lane at ``init_carry`` time — they can never start service."""
    if tab.n == a_max:
        return tab
    if tab.n > a_max:
        raise ValueError(f"AccelTable has {tab.n} accels > a_max={a_max}")
    pad = a_max - tab.n
    return AccelTable(
        n=a_max,
        service_cycles=np.concatenate(
            [tab.service_cycles,
             np.ones((pad, GRID_N), np.float32)]).astype(np.float32),
        egress_bytes=np.concatenate(
            [tab.egress_bytes,
             np.ones((pad, GRID_N), np.float32)]).astype(np.float32),
        parallelism=np.concatenate(
            [tab.parallelism, np.zeros(pad, np.int32)]).astype(np.int32),
        names=list(tab.names) + ["__pad__"] * pad,
        # padded rows carry no spec: spec_of() guards, and no flow ever
        # routes to them anyway
        specs=list(tab.specs),
    )


def _flow_args(flows: FlowSet, n_max: int) -> dict[str, np.ndarray]:
    """Per-flow routing/weight tables padded to ``n_max`` plus the validity
    mask.  Padded lanes route to accel 0 / direction 0 (any in-range value:
    they are never granted) and carry weight 1 to keep 1/w finite."""
    n = flows.n

    def pad(x, fill, dtype):
        x = np.asarray(x, dtype)
        return np.concatenate(
            [x, np.full((n_max - n,), fill, dtype)]) if n_max > n else x

    return dict(
        fl_accel=pad(flows.accel_id, 0, np.int32),
        fl_in_dir=pad(flows.ingress_dir, 0, np.int32),
        fl_eg_dir=pad(flows.egress_dir, 0, np.int32),
        # inline-NIC-RX delivers the full payload to the host no matter what
        # the accelerator emits; other paths transfer the accel's output.
        fl_eg_full=pad(flows.path == int(Path.INLINE_NIC_RX), False, bool),
        fl_prio=pad(flows.priority, 0, np.float32),
        fl_w=pad(np.maximum(flows.weight, 1e-3), 1.0, np.float32),
        fl_mask=pad(np.ones(n, bool), False, bool),
    )


def _resource_tables(flows: FlowSet, accels: AccelTable, link: LinkSpec,
                     n_max: int) -> tuple[np.ndarray, np.ndarray]:
    """Per-flow demand coefficients on the extra resource axes.

    Returns ``(w_in, w_eg)``, each ``[R-1, n_max]`` float32: bytes charged
    on axis r per ingress byte granted / per egress byte popped for flow i.
    Resolution order: a flow's own ``res_demand`` hint, else its
    accelerator's ``AcceleratorSpec.res_demand``, else 1.0/1.0 (every byte
    crosses the axis).  ``fabric_only`` axes charge nothing for off-fabric
    (dir == 2) stage directions.  Padded flow lanes keep 0 coefficients —
    they are never granted, so the value is inert either way."""
    rspecs = getattr(link, "resources", ())
    R = len(rspecs)
    w_in = np.zeros((R, n_max), np.float32)
    w_eg = np.zeros((R, n_max), np.float32)
    specs = getattr(flows, "specs", ())
    for r, rs in enumerate(rspecs):
        for i in range(flows.n):
            sp = specs[i] if i < len(specs) else None
            ic = ec = None
            if sp is not None:
                for nm, a, b in getattr(sp, "res_demand", ()):
                    if nm == rs.name:
                        ic, ec = float(a), float(b)
                        break
            if ic is None:
                aspec = (accels.spec_of(int(flows.accel_id[i]))
                         if hasattr(accels, "spec_of") else None)
                ic, ec = (aspec.resource_demand(rs.name)
                          if aspec is not None else (1.0, 1.0))
            if rs.fabric_only:
                if int(flows.ingress_dir[i]) == 2:
                    ic = 0.0
                if int(flows.egress_dir[i]) == 2:
                    ec = 0.0
            # clamp: negative demand would refill a bucket mid-tick,
            # breaking the eligibility monotonicity the fast grant path
            # relies on
            w_in[r, i] = max(ic, 0.0)
            w_eg[r, i] = max(ec, 0.0)
    return w_in, w_eg


# ---------------------------------------------------------------------------
# Traced-argument packing (everything here may change without a retrace)
# ---------------------------------------------------------------------------


def _window_stall(stall_mask, cfg: SimConfig, t0_ticks) -> np.ndarray:
    """Window-relative stall mask, always ``[n_ticks]`` so the compiled
    signature is independent of the window start ``t0``."""
    if stall_mask is None:
        return np.zeros(cfg.n_ticks, bool)
    stall_mask = np.asarray(stall_mask, bool)
    if stall_mask.shape[-1] == cfg.n_ticks:
        return stall_mask
    t0 = int(t0_ticks)
    if stall_mask.shape[-1] < t0 + cfg.n_ticks:
        raise ValueError(
            f"stall mask covers {stall_mask.shape[-1]} ticks < "
            f"t0+n_ticks={t0 + cfg.n_ticks}")
    return stall_mask[..., t0:t0 + cfg.n_ticks]


def _check_modes(cfg: SimConfig) -> None:
    """Traced mode words bypass compile-time checks — validate up front."""
    if cfg.arbiter not in (ARB_RR, ARB_WRR, ARB_PRIORITY, ARB_WFQ):
        raise ValueError(cfg.arbiter)
    if cfg.shaping not in (SHAPING_NONE, SHAPING_HW, SHAPING_SW):
        raise ValueError(cfg.shaping)


def _pack_args(flows: FlowSet, accels: AccelTable, link: LinkSpec,
               cfg: SimConfig, arr_t, arr_sz, stall_mask,
               t0_ticks) -> dict[str, Any]:
    _check_modes(cfg)
    h2d_bpc, d2h_bpc = link.bytes_per_cycle()
    args = dict(
        arr_t=jnp.asarray(arr_t, jnp.int32),
        arr_sz=jnp.asarray(arr_sz, jnp.int32),
        t0=jnp.asarray(t0_ticks, jnp.int32),
        svc_tab=jnp.asarray(accels.service_cycles, jnp.float32),
        eg_tab=jnp.asarray(accels.egress_bytes, jnp.float32),
        # per-accelerator validity (ragged accel batching): a padded row is
        # never routed to, never serves, and never draws host-delay jitter
        ac_mask=jnp.asarray(_accel_mask(accels), bool),
        bpc=jnp.asarray([h2d_bpc, d2h_bpc], jnp.float32),
        ovh=jnp.asarray(link.msg_overhead_bytes, jnp.float32),
        credits=jnp.asarray(link.credits, jnp.int32),
        # system mode words (Sec. 5.1 configurations) — traced, so
        # heterogeneous baselines share one compiled engine
        mode=jnp.asarray(cfg.shaping, jnp.int32),
        arb=jnp.asarray(cfg.arbiter, jnp.int32),
        sw_delay=jnp.asarray(cfg.sw_host_delay_cycles, jnp.float32),
        sw_jit=jnp.asarray(cfg.sw_jitter_cycles, jnp.float32),
        stall=jnp.asarray(_window_stall(stall_mask, cfg, t0_ticks), bool),
        # extra contended resource axes (R-1 of them; empty arrays in the
        # scalar default, where the whole resource pipeline compiles away)
        res_cap=jnp.asarray(link.resource_caps_per_cycle(), jnp.float32),
        res_burst=jnp.asarray(link.resource_burst_bytes(), jnp.float32),
    )
    w_in, w_eg = _resource_tables(flows, accels, link, flows.n)
    args["res_w_in"] = jnp.asarray(w_in)
    args["res_w_eg"] = jnp.asarray(w_eg)
    for k, v in _flow_args(flows, flows.n).items():
        args[k] = jnp.asarray(v)
    return args


def _args_sig(args: dict[str, Any]) -> tuple:
    return tuple(sorted((k, v.shape) for k, v in args.items()))


# ---------------------------------------------------------------------------
# The tick body
# ---------------------------------------------------------------------------

#: inner pipeline-stage loops (k_grant / k_srv / k_eg, trip counts 2-16) are
#: unrolled into the scan body up to this bound: XLA while-loop per-iteration
#: overhead dominates these tiny bodies on CPU.
_UNROLL_MAX = 32


def _fori(n: int, body, init):
    """fori_loop that statically unrolls small trip counts."""
    if n <= _UNROLL_MAX:
        val = init
        for i in range(n):
            val = body(i, val)
        return val
    return jax.lax.fori_loop(0, n, body, init)


@functools.lru_cache(maxsize=None)
def _lcg_tables(n: int) -> tuple[np.ndarray, np.ndarray]:
    """Closed-form LCG step tables: r_m = r0 * POW[m-1] + SUM[m-1] (int32
    wraparound) equals m iterated ``r = r * A + C`` updates."""
    a, c, m = int(_LCG_A), int(_LCG_C), 1 << 32
    pows, sums = [], []
    p, s = 1, 0
    for _ in range(n):
        p = (p * a) % m
        s = (s * a + c) % m
        pows.append(p)
        sums.append(s)
    to_i32 = lambda v: np.array(v, np.uint32).astype(np.int32)  # noqa: E731
    return to_i32(pows), to_i32(sums)


def _interp_mat(table, msg_bytes_f32):
    """interp_grid over a full [A, K] message matrix (one row per accel)."""
    A = table.shape[0]
    a_grid = jnp.broadcast_to(jnp.arange(A, dtype=jnp.int32)[:, None],
                              msg_bytes_f32.shape)
    return interp_grid(table, a_grid, msg_bytes_f32)


def _tick(cfg: SimConfig, args: dict, carry: dict, t):
    arr_t, arr_sz = args["arr_t"], args["arr_sz"]
    fl_accel, fl_in_dir = args["fl_accel"], args["fl_in_dir"]
    fl_eg_dir, fl_eg_full = args["fl_eg_dir"], args["fl_eg_full"]
    fl_prio, fl_w, fl_mask = args["fl_prio"], args["fl_w"], args["fl_mask"]
    svc_tab, eg_tab = args["svc_tab"], args["eg_tab"]
    ac_mask = args["ac_mask"]
    bpc, ovh, credits = args["bpc"], args["ovh"], args["credits"]
    mode, arb = args["mode"], args["arb"]
    N = fl_accel.shape[0]
    A = svc_tab.shape[0]
    iota_n = jnp.arange(N, dtype=jnp.int32)
    sw = mode == SHAPING_SW
    shaped = (mode == SHAPING_HW) | sw
    arb_rr = arb == ARB_RR
    # active (unpadded) lanes; arbiter keys cycle modulo this count so a
    # padded batch element is bitwise-identical to its unpadded serial run
    n_act = jnp.maximum(jnp.sum(fl_mask.astype(jnp.int32)), 1)
    # active accelerators (padded accel rows fill the trailing positions);
    # the service stage and its host-delay LCG skip padded rows entirely
    ac_n = jnp.maximum(jnp.sum(ac_mask.astype(jnp.int32)), 1)

    now = t * cfg.tick_cycles
    now_end = now + cfg.tick_cycles
    is_stall = sw & args["stall"][t - args["t0"]]

    # -- 1. token-bucket timers ------------------------------------
    # host descheduled (software shaping): refills deferred, catch up on
    # wakeup; hardware shaping and unshaped systems tick every cycle
    pend = carry["sw_pend"] + cfg.tick_cycles
    elapsed = jnp.where(sw, jnp.where(is_stall, 0, pend), cfg.tick_cycles)
    carry["sw_pend"] = jnp.where(sw & is_stall, pend, 0)
    carry["tb"] = tb.advance(carry["tb"], elapsed)

    # -- 2. arrivals -> per-flow queues (single gather) ----------------
    # one [N, k_arr] gather of the next candidate arrivals per flow; the
    # due set is a per-row prefix (traces are time-sorted, INF-padded), so
    # counts replace the old k_arr-iteration drain loop exactly: the first
    # `room` due messages are taken, the remaining due ones dropped.
    M = arr_t.shape[1]
    jj_a = jnp.arange(cfg.k_arr, dtype=jnp.int32)
    pos = carry["arr_ptr"][:, None] + jj_a[None, :]
    gidx = jnp.minimum(pos, M - 1)
    nxt_t = arr_t[iota_n[:, None], gidx]
    nxt_s = arr_sz[iota_n[:, None], gidx]
    due = (nxt_t < now_end) & (pos < M)
    n_due = due.astype(jnp.int32).sum(1)
    n_take = jnp.minimum(n_due, jnp.maximum(cfg.qlen - carry["q_cnt"], 0))
    take = due & (jj_a[None, :] < n_take[:, None])
    slot = (carry["q_head"][:, None] + carry["q_cnt"][:, None]
            + jj_a[None, :]) % cfg.qlen
    row = jnp.where(take, iota_n[:, None], N)        # OOB rows are dropped
    carry["q_sz"] = carry["q_sz"].at[row, slot].set(nxt_s, mode="drop")
    carry["q_at"] = carry["q_at"].at[row, slot].set(nxt_t, mode="drop")
    carry["q_cnt"] = carry["q_cnt"] + n_take
    carry["arr_ptr"] = carry["arr_ptr"] + n_due
    carry["c_drops"] = carry["c_drops"] + (n_due - n_take)

    # -- 3. per-tick link budgets ------------------------------------
    budget = bpc * cfg.tick_cycles + carry["lres"]  # [2] bytes
    # extra resource axes (R_res = R-1; 0 in the scalar default).  R_res is
    # a *static* shape, so every resource op below sits behind a python
    # `if R_res:` guard — the R=1 compiled graph is structurally identical
    # to the pre-vector engine, which is what guarantees the bitwise
    # degenerate contract.  The empty [0] arrays still thread through the
    # cond/loop state tuples so branch signatures stay consistent.
    R_res = args["res_cap"].shape[0]
    res_bud = args["res_cap"] * cfg.tick_cycles + carry["res_res"]
    res_w_in, res_w_eg = args["res_w_in"], args["res_w_eg"]
    if R_res:
        # axes a flow charges in EITHER direction: its grants stall while
        # any of them is in debt.  Only the grant stage is gated — egress
        # charges its bytes as additional debt when it pops (gating pops
        # too would let the earlier grant stage starve egress forever at
        # saturation); sustainable ingress goodput on a saturated axis is
        # then cap / (w_in + w_eg * egress_ratio), which is exactly the
        # demand-coefficient algebra CapacityEntry margins use.
        res_w_any = (res_w_in > 0.0) | (res_w_eg > 0.0)

    # -- 4. shaper + arbiter grants ----------------------------------
    def grant_inputs(c, budget, res_bud):
        """Head-of-line state + eligibility + arbiter key per flow."""
        head_sz = c["q_sz"][iota_n, c["q_head"]]
        head_at = c["q_at"][iota_n, c["q_head"]]
        have = c["q_cnt"] > 0
        cost = tb.cost_of(c["tb"], head_sz)
        tok_ok = jnp.logical_or(~shaped, c["tb"].tokens >= cost)
        a_of = fl_accel
        aq_room = jnp.logical_and(
            c["aq_cnt"][a_of] < cfg.aq_len,
            c["aq_bytes"][a_of] + head_sz <= cfg.aq_byte_cap)
        cred_ok = c["credits_used"] < credits
        # A message may start whenever the link has *any* remaining
        # budget; it then drives the budget negative, which models its
        # serialization time (the link stays busy / in debt until the
        # per-tick replenishment pays it off).
        bud_f = jnp.where(fl_in_dir == 2, jnp.float32(3e38),
                          budget[jnp.minimum(fl_in_dir, 1)])
        bud_ok = bud_f > 0.0
        elig = (have & tok_ok & aq_room & cred_ok & bud_ok & fl_mask
                & jnp.logical_not(is_stall))
        if R_res:
            # a flow stalls while ANY axis it demands is in debt (same
            # start-when-positive semantics as the link budget above)
            res_ok = jnp.all((~res_w_any) | (res_bud[:, None] > 0.0),
                             axis=0)
            elig = elig & res_ok

        # arbiter key (lower = served first), selected by the traced mode
        # word.  Pure RR cycles by lane index modulo the *static* lane
        # count N: for any active subset this induces exactly the cyclic
        # lane order after rr_ptr, so it is grant-for-grant identical to
        # the old modulo-n_act key when active lanes form a prefix AND
        # stays correct when departures punch holes mid-table (mod n_act
        # would alias two active lanes onto one key there).  The WRR/WFQ/
        # priority tie-break term keeps the modulo-n_act *values* so those
        # float keys stay bitwise-identical between padded and unpadded
        # runs.
        rr_cyc = ((iota_n - c["rr_ptr"] - 1) % N).astype(jnp.float32)
        rr_key = ((iota_n - c["rr_ptr"] - 1) % n_act).astype(jnp.float32)
        key = jnp.where(
            arb_rr, rr_cyc,
            jnp.where(arb == ARB_PRIORITY, -fl_prio * 1e6 + rr_key,
                      c["vft"] + 1e-6 * rr_key))        # WRR / WFQ
        key = jnp.where(elig, key, jnp.float32(3e38))
        return head_sz, head_at, cost, elig, key

    def grant_body(_, st):
        c, budget, res_bud = st
        head_sz, head_at, cost, elig, key = grant_inputs(c, budget, res_bud)
        g = jnp.argmin(key).astype(jnp.int32)
        ok = elig[g]

        sz = head_sz[g]
        at = head_at[g]
        onehot = (iota_n == g) & ok
        # consume tokens (transparent when unshaped)
        c["tb"] = c["tb"]._replace(
            tokens=c["tb"].tokens - jnp.where(onehot & shaped, cost, 0))
        # pop flow queue
        c["q_head"] = (c["q_head"] + onehot) % cfg.qlen
        c["q_cnt"] = c["q_cnt"] - onehot
        # link budget + credits (per-message fabric overhead included)
        dir_idx = jnp.minimum(fl_in_dir[g], 1)
        spend = jnp.where((fl_in_dir[g] != 2) & ok,
                          sz.astype(jnp.float32) + ovh, 0.0)
        budget = budget.at[dir_idx].add(-spend)
        if R_res:
            # charge the granted message's ingress demand on every axis
            # (payload bytes only — the TLP overhead is a link artifact)
            res_bud = res_bud - jnp.where(
                ok, res_w_in[:, g] * sz.astype(jnp.float32), 0.0)
        c["credits_used"] = c["credits_used"] + ok.astype(jnp.int32)
        # accel queue push
        a = fl_accel[g]
        slot = (c["aq_head"][a] + c["aq_cnt"][a]) % cfg.aq_len
        c["aq_sz"] = c["aq_sz"].at[a, slot].set(
            jnp.where(ok, sz, c["aq_sz"][a, slot]))
        c["aq_fl"] = c["aq_fl"].at[a, slot].set(
            jnp.where(ok, g, c["aq_fl"][a, slot]))
        c["aq_at"] = c["aq_at"].at[a, slot].set(
            jnp.where(ok, at, c["aq_at"][a, slot]))
        c["aq_cnt"] = c["aq_cnt"].at[a].add(ok.astype(jnp.int32))
        c["aq_bytes"] = c["aq_bytes"].at[a].add(jnp.where(ok, sz, 0))
        # arbiter state.  WRR is message-granular (one packet per flow
        # per round — how the paper's Host_noTS FPGA arbiter behaves,
        # letting large messages steal bytes); WFQ is byte-granular.
        c["rr_ptr"] = jnp.where(ok, g, c["rr_ptr"])
        vft_inc = jnp.where(arb == ARB_WRR, jnp.float32(1.0),
                            sz.astype(jnp.float32)) / fl_w
        c["vft"] = c["vft"] + jnp.where(onehot, vft_inc, 0.0)
        # counters
        c["c_adm_msgs"] = c["c_adm_msgs"] + onehot.astype(jnp.int32)
        lo = c["c_adm_b_lo"] + jnp.where(onehot, sz, 0)
        c["c_adm_b_hi"] = c["c_adm_b_hi"] + (lo >> 20)
        c["c_adm_b_lo"] = lo & 0xFFFFF
        return c, budget, res_bud

    def seq_grants(c, budget, res_bud, *_aux):
        c, budget, res_bud = _fori(cfg.k_grant, grant_body,
                                   (c, budget, res_bud))
        return c, budget, res_bud

    use_fast = cfg.grant_fast and cfg.k_grant > 1 and N > 1
    if use_fast:
        # One-shot grant selection for the common uncontended RR tick.
        # Sorting eligible flows by the RR key visits them in exactly the
        # cyclic order the sequential argmin loop would (each grant moves
        # rr_ptr to the granted flow, so the next argmin is the next
        # eligible flow after it); eligibility is monotone within a tick
        # (budgets/credits/queues only move toward ineligibility), so the
        # first-K selection equals the sequential one whenever
        #   (a) every candidate passes its *cumulative* budget / credit /
        #       accel-queue check (prefix sums below), and
        #   (b) no flow could be granted twice (either >= k_grant flows
        #       are eligible, or every eligible flow has a single queued
        #       message).
        # Any contended (or non-RR) tick falls back to the sequential loop.
        K = min(cfg.k_grant, N)
        head_sz, head_at, cost, elig, key = grant_inputs(carry, budget,
                                                         res_bud)
        order = jnp.argsort(key)[:K]             # candidate flows, RR order
        valid = elig[order]                       # eligible prefix
        vi = valid.astype(jnp.int32)
        csz = head_sz[order]
        cat = head_at[order]
        ccost = cost[order]
        cdir = fl_in_dir[order]
        d01 = jnp.minimum(cdir, 1)
        cacc = fl_accel[order]
        spend = jnp.where((cdir != 2) & valid,
                          csz.astype(jnp.float32) + ovh, 0.0)
        lt_i = jnp.tril(jnp.ones((K, K), jnp.int32), -1)   # [j, i]: i < j
        lt_f = lt_i.astype(jnp.float32)
        same_dir = (d01[None, :] == d01[:, None])
        cum_spend = (lt_f * same_dir.astype(jnp.float32)) @ spend
        bud_ok = (cdir == 2) | (budget[d01] - cum_spend > 0.0)
        same_acc = (cacc[None, :] == cacc[:, None]).astype(jnp.int32)
        cnt_before = (lt_i * same_acc) @ vi
        byt_before = (lt_i * same_acc) @ jnp.where(valid, csz, 0)
        aq_ok = ((carry["aq_cnt"][cacc] + cnt_before < cfg.aq_len)
                 & (carry["aq_bytes"][cacc] + byt_before + csz
                    <= cfg.aq_byte_cap))
        idx_before = lt_i @ vi
        cred_ok = carry["credits_used"] + idx_before < credits
        ok_all = jnp.all(~valid | (bud_ok & aq_ok & cred_ok))
        if R_res:
            # cumulative per-axis check: candidate j must see a positive
            # bucket after the spends of every valid candidate before it
            # (the sequential loop's mid-tick eligibility re-check)
            c_any = res_w_any[:, order]                         # [R, K]
            c_rspend = (res_w_in[:, order]
                        * jnp.where(valid, csz, 0).astype(jnp.float32))
            cum_res = c_rspend @ lt_f.T                         # [R, K]
            res_ok_c = jnp.all(
                (~c_any) | (res_bud[:, None] - cum_res > 0.0), axis=0)
            ok_all = ok_all & jnp.all(~valid | res_ok_c)
        n_elig = jnp.sum(elig.astype(jnp.int32))
        regrant_safe = ((n_elig >= cfg.k_grant)
                        | jnp.all(~elig | (carry["q_cnt"] <= 1)))
        fast_pred = ok_all & regrant_safe & arb_rr

        # Under vmap (run_window_batch) this cond lowers to a select that
        # evaluates BOTH branches per lane.  That waste is accepted on
        # purpose: batched and serial runs then share the exact per-lane
        # computation, which is what guarantees simulate_batch() counters
        # bitwise-match serial simulate() — stripping the fast path from
        # batch engines would instead rely on fast==sequential holding to
        # the last float ulp.  Callers who want a leaner batch engine can
        # set SimConfig.grant_fast=False on both sides.
        def vec_grants(c, budget, res_bud, order, valid, vi, csz, cat,
                       ccost, cdir, d01, cacc, spend, cnt_before):
            c["tb"] = c["tb"]._replace(
                tokens=c["tb"].tokens.at[order].add(
                    -jnp.where(valid & shaped, ccost, 0)))
            if R_res:
                # subtract in the exact sequential chain order: non-dyadic
                # demand coefficients make float sums order-sensitive, and
                # the carried residue must match the sequential loop's
                r_spend = (res_w_in[:, order]
                           * jnp.where(valid, csz, 0).astype(jnp.float32))
                for j in range(K):
                    res_bud = res_bud - r_spend[:, j]
            c["q_head"] = (c["q_head"]
                           + jnp.zeros((N,), jnp.int32).at[order].add(vi)) \
                % cfg.qlen
            c["q_cnt"] = c["q_cnt"] - jnp.zeros((N,), jnp.int32) \
                .at[order].add(vi)
            budget = budget - jnp.zeros((2,), jnp.float32).at[d01].add(spend)
            n_g = jnp.sum(vi)
            c["credits_used"] = c["credits_used"] + n_g
            slot = (c["aq_head"][cacc] + c["aq_cnt"][cacc] + cnt_before) \
                % cfg.aq_len
            row = jnp.where(valid, cacc, A)       # OOB rows are dropped
            c["aq_sz"] = c["aq_sz"].at[row, slot].set(csz, mode="drop")
            c["aq_fl"] = c["aq_fl"].at[row, slot].set(order, mode="drop")
            c["aq_at"] = c["aq_at"].at[row, slot].set(cat, mode="drop")
            c["aq_cnt"] = c["aq_cnt"].at[cacc].add(vi)
            c["aq_bytes"] = c["aq_bytes"].at[cacc].add(
                jnp.where(valid, csz, 0))
            c["rr_ptr"] = jnp.where(
                n_g > 0, order[jnp.maximum(n_g - 1, 0)], c["rr_ptr"])
            vft_inc = jnp.where(arb == ARB_WRR, jnp.float32(1.0),
                                csz.astype(jnp.float32)) / fl_w[order]
            c["vft"] = c["vft"].at[order].add(jnp.where(valid, vft_inc, 0.0))
            c["c_adm_msgs"] = c["c_adm_msgs"].at[order].add(vi)
            lo = c["c_adm_b_lo"].at[order].add(jnp.where(valid, csz, 0))
            c["c_adm_b_hi"] = c["c_adm_b_hi"] + (lo >> 20)
            c["c_adm_b_lo"] = lo & 0xFFFFF
            return c, budget, res_bud

        carry, budget, res_bud = jax.lax.cond(
            fast_pred, vec_grants, seq_grants,
            carry, budget, res_bud, order, valid, vi, csz, cat, ccost,
            cdir, d01, cacc, spend, cnt_before)
    else:
        carry, budget, res_bud = seq_grants(carry, budget, res_bud)

    # -- 5. accelerator service --------------------------------------
    # sequential reference: one accel per iteration, pass-major order
    # (iteration i serves accel i % A on pass i // A)
    def srv_body(i, c):
        a = i % A
        act = ac_mask[a]      # padded accel rows (ragged batching) are inert
        lanes_a = c["lanes"][a]
        lane = jnp.argmin(lanes_a).astype(jnp.int32)
        # a lane that frees during this tick may chain back-to-back
        # (no tick-quantization idle gap between messages)
        free = lanes_a[lane] < jnp.float32(now_end)
        ok = free & (c["aq_cnt"][a] > 0) & act
        h = c["aq_head"][a]
        sz = c["aq_sz"][a, h]
        fl = c["aq_fl"][a, h]
        at = c["aq_at"][a, h]
        svc = interp_grid(svc_tab, a, sz.astype(jnp.float32))
        esz = interp_grid(eg_tab, a, sz.astype(jnp.float32))
        esz = jnp.where(fl_eg_full[fl], sz.astype(jnp.float32), esz)
        end = jnp.maximum(lanes_a[lane], jnp.float32(now)) + svc
        c["lanes"] = c["lanes"].at[a, lane].set(
            jnp.where(ok, end, lanes_a[lane]))
        c["aq_head"] = c["aq_head"].at[a].add(ok.astype(jnp.int32)) \
            % cfg.aq_len
        c["aq_cnt"] = c["aq_cnt"].at[a].add(-ok.astype(jnp.int32))
        c["aq_bytes"] = c["aq_bytes"].at[a].add(jnp.where(ok, -sz, 0))
        # host-processing delay (software-mediated shaping only; the LCG
        # advances once per *active-accelerator* iteration whenever shaping
        # is software, busy or idle, exactly like the closed-form batch
        # draw below — padded rows draw nothing, so a ragged element's
        # jitter stream matches its unpadded serial run)
        r = c["rng"] * _LCG_A + _LCG_C
        c["rng"] = jnp.where(sw & act, r, c["rng"])
        u = (jnp.abs(r) % 65536).astype(jnp.float32) / 65536.0
        hostd = jnp.where(sw, args["sw_delay"] + (u ** 4) * args["sw_jit"],
                          jnp.float32(0.0))
        ready = (end + hostd).astype(jnp.int32)
        # egress queue push
        d = fl_eg_dir[fl]
        slot = (c["eq_head"][d] + c["eq_cnt"][d]) % cfg.eq_len
        full = c["eq_cnt"][d] >= cfg.eq_len
        okq = ok & jnp.logical_not(full)
        c["eq_sz"] = c["eq_sz"].at[d, slot].set(
            jnp.where(okq, jnp.maximum(esz.astype(jnp.int32), 1),
                      c["eq_sz"][d, slot]))
        c["eq_isz"] = c["eq_isz"].at[d, slot].set(
            jnp.where(okq, sz, c["eq_isz"][d, slot]))
        c["eq_fl"] = c["eq_fl"].at[d, slot].set(
            jnp.where(okq, fl, c["eq_fl"][d, slot]))
        c["eq_at"] = c["eq_at"].at[d, slot].set(
            jnp.where(okq, at, c["eq_at"][d, slot]))
        c["eq_rd"] = c["eq_rd"].at[d, slot].set(
            jnp.where(okq, ready, c["eq_rd"][d, slot]))
        c["eq_cnt"] = c["eq_cnt"].at[d].add(okq.astype(jnp.int32))
        return c

    def seq_srv(c):
        return _fori(A * cfg.k_srv, srv_body, c)

    # Vectorized service pays off only once the stage is wide enough:
    # measured on XLA-CPU, narrow service next to the vectorized egress
    # stage fuses pathologically (3x slower than the unrolled loop), while
    # wide stages gain 2-4x.  The knee (8 on XLA-CPU) is backend-dependent:
    # SimConfig.service_vec_min / $REPRO_SERVICE_VEC_MIN override it.  The
    # threshold is static, so serial and batched runs share the path.
    if cfg.stage_fast and A * cfg.k_srv >= cfg.service_vec_min:
        # Prefix-sum slot assignment (the treatment PR 1 gave RR grants):
        # sort each accelerator's lanes by busy-time; the k-th queued
        # message starts on the k-th least-busy lane.  This equals the
        # sequential argmin walk whenever no assigned lane frees again
        # within this tick (its end >= now_end): assigned lanes then sort
        # strictly after every still-free lane, so the sequential argmin
        # sequence is exactly the sorted order.  A chaining tick (tiny
        # service times) falls back to the sequential loop.
        Ks = cfg.k_srv
        ia = jnp.arange(A, dtype=jnp.int32)
        kk = jnp.arange(Ks, dtype=jnp.int32)
        kl = jnp.minimum(kk, cfg.lmax - 1)
        sl = jnp.sort(carry["lanes"], axis=1)[:, kl]       # [A, Ks]
        si = jnp.argsort(carry["lanes"], axis=1)[:, kl].astype(jnp.int32)
        free = (sl < jnp.float32(now_end)) & (kk < cfg.lmax)[None, :]
        have = kk[None, :] < carry["aq_cnt"][:, None]
        s_ok = free & have & ac_mask[:, None]               # prefix rows
        aslot = (carry["aq_head"][:, None] + kk[None, :]) % cfg.aq_len
        s_sz = carry["aq_sz"][ia[:, None], aslot]
        s_fl = carry["aq_fl"][ia[:, None], aslot]
        s_at = carry["aq_at"][ia[:, None], aslot]
        s_svc = _interp_mat(svc_tab, s_sz.astype(jnp.float32))
        s_esz = _interp_mat(eg_tab, s_sz.astype(jnp.float32))
        s_esz = jnp.where(fl_eg_full[s_fl], s_sz.astype(jnp.float32), s_esz)
        s_end = jnp.maximum(sl, jnp.float32(now)) + s_svc
        srv_fast = jnp.all(~s_ok | (s_end >= jnp.float32(now_end)))

        def vec_srv(c, s_ok, si, s_sz, s_fl, s_at, s_esz, s_end):
            n_start = s_ok.astype(jnp.int32).sum(1)
            lrow = jnp.where(s_ok, ia[:, None], A)   # OOB rows are dropped
            c["lanes"] = c["lanes"].at[lrow, si].set(s_end, mode="drop")
            c["aq_head"] = (c["aq_head"] + n_start) % cfg.aq_len
            c["aq_cnt"] = c["aq_cnt"] - n_start
            c["aq_bytes"] = c["aq_bytes"] - jnp.where(s_ok, s_sz, 0).sum(1)
            # host-processing delay: closed-form LCG draw for *active*
            # iteration i = k*ac_n + a (padded accel rows draw nothing),
            # bitwise-equal to the sequential per-step update of a run
            # with only the active accelerators
            powv, sumv = _lcg_tables(A * Ks)
            it = jnp.minimum(kk[None, :] * ac_n + ia[:, None],
                             A * Ks - 1)                     # [A, Ks]
            r = c["rng"] * jnp.asarray(powv)[it] + jnp.asarray(sumv)[it]
            adv = jnp.maximum(ac_n * Ks - 1, 0)
            c["rng"] = jnp.where(sw, c["rng"] * jnp.asarray(powv)[adv]
                                 + jnp.asarray(sumv)[adv], c["rng"])
            u = (jnp.abs(r) % 65536).astype(jnp.float32) / 65536.0
            hostd = jnp.where(sw, args["sw_delay"]
                              + (u ** 4) * args["sw_jit"], jnp.float32(0.0))
            ready = (s_end + hostd).astype(jnp.int32)
            # egress pushes in sequential iteration order (k-major flatten)
            flat = lambda x: x.T.reshape(-1)                 # noqa: E731
            okf = flat(s_ok)
            d = fl_eg_dir[flat(s_fl)]
            Mt = A * Ks
            lt = jnp.tril(jnp.ones((Mt, Mt), jnp.int32), -1)
            same_d = (d[None, :] == d[:, None]).astype(jnp.int32)
            rank = (lt * same_d) @ okf.astype(jnp.int32)
            okq = okf & (c["eq_cnt"][d] + rank < cfg.eq_len)
            eslot = (c["eq_head"][d] + c["eq_cnt"][d] + rank) % cfg.eq_len
            drow = jnp.where(okq, d, 3)           # OOB rows are dropped
            c["eq_sz"] = c["eq_sz"].at[drow, eslot].set(
                jnp.maximum(flat(s_esz).astype(jnp.int32), 1), mode="drop")
            c["eq_isz"] = c["eq_isz"].at[drow, eslot].set(
                flat(s_sz), mode="drop")
            c["eq_fl"] = c["eq_fl"].at[drow, eslot].set(
                flat(s_fl), mode="drop")
            c["eq_at"] = c["eq_at"].at[drow, eslot].set(
                flat(s_at), mode="drop")
            c["eq_rd"] = c["eq_rd"].at[drow, eslot].set(
                flat(ready), mode="drop")
            c["eq_cnt"] = c["eq_cnt"] + jnp.zeros((3,), jnp.int32) \
                .at[d].add(okq.astype(jnp.int32))
            return c

        carry = jax.lax.cond(srv_fast, vec_srv, lambda c, *_a: seq_srv(c),
                             carry, s_ok, si, s_sz, s_fl, s_at, s_esz, s_end)
    else:
        carry = seq_srv(carry)

    # -- 6. egress link + completions ----------------------------------
    dirs = jnp.arange(3, dtype=jnp.int32)

    def eg_body(_, st):
        c, budget, res_bud = st
        h = c["eq_head"]                       # [3]
        sz = c["eq_sz"][dirs, h]
        isz = c["eq_isz"][dirs, h]
        fl = c["eq_fl"][dirs, h]
        at = c["eq_at"][dirs, h]
        rd = c["eq_rd"][dirs, h]
        have = c["eq_cnt"] > 0
        ready = rd < now_end
        bud3 = jnp.concatenate([budget, jnp.asarray([3e38], jnp.float32)])
        bud_ok = bud3[dirs] > 0.0
        pop = have & ready & bud_ok            # [3]
        c["eq_head"] = (c["eq_head"] + pop) % cfg.eq_len
        c["eq_cnt"] = c["eq_cnt"] - pop
        spend = jnp.where(pop[:2], sz[:2].astype(jnp.float32) + ovh, 0.0)
        budget = budget - spend
        if R_res:
            # ungated debt charge — see res_w_any above; the three
            # directions' spends of one iteration subtract together
            res_bud = res_bud - (
                res_w_eg[:, fl] * jnp.where(pop, sz, 0)
                .astype(jnp.float32)[None, :]).sum(1)
        c["credits_used"] = c["credits_used"] - pop.sum().astype(jnp.int32)
        # completion = transfer start + own serialization delay
        ser = jnp.where(dirs < 2,
                        sz.astype(jnp.float32) / bpc[jnp.minimum(dirs, 1)],
                        0.0)
        comp_time = jnp.maximum(rd, now) + ser.astype(jnp.int32)
        lat = comp_time - at
        # record (scratch slot comp_cap for non-pops)
        base = c["comp_n"]
        offs = jnp.cumsum(pop.astype(jnp.int32)) - pop.astype(jnp.int32)
        idx = jnp.where(pop, (base + offs) % cfg.comp_cap, cfg.comp_cap)
        c["comp_fl"] = c["comp_fl"].at[idx].set(fl)
        c["comp_lat"] = c["comp_lat"].at[idx].set(lat)
        c["comp_t"] = c["comp_t"].at[idx].set(comp_time)
        c["comp_sz"] = c["comp_sz"].at[idx].set(isz)
        c["comp_n"] = base + pop.sum().astype(jnp.int32)
        # per-flow counters (SLO accounting is on ingress payload bytes,
        # as the paper's traffic generator measures); scatter-adds
        # accumulate duplicate flow ids across the three directions.
        c["c_done_msgs"] = c["c_done_msgs"].at[fl].add(pop.astype(jnp.int32))
        lo = c["c_done_b_lo"].at[fl].add(jnp.where(pop, isz, 0))
        c["c_done_b_hi"] = c["c_done_b_hi"] + (lo >> 20)
        c["c_done_b_lo"] = lo & 0xFFFFF
        c["c_lat_sum"] = c["c_lat_sum"].at[fl].add(
            jnp.where(pop, lat.astype(jnp.float32), 0.0))
        return c, budget, res_bud

    if cfg.stage_fast:
        # Vectorized egress: gather the next k_eg ring entries of every
        # direction at once.  Pops per direction are a prefix (a head that
        # is not ready / not funded stays at the head for the rest of the
        # tick), so one cumulative-AND replaces the k_eg-iteration loop.
        # The budget chain is evaluated in the exact sequential subtraction
        # order to keep the carried link debt bitwise-identical.
        Ke = cfg.k_eg
        jj = jnp.arange(Ke, dtype=jnp.int32)
        eh = (carry["eq_head"][:, None] + jj[None, :]) % cfg.eq_len
        e_sz = carry["eq_sz"][dirs[:, None], eh]
        e_isz = carry["eq_isz"][dirs[:, None], eh]
        e_fl = carry["eq_fl"][dirs[:, None], eh]
        e_at = carry["eq_at"][dirs[:, None], eh]
        e_rd = carry["eq_rd"][dirs[:, None], eh]
        e_have = jj[None, :] < carry["eq_cnt"][:, None]
        e_ready = e_rd < now_end
        spend_mat = jnp.where((dirs < 2)[:, None],
                              e_sz.astype(jnp.float32) + ovh, 0.0)
        pops, prev = [], jnp.ones((3,), bool)
        b_run = budget
        r_run = res_bud
        for j in range(Ke):
            bud_ok = jnp.concatenate(
                [b_run, jnp.asarray([3e38], jnp.float32)]) > 0.0
            pop_j = prev & e_have[:, j] & e_ready[:, j] & bud_ok
            b_run = b_run - jnp.where(pop_j[:2], spend_mat[:2, j], 0.0)
            if R_res:
                r_run = r_run - (
                    res_w_eg[:, e_fl[:, j]]
                    * jnp.where(pop_j, e_sz[:, j], 0)
                    .astype(jnp.float32)[None, :]).sum(1)
            pops.append(pop_j)
            prev = pop_j
        pop = jnp.stack(pops, axis=1)                       # [3, Ke]
        budget = b_run
        res_bud = r_run
        npop = pop.astype(jnp.int32).sum(1)
        carry["eq_head"] = (carry["eq_head"] + npop) % cfg.eq_len
        carry["eq_cnt"] = carry["eq_cnt"] - npop
        carry["credits_used"] = carry["credits_used"] - npop.sum()
        ser = jnp.where((dirs < 2)[:, None],
                        e_sz.astype(jnp.float32)
                        / bpc[jnp.minimum(dirs, 1)][:, None], 0.0)
        comp_time = jnp.maximum(e_rd, now) + ser.astype(jnp.int32)
        lat = comp_time - e_at
        # completion ring in sequential (iteration, direction) order
        flat = lambda x: x.T.reshape(-1)                    # noqa: E731
        popf = flat(pop)
        offs = jnp.cumsum(popf.astype(jnp.int32)) - popf.astype(jnp.int32)
        idx = jnp.where(popf, (carry["comp_n"] + offs) % cfg.comp_cap,
                        cfg.comp_cap)
        carry["comp_fl"] = carry["comp_fl"].at[idx].set(flat(e_fl))
        carry["comp_lat"] = carry["comp_lat"].at[idx].set(flat(lat))
        carry["comp_t"] = carry["comp_t"].at[idx].set(flat(comp_time))
        carry["comp_sz"] = carry["comp_sz"].at[idx].set(flat(e_isz))
        carry["comp_n"] = carry["comp_n"] + npop.sum()
        carry["c_done_msgs"] = carry["c_done_msgs"].at[flat(e_fl)].add(
            popf.astype(jnp.int32))
        lo = carry["c_done_b_lo"].at[flat(e_fl)].add(
            jnp.where(popf, flat(e_isz), 0))
        carry["c_done_b_hi"] = carry["c_done_b_hi"] + (lo >> 20)
        carry["c_done_b_lo"] = lo & 0xFFFFF
        carry["c_lat_sum"] = carry["c_lat_sum"].at[flat(e_fl)].add(
            jnp.where(popf, flat(lat).astype(jnp.float32), 0.0))
    else:
        carry, budget, res_bud = _fori(cfg.k_eg, eg_body,
                                       (carry, budget, res_bud))

    # Positive leftover budget is lost (a link cannot save idle time);
    # negative budget (serialization debt of in-flight messages) carries.
    carry["lres"] = jnp.minimum(budget, 0.0)
    if R_res:
        # each axis is a token bucket: unused budget carries up to the
        # axis' burst depth (burst 0 reproduces the link's lose-idle-time
        # semantics); debt always carries
        carry["res_res"] = jnp.minimum(res_bud, args["res_burst"])
    return carry


def _run_core(cfg: SimConfig, carry: dict, args: dict) -> dict:
    xs = args["t0"] + jnp.arange(cfg.n_ticks, dtype=jnp.int32)
    carry, _ = jax.lax.scan(lambda c, t: (_tick(cfg, args, c, t), None),
                            carry, xs)
    return carry


# ---------------------------------------------------------------------------
# Module-level compile cache
# ---------------------------------------------------------------------------

_RUN_CACHE: dict[Any, Any] = {}
_CACHE_MAX = 64     # profiler sweeps can touch many context shapes; evict
                    # oldest engines (FIFO) so a long-lived control plane
                    # does not accumulate compiled executables unboundedly


def _get_run(key, builder):
    fn = _RUN_CACHE.get(key)
    if fn is None:
        if len(_RUN_CACHE) >= _CACHE_MAX:
            _RUN_CACHE.pop(next(iter(_RUN_CACHE)))
        fn = builder()
        _RUN_CACHE[key] = fn
    return fn


def cache_info() -> dict[str, int]:
    """Compile-cache stats: distinct engine signatures + live XLA traces.

    ``traces`` counts actual jit-cache entries across all cached engines —
    a steady value across repeated ``simulate()`` / ``run_managed`` windows
    proves zero recompiles.  ``_cache_size`` is a private jit attribute
    (present in the pinned jax; see requirements-dev.txt) — if a future
    jax drops it we degrade to one trace per entry rather than raising."""
    return {"entries": len(_RUN_CACHE),
            "traces": sum(getattr(f, "_cache_size", lambda: 1)()
                          for f in _RUN_CACHE.values())}


def cache_clear() -> None:
    _RUN_CACHE.clear()


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def run_window(flows: FlowSet, accels: AccelTable, link: LinkSpec,
               cfg: SimConfig, tb_state: tb.TBState, arr_t, arr_sz,
               stall_mask=None, *, t0_ticks: int = 0,
               carry: dict | None = None) -> dict:
    """Run one compiled window; returns the raw device carry.

    The input carry is **donated**: device buffers are reused in place, so
    do not touch a carry after passing it back in (hand the returned one
    forward instead, as ``ArcusRuntime.run_managed`` does)."""
    args = _pack_args(flows, accels, link, cfg, arr_t, arr_sz, stall_mask,
                      t0_ticks)
    if carry is None:
        carry = init_carry(flows, accels, cfg, tb_state,
                           n_res=len(getattr(link, "resources", ())))
    else:
        carry = reconfigure_carry(carry, tb_state)
    key = ("single", _static_cfg(cfg), _args_sig(args))
    run = _get_run(key, lambda: jax.jit(
        functools.partial(_run_core, _static_cfg(cfg)),
        donate_argnums=(0,)))
    return run(carry, args)


def _as_list(x, B):
    return list(x) if isinstance(x, (list, tuple)) else [x] * B


def run_window_batch(flows: FlowSet | Sequence[FlowSet],
                     accels: AccelTable | Sequence[AccelTable],
                     link: LinkSpec | Sequence[LinkSpec],
                     cfg: SimConfig | Sequence[SimConfig],
                     tb_states: Sequence[tb.TBState] | None,
                     arr_t, arr_sz, stall_mask=None, *,
                     t0_ticks: int = 0, carry: dict | None = None,
                     fl_masks: Sequence[np.ndarray] | None = None) -> dict:
    """Run B independent windows in one compiled ``jax.vmap`` call.

    Batched per element: arrival trace, TBState registers, and (when
    sequences are passed) flow sets, SimConfigs, accelerator tables, link
    specs and ``[B, T]`` stall masks.  Flow sets may have *different flow
    counts*: they are padded to the largest count and masked (``fl_mask``),
    with counters of active lanes bitwise-equal to unpadded serial runs.
    Accelerator tables may likewise have *different accelerator counts*:
    they are padded to the largest count (``pad_accel_table``) and masked
    (``ac_mask``), with the same bitwise guarantee.  SimConfigs may differ
    only in the traced mode fields (``TRACED_CFG_FIELDS``: shaping,
    arbiter, software-delay model) — the structural fields form the single
    compile signature.

    Passing back the returned ``carry`` resumes all B dataplanes with fresh
    per-element TBState registers applied (the fleet-scale analogue of
    ``run_window``'s resumption: ``ArcusRuntime.run_managed_batch`` drives
    its whole window loop through this).  On resumption ``tb_states=None``
    skips the register rewrite entirely — the carry's registers are
    already current (the fast path for a window after which no server
    reconfigured; bitwise-identical to rewriting the unchanged values).
    The input carry is **donated** — hand the returned one forward, never
    reuse the one passed in.  Returns the raw batched carry.

    ``fl_masks`` (one ``[n_flows_max]`` bool array per element) overrides
    the default validity masks: the tenant-lifecycle control plane uses it
    to punch *mid-table holes* (a departed tenant's lane goes inert while
    every other lane keeps its position, so a resumed carry never needs a
    re-pack or a recompile).  Without it, masks are the usual active
    prefix derived from each element's flow count."""
    if not hasattr(arr_t, "ndim"):       # nested python lists
        arr_t = np.asarray(arr_t)
        arr_sz = np.asarray(arr_sz)
    if arr_t.ndim != 3:
        raise ValueError(
            f"arr_t must be [B, N, M] (got ndim={arr_t.ndim}) — "
            "see stack_arrivals()")
    B = arr_t.shape[0]
    flows_l = _as_list(flows, B)
    accels_l = _as_list(accels, B)
    links_l = _as_list(link, B)
    cfgs_l = _as_list(cfg, B)
    if tb_states is None and carry is None:
        raise ValueError("tb_states=None is only valid when resuming a "
                         "carry (initial registers are required)")
    if not (len(accels_l) == B and len(links_l) == B
            and (tb_states is None or len(tb_states) == B)
            and len(flows_l) == B and len(cfgs_l) == B):
        raise ValueError(
            f"batch size mismatch: arr_t has B={B} but "
            f"flows={len(flows_l)}, accels={len(accels_l)}, "
            f"links={len(links_l)}, "
            f"tb_states={len(tb_states or [])}, cfgs={len(cfgs_l)}")
    cfg0 = cfgs_l[0]
    if any(_static_cfg(c) != _static_cfg(cfg0) for c in cfgs_l[1:]):
        raise ValueError(
            "batched SimConfigs may differ only in traced fields "
            f"{TRACED_CFG_FIELDS}")
    for c in cfgs_l[1:]:
        _check_modes(c)    # element 0 is checked by _pack_args below
    a_max = max(a.n for a in accels_l)
    padded_l = [pad_accel_table(a, a_max) for a in accels_l]

    n_res = len(getattr(links_l[0], "resources", ()))
    if any(len(getattr(l, "resources", ())) != n_res
           for l in links_l[1:]):
        raise ValueError(
            "batched LinkSpecs must all carry the same number of resource "
            "axes (resource tables are a shared traced shape; a huge-"
            "capacity axis is inert if an element needs fewer)")

    n_max = max(f.n for f in flows_l)
    if arr_t.shape[1] != n_max:
        raise ValueError(
            f"arr_t flow axis {arr_t.shape[1]} != n_flows_max {n_max} — "
            "see stack_arrivals()")

    if fl_masks is not None and len(fl_masks) != B:
        raise ValueError(
            f"fl_masks must have one mask per element (got {len(fl_masks)} "
            f"for B={B})")
    flows_batched = (fl_masks is not None
                     or (isinstance(flows, (list, tuple))
                         and (len(set(f.n for f in flows_l)) > 1
                              or any(f is not flows_l[0] for f in flows_l))))
    accel_batched = isinstance(accels, (list, tuple))
    link_batched = isinstance(link, (list, tuple))
    cfg_batched = (isinstance(cfg, (list, tuple))
                   and any(c != cfg0 for c in cfgs_l[1:]))
    stall_np = None if stall_mask is None else np.asarray(stall_mask, bool)
    stall_batched = stall_np is not None and stall_np.ndim == 2

    # pack with tiny placeholders for the per-element entries (the real
    # batched trace / stall arrays replace them below) so a multi-megabyte
    # single-element trace is never uploaded just to be discarded
    ph = np.zeros((n_max, 1), np.int32)
    flows0 = flows_l[0] if flows_l[0].n == n_max else flows_l[
        int(np.argmax([f.n for f in flows_l]))]
    args = _pack_args(flows0, padded_l[0], links_l[0], cfg0,
                      ph, ph, None, t0_ticks)
    axes = {k: None for k in args}
    args["arr_t"] = jnp.asarray(arr_t, jnp.int32)
    args["arr_sz"] = jnp.asarray(arr_sz, jnp.int32)
    axes["arr_t"] = axes["arr_sz"] = 0
    if flows_batched:
        per_el = [_flow_args(f, n_max) for f in flows_l]
        if fl_masks is not None:
            for p, m in zip(per_el, fl_masks):
                m = np.asarray(m, bool)
                if m.shape != (n_max,):
                    raise ValueError(
                        f"fl_masks entries must be [{n_max}] bool "
                        f"(got shape {m.shape})")
                p["fl_mask"] = m
        for k in per_el[0]:
            args[k] = jnp.stack([jnp.asarray(p[k]) for p in per_el])
            axes[k] = 0
    if cfg_batched:
        args["mode"] = jnp.asarray([c.shaping for c in cfgs_l], jnp.int32)
        args["arb"] = jnp.asarray([c.arbiter for c in cfgs_l], jnp.int32)
        args["sw_delay"] = jnp.asarray(
            [c.sw_host_delay_cycles for c in cfgs_l], jnp.float32)
        args["sw_jit"] = jnp.asarray(
            [c.sw_jitter_cycles for c in cfgs_l], jnp.float32)
        axes["mode"] = axes["arb"] = axes["sw_delay"] = axes["sw_jit"] = 0
    if accel_batched:
        args["svc_tab"] = jnp.stack(
            [jnp.asarray(a.service_cycles, jnp.float32) for a in padded_l])
        args["eg_tab"] = jnp.stack(
            [jnp.asarray(a.egress_bytes, jnp.float32) for a in padded_l])
        args["ac_mask"] = jnp.stack(
            [jnp.asarray(_accel_mask(a), bool) for a in padded_l])
        axes["svc_tab"] = axes["eg_tab"] = axes["ac_mask"] = 0
    if link_batched:
        args["bpc"] = jnp.asarray([l.bytes_per_cycle() for l in links_l],
                                  jnp.float32)
        args["ovh"] = jnp.asarray(
            [l.msg_overhead_bytes for l in links_l], jnp.float32)
        args["credits"] = jnp.asarray([l.credits for l in links_l], jnp.int32)
        axes["bpc"] = axes["ovh"] = axes["credits"] = 0
        if n_res:
            args["res_cap"] = jnp.asarray(
                np.stack([l.resource_caps_per_cycle() for l in links_l]),
                jnp.float32)
            args["res_burst"] = jnp.asarray(
                np.stack([l.resource_burst_bytes() for l in links_l]),
                jnp.float32)
            axes["res_cap"] = axes["res_burst"] = 0
    if n_res and (flows_batched or accel_batched or link_batched):
        # demand coefficients depend on flows x accels x link axes; batch
        # the [R-1, n_max] tables whenever any of the three is per-element
        tabs = [_resource_tables(flows_l[b], padded_l[b], links_l[b], n_max)
                for b in range(B)]
        args["res_w_in"] = jnp.asarray(np.stack([t[0] for t in tabs]),
                                       jnp.float32)
        args["res_w_eg"] = jnp.asarray(np.stack([t[1] for t in tabs]),
                                       jnp.float32)
        axes["res_w_in"] = axes["res_w_eg"] = 0
    if stall_np is not None:
        args["stall"] = jnp.asarray(
            _window_stall(stall_np, cfg0, t0_ticks), bool)
        axes["stall"] = 0 if stall_batched else None

    if carry is None:
        tb_padded = [pad_tb_state(tb_states[b], n_max) for b in range(B)]
        carries = [init_carry(flows_l[b], padded_l[b], cfg0, tb_padded[b],
                              n_flows=n_max, n_res=n_res)
                   for b in range(B)]
        carry = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *carries)
    elif tb_states is not None:
        # resumed fleet window: write only the per-element parameter
        # "registers" (stacked [B, n_max] leaves), like run_window does;
        # tb_states=None resumes without touching the registers
        tb_padded = [pad_tb_state(tb_states[b], n_max) for b in range(B)]
        stacked_tb = jax.tree_util.tree_map(
            lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]), *tb_padded)
        carry = reconfigure_carry(carry, stacked_tb)

    key = ("batch", _static_cfg(cfg0), B, _args_sig(args),
           tuple(sorted(axes.items())))
    run = _get_run(key, lambda: jax.jit(
        jax.vmap(functools.partial(_run_core, _static_cfg(cfg0)),
                 in_axes=(0, axes)),
        donate_argnums=(0,)))
    return run(carry, args)
