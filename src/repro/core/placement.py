"""Fleet admission placement — CapacityPlanning lifted fleet-wide.

Arcus's CapacityPlanning (Sec. 3.3, 4.3) admits a flow only if the
profiled Capacity(t, X, N) context of the target accelerator stays
SLO-Friendly.  Run per client server that is a *local* decision: the
caller pre-picks a server, and a flow rejected on a loaded server dies
even when a sibling server has profiled headroom — the coordination gap
"SLO beyond the Hardware Isolation Limits" describes when per-device
isolation is managed in isolation.

This module closes the gap with a pluggable *placement* layer: a
``PlacementPolicy`` ranks the fleet-wide candidate set (every compatible
(server, accelerator) pair, each carrying its profiled would-be context),
and ``runtime.place_fleet`` drives one admission round per tenant,
batching the whole round's cross-server candidate profiling into ONE
``profiler.profile_contexts_multi`` engine call.

Policies (all deterministic):

* ``FirstFit``    — first feasible candidate in (server, accelerator)
                    enumeration order.  Pinned to a fixed server it
                    reproduces per-server ``register_fleet`` decisions
                    exactly (the parity contract).
* ``BestFit``     — feasible candidate with the smallest post-admission
                    residual capacity (tightest fit: keeps the largest
                    holes open for future large tenants).
* ``SLOAware``    — feasible candidate maximizing the post-admission
                    ``slo_tag`` margin (distance of the would-be context
                    from its nearest capacity/ceiling constraint) — it
                    shops every server's accelerator complement and lands
                    the tenant where the fleet keeps the most SLO slack.

For the scoring policies (``BestFit``, ``SLOAware``) ties break on a
*canonical server key* (accelerator complement + registered flow ids),
not the presentation index, so a permuted ``runtimes`` sequence places
every tenant on the same physical server (only true clones — identical
complement AND identical registered set — fall back to presentation
order).  ``FirstFit`` is deterministic for a *given* server order but,
by definition, follows that order — permuting the fleet permutes its
picks.
"""
from __future__ import annotations

import dataclasses

from repro.core import profiler
from repro.core.flow import FlowSpec
from repro.core.profiler import CapacityEntry


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One (server, accelerator) landing option for a tenant, with the
    profiled would-be context it creates."""

    server: int                    # index into the runtimes sequence
    accel_id: int                  # accelerator index on that server
    spec: FlowSpec                 # tenant spec rebound to accel_id
    entry: CapacityEntry           # profiled post-admission context
    slo_gbps: tuple[float, ...]    # canonical-order SLO vector (w/ tenant)
    feasible: bool                 # entry.slo_tag(slo_gbps)
    margin: float                  # entry.slo_margin(slo_gbps) — min axis
    residual: float                # entry.residual_gbps(slo_gbps)
    server_key: tuple              # canonical tie-break identity
    # per-resource-axis margins (entry.slo_margins; axis 0 = link).  Empty
    # for hand-built candidates — axis-scoring policies fall back to the
    # scalar margin then.
    margin_res: tuple = ()


@dataclasses.dataclass
class Placement:
    """Outcome of one admission round, aligned with ``place_fleet``'s
    input specs."""

    spec: FlowSpec
    server: int | None             # None = rejected fleet-wide
    accel_id: int | None
    accepted: bool
    n_candidates: int
    n_feasible: int


def server_key(runtime) -> tuple:
    """Canonical identity of a server for policy tie-breaks: accelerator
    complement (ordered — it defines accel ids) plus the registered flow
    ids.  Invariant under permutation of the ``runtimes`` sequence."""
    return (tuple(a.name for a in runtime.accel_specs),
            tuple(sorted(runtime.table)))


class PlacementPolicy:
    """Chooses where (if anywhere) a tenant lands, given the profiled
    fleet-wide candidate set of one admission round.

    ``select`` sees every candidate — feasible or not — in (server,
    accelerator) enumeration order and returns the winner or ``None``
    (reject).  Implementations must be deterministic functions of the
    candidate set; use ``_tie_key`` so equal scores resolve by canonical
    server identity rather than presentation order."""

    name = "base"

    def select(self, candidates: list[Candidate]) -> Candidate | None:
        raise NotImplementedError

    @staticmethod
    def _tie_key(c: Candidate) -> tuple:
        return (c.server_key, c.accel_id, c.server)


class FirstFit(PlacementPolicy):
    """First feasible candidate in enumeration order.  With ``pinned``
    servers this is exactly today's per-server admission."""

    name = "first_fit"

    def select(self, candidates: list[Candidate]) -> Candidate | None:
        for c in candidates:
            if c.feasible:
                return c
        return None


class BestFit(PlacementPolicy):
    """Smallest post-admission residual capacity among feasible
    candidates (classic best-fit packing on the profiled capacities)."""

    name = "best_fit"

    def select(self, candidates: list[Candidate]) -> Candidate | None:
        feasible = [c for c in candidates if c.feasible]
        if not feasible:
            return None
        return min(feasible, key=lambda c: (c.residual, self._tie_key(c)))


class SLOAware(PlacementPolicy):
    """Largest post-admission ``slo_tag`` margin among feasible
    candidates: the landing spot whose would-be context keeps the most
    normalized headroom to its nearest constraint (aggregate capacity or
    a per-flow contention ceiling).

    By default the score is the *vector* margin — the min over every
    resource axis — so a bandwidth-bound tenant steers away from a
    memory-saturated server and vice versa.  ``axis=<r>`` scores one
    axis' margin only (feasibility stays vector-checked): ``axis=0`` is
    exactly the pre-vector scalar policy, the comparison baseline
    ``benchmarks/contention.py`` measures the vector gain against."""

    name = "slo_aware"

    def __init__(self, axis: int | None = None):
        self.axis = axis
        if axis is not None:
            self.name = f"slo_aware_axis{axis}"

    def _score(self, c: Candidate) -> float:
        if self.axis is not None and len(c.margin_res) > self.axis:
            return c.margin_res[self.axis]
        return c.margin

    def select(self, candidates: list[Candidate]) -> Candidate | None:
        feasible = [c for c in candidates if c.feasible]
        if not feasible:
            return None
        return min(feasible,
                   key=lambda c: (-self._score(c), self._tie_key(c)))


POLICIES = {p.name: p for p in (FirstFit, BestFit, SLOAware)}


def _score_sig(spec: FlowSpec) -> tuple:
    """Scoring-relevant identity of a candidate spec.

    A candidate's score — profiled entry, canonical SLO vector, margin,
    residual, feasibility — is a function of the would-be context, which
    sees only (path, traffic pattern, SLO); flow/vm ids never enter it.
    Keying on this signature lets a homogeneous tenant stream (same
    shape, different ids) reuse scores round over round.  The
    resource-demand hint re-keys the would-be context (and its margins),
    so it is part of the identity."""
    return (int(spec.path), spec.pattern, spec.slo, spec.res_demand)


class ScoreCache:
    """Stateful candidate scorer: reuse prior-round margins for servers
    whose tables did not change.

    Placement used to re-score every admission round from scratch, even
    though a round changes exactly ONE server (the winner's
    PerFlowStatusTable grows by one tenant, re-keying its would-be
    contexts) — every other server's candidates for a same-shaped spec
    are bit-for-bit the previous round's.  The cache keys the scoring
    fields on (server, accelerator, ``_score_sig(spec)``) and guards them
    with the runtime's ``lifecycle_version`` (bumped by ``register`` /
    ``deregister``): a hit replays the stored floats into a fresh
    ``Candidate`` for the current spec — same margins, same decision —
    and skips rebuilding + profiling the context entirely; a registration
    or departure on a server invalidates only that server's entries.

    ``runtime.place_fleet`` / ``FleetController.place`` thread the
    controller's long-lived cache through their rounds by default; pass
    your own instance to share scores across call sites.  Hit/miss counts
    are exposed via ``profiler.profiling_stats()`` (``score_hits`` /
    ``score_misses``)."""

    def __init__(self):
        self._scores: dict[tuple, tuple[int, tuple]] = {}

    def lookup(self, runtime, server: int, accel_id: int,
               spec: FlowSpec) -> Candidate | None:
        hit = self._scores.get((server, accel_id, _score_sig(spec)))
        # the guard binds the entry to the exact runtime (its
        # process-unique _uid — id() could be reused after gc) AND its
        # membership version: a cache shared across different fleets (or
        # a rebuilt fleet reusing server indices) must never replay
        # another runtime's margins
        if hit is not None and hit[0] == (getattr(runtime, "_uid",
                                                  id(runtime)),
                                          runtime.lifecycle_version):
            profiler._PROFILING_STATS["score_hits"] += 1
            entry, slo, ok, margin, residual, skey, margin_res = hit[1]
            return Candidate(server=server, accel_id=accel_id, spec=spec,
                             entry=entry, slo_gbps=slo, feasible=ok,
                             margin=margin, residual=residual,
                             server_key=skey, margin_res=margin_res)
        profiler._PROFILING_STATS["score_misses"] += 1
        return None

    def store(self, runtime, server: int, accel_id: int, spec: FlowSpec,
              c: Candidate) -> None:
        self._scores[(server, accel_id, _score_sig(spec))] = (
            (getattr(runtime, "_uid", id(runtime)),
             runtime.lifecycle_version),
            (c.entry, c.slo_gbps, c.feasible, c.margin, c.residual,
             c.server_key, c.margin_res))

    def server_margin(self, server: int) -> float | None:
        """Worst cached SLO-aware margin among a server's scored
        candidates (``None`` when the server has none) — an advisory
        tightness signal for the slow control tier
        (``control.GlobalRetarget``): it intentionally ignores the
        version guard, since even a slightly stale margin says more
        about a server's headroom than no signal at all."""
        margins = [vals[3] for key, (_guard, vals) in self._scores.items()
                   if key[0] == server]
        return min(margins) if margins else None

    def clear(self) -> None:
        self._scores.clear()
