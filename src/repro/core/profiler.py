"""Offline profiling -> Capacity(t, X, N) tables (Arcus §3.3, §4.3).

"We propose to perform offline profiling to learn Capacity(t, X, N), i.e.,
the available capacity of an accelerator X at a given time t shared by N
VMs, w.r.t. traffic patterns T, path mode combinations P, and system
settings S."

A *context* is (accelerator, [(path, msg-size bucket, load bucket)] per
flow).  For each context the profiler runs a short, unshaped, full-load
dataplane simulation and records the aggregate achievable capacity and the
per-flow split.  Entries carry a 1-bit SLO-Friendly / SLO-Violating tag,
evaluated against a concrete SLO vector at query time (and re-run whenever a
new flow registers, Sec. 5.3.2).

Contexts are stored in *canonical order* (sorted by (path, msg bucket, load
decile)); ``per_flow_gbps`` follows that order, so a cache hit from a
permuted caller context still lines up.  ``profile_contexts`` batches many
heterogeneous contexts — different flow counts, different accelerators —
into a single ragged ``simulate_batch`` call: one compiled engine executes
the whole Capacity(t, X, N) sweep instead of one compile-bound serial run
per context.  ``profile_contexts_multi`` extends that across *multiple*
ProfileTables (one per client server in a fleet): all cache-missing
contexts of every table, grouped by profiling config, run as one batched
engine call — this is what ``runtime.register_fleet`` drives each
admission round through.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import warnings
from typing import Sequence

import numpy as np

from repro.core import baselines, token_bucket as tb
from repro.core.accelerator import AccelTable, AcceleratorSpec
from repro.core.flow import (PATH_EGRESS_DIR, PATH_INGRESS_DIR, SLO, FlowSet,
                             FlowSpec, Path, TrafficPattern)
from repro.core.interconnect import ARB_RR, RES_LINK, LinkSpec
from repro.core.sim import (SHAPING_NONE, SimConfig, gen_arrivals, simulate,
                            simulate_batch, stack_arrivals)


def msg_bucket(msg_bytes: int) -> int:
    """Log2 bucket of the message size (64B..1MB)."""
    return int(np.clip(np.round(np.log2(max(msg_bytes, 1))), 6, 20))


def canonical_order(flows: list[tuple[Path, int, float]]) -> list[int]:
    """Indices sorting a context into canonical (path, msg bucket, load
    decile) order — the single source of truth for how
    ``CapacityEntry.per_flow_gbps`` (and any positional SLO vector fed to
    ``slo_tag``) is ordered.  Context tuples may carry a 4th element (a
    per-tenant resource-demand hint); it does not participate in the sort
    key, so hinted and unhinted contexts order identically."""
    return sorted(range(len(flows)),
                  key=lambda i: (int(flows[i][0]), msg_bucket(flows[i][1]),
                                 int(round(flows[i][2] * 10))))


def canonical_context(flows: list[tuple[Path, int, float]]
                      ) -> list[tuple[Path, int, float]]:
    """Context flows in canonical order (see ``canonical_order``)."""
    return [flows[i] for i in canonical_order(flows)]


def context_key(accel_name: str,
                flows: list[tuple[Path, int, float]]) -> str:
    """Canonical context: accel + sorted (path, msg bucket, load decile).

    A non-empty resource-demand hint (optional 4th tuple element) is
    appended to that flow's key part — a hinted tenant profiles under its
    own context.  Hint-free tuples produce keys bitwise-identical to the
    pre-vector format, so committed baselines keep hitting."""
    parts = []
    for t in canonical_context(flows):
        s = (f"{int(t[0])}.{msg_bucket(t[1])}.{int(round(t[2] * 10))}")
        if len(t) > 3 and t[3]:
            s += "~" + ",".join(f"{nm}:{ic:g}:{ec:g}"
                                for nm, ic, ec in t[3])
        parts.append(s)
    return accel_name + "|" + ";".join(parts)


@dataclasses.dataclass(init=False)
class CapacityEntry:
    """Profiled capacity of one context, as a resource vector.

    Axis 0 is always the link: ``capacity[0]`` is the measured aggregate
    ingress goodput and ``per_flow[0]`` the measured per-flow split under
    fair arbitration (exactly the pre-vector ``capacity_gbps`` /
    ``per_flow_gbps`` fields, which remain readable as properties).  Each
    extra axis r >= 1 mirrors one ``LinkSpec.resources`` entry:
    ``capacity[r]`` is that axis' shaped capacity in Gbps and
    ``per_flow[r][i]`` the flow's *demand coefficient* — Gbps charged on
    the axis per Gbps of ingress goodput (ingress coefficient plus the
    egress coefficient scaled by the device's egress/ingress byte ratio).

    Migration note: the scalar fields were renamed —
    ``capacity_gbps`` -> ``capacity[0]``, ``per_flow_gbps`` ->
    ``per_flow[0]``.  Scalar positional arguments are promoted to R=1
    vectors silently; the old keyword names still construct entries via a
    ``DeprecationWarning`` shim."""

    capacity: list          # [R] Gbps per axis (axis 0 measured)
    per_flow: list          # [R][n]: measured split / demand coefficients
    fairness: float         # Jain's index of the link split
    ctx: str
    res_names: list         # [R] axis names (axis 0 = "link")

    def __init__(self, capacity=None, per_flow=None, fairness: float = 0.0,
                 ctx: str = "", res_names=None, *,
                 capacity_gbps=None, per_flow_gbps=None):
        if capacity_gbps is not None or per_flow_gbps is not None:
            warnings.warn(
                "CapacityEntry(capacity_gbps=..., per_flow_gbps=...) is "
                "deprecated: pass the vector fields capacity= / per_flow= "
                "(scalars are promoted to R=1)", DeprecationWarning,
                stacklevel=2)
            capacity = capacity_gbps if capacity is None else capacity
            per_flow = per_flow_gbps if per_flow is None else per_flow
        if capacity is None:
            raise TypeError("CapacityEntry requires capacity")
        if not isinstance(capacity, (list, tuple, np.ndarray)):
            capacity = [capacity]              # scalar -> R=1 degenerate
        per_flow = [] if per_flow is None else per_flow
        if not (len(per_flow) and isinstance(per_flow[0],
                                             (list, tuple, np.ndarray))):
            per_flow = [per_flow]              # flat split -> R=1
        self.capacity = [float(c) for c in capacity]
        self.per_flow = [[float(g) for g in row] for row in per_flow]
        self.fairness = float(fairness)
        self.ctx = ctx
        if res_names is None:
            res_names = [RES_LINK] + [f"res{r}"
                                      for r in range(1, len(self.capacity))]
        self.res_names = list(res_names)

    # -- renamed-field compatibility (see class docstring) -------------
    @property
    def capacity_gbps(self) -> float:
        return self.capacity[0]

    @property
    def per_flow_gbps(self) -> list:
        return self.per_flow[0]

    def slo_tag(self, slo_gbps: list[float], margin: float = 0.02) -> bool:
        """True = SLO-Friendly: requested SLOs fit the profiled capacity and
        no single SLO exceeds what contention lets one flow reach.

        The per-flow ceiling is ``n * per_flow_gbps[i]``: a flow whose
        contended fair split is g can at best inherit the other n-1 flows'
        arbiter rounds when shaping throttles them, i.e. ~n x g — a
        small-message flow cannot be promised a large-message flow's rate
        no matter how the others are shaped (Fig. 7 heterogeneity).
        ``slo_gbps`` aligns positionally with ``per_flow_gbps`` (canonical
        context order) when the lengths match; aggregate-style queries
        (fewer SLOs than profiled flows) are checked against the best
        single-flow ceiling.

        Defined as ``slo_margin >= 0`` — one copy of the constraint
        logic; the normalization there preserves every inequality's sign
        exactly, so decisions are identical to checking the raw
        inequalities."""
        return self.slo_margin(slo_gbps, margin) >= 0

    def _axis_demand(self, r: int, slo_gbps: list[float]) -> float:
        """Gbps the SLO vector puts on extra axis r (coefficient-weighted;
        aggregate-style queries use the worst coefficient)."""
        coefs = self.per_flow[r]
        if coefs and len(slo_gbps) == len(coefs):
            return sum(s * c for s, c in zip(slo_gbps, coefs))
        worst = max(coefs, default=1.0)
        return sum(s * worst for s in slo_gbps)

    def residual_gbps(self, slo_gbps: list[float],
                      margin: float = 0.02) -> float:
        """Profiled capacity left once the context's SLO vector is honored
        (negative = oversubscribed), minimized over every resource axis.
        The quantity best-fit placement packs on: the server whose
        post-admission residual is smallest-but-nonnegative is the
        tightest fit.  R=1 entries reduce to the link-axis residual."""
        res = self.capacity[0] * (1 - margin) - sum(slo_gbps)
        for r in range(1, len(self.capacity)):
            res = min(res, self.capacity[r] * (1 - margin)
                      - self._axis_demand(r, slo_gbps))
        return res

    def slo_margins(self, slo_gbps: list[float], margin: float = 0.02
                    ) -> list[float]:
        """Per-axis normalized headroom, aligned with ``res_names``.

        Axis 0 is the pre-vector ``slo_margin``: min of
        (limit - demand) / limit over the aggregate link capacity and the
        per-flow contention ceilings.  Each extra axis r compares the
        coefficient-weighted SLO demand against the axis' shaped
        capacity."""
        cap = self.capacity[0] * (1 - margin)
        m = (cap - sum(slo_gbps)) / max(cap, 1e-12)
        n = len(self.per_flow[0])
        ceil = [n * g * (1 - margin) for g in self.per_flow[0]]
        if n and len(slo_gbps) == n:
            pairs = zip(slo_gbps, ceil)
        else:
            best = max(ceil, default=cap)
            pairs = ((s, best) for s in slo_gbps)
        for s, c in pairs:
            m = min(m, (c - s) / max(c, 1e-12))
        out = [m]
        for r in range(1, len(self.capacity)):
            lim = self.capacity[r] * (1 - margin)
            out.append((lim - self._axis_demand(r, slo_gbps))
                       / max(lim, 1e-12))
        return out

    def slo_margin(self, slo_gbps: list[float], margin: float = 0.02
                   ) -> float:
        """Worst-case headroom across ALL resource axes: the min of
        ``slo_margins``.  Sign-consistent with ``slo_tag`` (>= 0 iff
        SLO-Friendly); the magnitude is what SLO-aware placement maximizes.
        R=1 entries reproduce the pre-vector value bitwise (the min over a
        single axis is that axis)."""
        ms = self.slo_margins(slo_gbps, margin)
        m = ms[0]
        for v in ms[1:]:
            m = min(m, v)
        return m


def _context_specs(flows: list[tuple[Path, int, float]]) -> list[FlowSpec]:
    out = []
    for i, t in enumerate(canonical_context(flows)):
        p, m, l = t[0], t[1], t[2]
        hint = tuple(tuple(h) for h in t[3]) if len(t) > 3 else ()
        out.append(FlowSpec(i, i, p, 0,
                            TrafficPattern(msg_bytes=m, load=max(l, 0.99),
                                           process="poisson"),
                            SLO.gbps(1e9), weight=1.0, res_demand=hint))
    return out


class ProfileTable:
    """The ProfileTable of Sec. 4.3 — pointer per context to profiled
    Capacity results."""

    def __init__(self, link: LinkSpec | None = None,
                 *, n_ticks: int = 60_000, tick_cycles: int = 8,
                 clock_hz: float | None = None):
        self.entries: dict[str, CapacityEntry] = {}
        self.link = link or LinkSpec()
        self.n_ticks = n_ticks
        self.tick_cycles = tick_cycles
        # profiling runs on the table's link clock unless explicitly
        # overridden — dataplane rates, accelerator service cycles and the
        # profiled window seconds then all derive from ONE clock (the same
        # threading run_managed got in PR 4; an explicit clock_hz wins)
        self.clock_hz = float(clock_hz if clock_hz is not None
                              else self.link.clock_hz)

    def _cfg(self) -> SimConfig:
        return SimConfig(n_ticks=self.n_ticks, tick_cycles=self.tick_cycles,
                         clock_hz=self.clock_hz,
                         shaping=SHAPING_NONE, arbiter=ARB_RR)

    def _entry_from_result(self, key: str, res, n: int,
                           accel: AcceleratorSpec | None = None,
                           ctx: list | None = None) -> CapacityEntry:
        per = [res.mean_ingress_gbps(i, None) for i in range(n)]
        x = np.asarray(per)
        fair = float((x.sum() ** 2) / (len(x) * (x ** 2).sum() + 1e-12))
        caps = [float(x.sum())]
        pflows = [per]
        names = [RES_LINK]
        # extra axes: shaped capacity is the axis' static cap; the per-flow
        # column is the demand coefficient the engine charges (ingress
        # coefficient + egress coefficient x the device's egress/ingress
        # byte ratio, with fabric_only axes skipping off-fabric directions)
        for rs in getattr(self.link, "resources", ()):
            coefs = []
            for t in canonical_context(ctx or []):
                p, m = Path(t[0]), float(t[1])
                hint = t[3] if len(t) > 3 else ()
                ic = ec = None
                for nm, a, b in hint:
                    if nm == rs.name:
                        ic, ec = float(a), float(b)
                        break
                if ic is None:
                    ic, ec = (accel.resource_demand(rs.name)
                              if accel is not None else (1.0, 1.0))
                if rs.fabric_only:
                    if PATH_INGRESS_DIR[p] == 2:
                        ic = 0.0
                    if PATH_EGRESS_DIR[p] == 2:
                        ec = 0.0
                if p == Path.INLINE_NIC_RX or accel is None:
                    ratio = 1.0   # full payload delivered to the host
                else:
                    ratio = float(accel.egress_bytes(m)) / max(m, 1.0)
                coefs.append(max(ic, 0.0) + ratio * max(ec, 0.0))
            caps.append(float(rs.capacity_gbps))
            pflows.append(coefs)
            names.append(rs.name)
        entry = CapacityEntry(caps, pflows, fair, key, names)
        self.entries[key] = entry
        return entry

    # -- profiling ------------------------------------------------------
    def profile_context(self, accel: AcceleratorSpec,
                        flows: list[tuple[Path, int, float]],
                        *, seed: int = 0) -> CapacityEntry:
        key = context_key(accel.name, flows)
        if key in self.entries:
            return self.entries[key]
        specs = _context_specs(flows)
        fset = FlowSet.build(specs)
        atab = AccelTable.build([accel], self.clock_hz)
        cfg = self._cfg()
        ref = {i: accel.peak_gbps for i in range(len(specs))}
        arr_t, arr_sz = gen_arrivals(fset, cfg, seed=seed, load_ref_gbps=ref)
        tbs = baselines.make_tb_state(baselines.HOST_NO_TS,
                                      [tb.TBParams(1, 1, 1)] * len(specs))
        res = simulate(fset, atab, self.link, cfg, tbs, arr_t, arr_sz)
        return self._entry_from_result(key, res, len(specs), accel, flows)

    def profile_contexts(self,
                         contexts: Sequence[tuple[AcceleratorSpec,
                                                  list[tuple[Path, int,
                                                             float]]]],
                         *, seed: int = 0) -> list[CapacityEntry]:
        """Profile many heterogeneous contexts in ONE compiled engine call.

        ``contexts`` is a sequence of (accelerator, flows) pairs; flow
        counts may differ (the engine pads + flow-masks the batch) and each
        element carries its own accelerator table.  Already-profiled or
        duplicate contexts are deduplicated against the cache, so only the
        misses are simulated — as one ragged ``simulate_batch``.  Entries
        are bitwise-identical to what serial ``profile_context`` calls
        produce (the masked engine's counters match unpadded serial runs).
        """
        return profile_contexts_multi([(self, a, f) for a, f in contexts],
                                      seed=seed)

    def sweep(self, accel: AcceleratorSpec, *, paths=(Path.FUNCTION_CALL,),
              msg_sizes=(64, 512, 4096), loads=(0.9,),
              n_flows=(1, 2)) -> None:
        """Offline sweep: "all contention cases are swept and recorded" —
        executed as one batched ragged engine call across every context."""
        contexts = []
        for n in n_flows:
            combos = itertools.combinations_with_replacement(
                itertools.product(paths, msg_sizes, loads), n)
            contexts.extend((accel, list(combo)) for combo in combos)
        self.profile_contexts(contexts)

    # -- queries --------------------------------------------------------
    def lookup(self, accel_name: str,
               flows: list[tuple[Path, int, float]]) -> CapacityEntry | None:
        return self.entries.get(context_key(accel_name, flows))

    def capacity(self, accel: AcceleratorSpec,
                 flows: list[tuple[Path, int, float]]) -> CapacityEntry:
        """Lookup; profile on miss (the paper sweeps offline — on-miss
        profiling keeps the repo usable without a pre-baked table)."""
        hit = self.lookup(accel.name, flows)
        return hit if hit is not None else self.profile_context(accel, flows)

    # -- persistence ----------------------------------------------------
    def to_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({k: dataclasses.asdict(v)
                       for k, v in self.entries.items()}, f, indent=1)

    @classmethod
    def from_json(cls, path: str, link: LinkSpec | None = None
                  ) -> "ProfileTable":
        """Load a persisted table.  Both schemas are accepted: the current
        vector form (``capacity`` / ``per_flow`` / ``res_names``) and the
        pre-vector scalar form (``capacity_gbps`` / ``per_flow_gbps``) —
        scalar entries load as R=1 degenerate vectors whose ``capacity[0]``
        / ``per_flow[0]`` are bit-for-bit the persisted floats."""
        t = cls(link)
        with open(path) as f:
            for k, v in json.load(f).items():
                if "capacity_gbps" in v:       # legacy scalar schema
                    t.entries[k] = CapacityEntry(
                        v["capacity_gbps"], v["per_flow_gbps"],
                        v.get("fairness", 0.0), v.get("ctx", ""))
                else:
                    t.entries[k] = CapacityEntry(
                        v["capacity"], v["per_flow"],
                        v.get("fairness", 0.0), v.get("ctx", ""),
                        v.get("res_names"))
        return t

    #: alias — the control-plane callers name the operation "load"
    load_json = from_json


#: running counters over batched profiling: ``calls`` = invocations of
#: ``profile_contexts_multi``, ``sim_batches`` = compiled ``simulate_batch``
#: launches it issued (0 when every context was a cache hit), ``contexts``
#: = cache-missing contexts actually simulated.  ``runtime.place_fleet``'s
#: one-engine-call-per-admission-round contract is asserted against these.
#: ``score_hits`` / ``score_misses`` count ``placement.ScoreCache``
#: candidate-score reuse (a hit skips rebuilding + re-querying the
#: candidate's would-be context entirely).
_PROFILING_STATS = {"calls": 0, "sim_batches": 0, "contexts": 0,
                    "score_hits": 0, "score_misses": 0}


def profiling_stats() -> dict[str, int]:
    """Snapshot of the batched-profiling counters (see above)."""
    return dict(_PROFILING_STATS)


def profiling_stats_clear() -> None:
    for k in _PROFILING_STATS:
        _PROFILING_STATS[k] = 0


def profile_contexts_multi(jobs: Sequence[tuple["ProfileTable",
                                                AcceleratorSpec,
                                                list[tuple[Path, int,
                                                           float]]]],
                           *, seed: int = 0) -> list[CapacityEntry]:
    """Fleet-aware batched profiling across MULTIPLE ProfileTables.

    ``jobs`` is a sequence of (table, accelerator, flows-context) triples —
    typically one per client server in a fleet, each server holding its own
    ProfileTable (possibly with its own LinkSpec).  All cache-missing
    contexts, deduplicated per table, run as ONE ragged ``simulate_batch``
    per profiling config (tables sharing ``n_ticks``/``tick_cycles``/
    ``clock_hz`` share the call; per-table links ride the batch's link
    axis).  Entries are
    bitwise-identical to serial ``profile_context`` runs and are written
    into each job's own table.  Returns entries aligned with ``jobs``."""
    _PROFILING_STATS["calls"] += 1
    keys = [context_key(a.name, f) for _, a, f in jobs]
    todo: dict[tuple[int, str], tuple["ProfileTable", str, AcceleratorSpec,
                                      list]] = {}
    for (table, accel, flows), key in zip(jobs, keys):
        tk = (id(table), key)
        if key not in table.entries and tk not in todo:
            todo[tk] = (table, key, accel, flows)
    groups: dict[tuple[int, int, float], list] = {}
    for item in todo.values():
        table = item[0]
        groups.setdefault((table.n_ticks, table.tick_cycles, table.clock_hz),
                          []).append(item)
    for items in groups.values():
        _PROFILING_STATS["sim_batches"] += 1
        _PROFILING_STATS["contexts"] += len(items)
        cfg = items[0][0]._cfg()
        fsets, atabs, tbss, arrs, ns, links = [], [], [], [], [], []
        for table, key, accel, flows in items:
            specs = _context_specs(flows)
            fset = FlowSet.build(specs)
            ref = {i: accel.peak_gbps for i in range(len(specs))}
            fsets.append(fset)
            atabs.append(AccelTable.build([accel], table.clock_hz))
            tbss.append(baselines.make_tb_state(
                baselines.HOST_NO_TS,
                [tb.TBParams(1, 1, 1)] * len(specs)))
            arrs.append(gen_arrivals(fset, cfg, seed=seed,
                                     load_ref_gbps=ref))
            ns.append(len(specs))
            links.append(table.link)
        link_arg = links[0] if all(ln is links[0] for ln in links) else links
        results = simulate_batch(fsets, atabs, link_arg, cfg, tbss,
                                 *stack_arrivals(arrs))
        for (table, key, a, f), res, n in zip(items, results, ns):
            table._entry_from_result(key, res, n, a, f)
    return [t.entries[k] for (t, _, _), k in zip(jobs, keys)]
