"""Offline profiling -> Capacity(t, X, N) tables (Arcus §3.3, §4.3).

"We propose to perform offline profiling to learn Capacity(t, X, N), i.e.,
the available capacity of an accelerator X at a given time t shared by N
VMs, w.r.t. traffic patterns T, path mode combinations P, and system
settings S."

A *context* is (accelerator, [(path, msg-size bucket, load bucket)] per
flow).  For each context the profiler runs a short, unshaped, full-load
dataplane simulation and records the aggregate achievable capacity and the
per-flow split.  Entries carry a 1-bit SLO-Friendly / SLO-Violating tag,
evaluated against a concrete SLO vector at query time (and re-run whenever a
new flow registers, Sec. 5.3.2).
"""
from __future__ import annotations

import dataclasses
import itertools
import json

import numpy as np

from repro.core import baselines, token_bucket as tb
from repro.core.accelerator import AccelTable, AcceleratorSpec
from repro.core.flow import (SLO, FlowSet, FlowSpec, Path, TrafficPattern)
from repro.core.interconnect import ARB_RR, LinkSpec
from repro.core.sim import SHAPING_NONE, SimConfig, gen_arrivals, simulate


def msg_bucket(msg_bytes: int) -> int:
    """Log2 bucket of the message size (64B..1MB)."""
    return int(np.clip(np.round(np.log2(max(msg_bytes, 1))), 6, 20))


def context_key(accel_name: str,
                flows: list[tuple[Path, int, float]]) -> str:
    """Canonical context: accel + sorted (path, msg bucket, load decile)."""
    parts = sorted((int(p), msg_bucket(m), int(round(l * 10)))
                   for p, m, l in flows)
    return accel_name + "|" + ";".join(f"{p}.{m}.{l}" for p, m, l in parts)


@dataclasses.dataclass
class CapacityEntry:
    capacity_gbps: float           # aggregate ingress goodput achievable
    per_flow_gbps: list[float]     # split under fair arbitration
    fairness: float                # Jain's index of the split
    ctx: str = ""

    def slo_tag(self, slo_gbps: list[float], margin: float = 0.02) -> bool:
        """True = SLO-Friendly: requested SLOs fit the profiled capacity and
        no single SLO exceeds what contention lets one flow reach."""
        total_ok = sum(slo_gbps) <= self.capacity_gbps * (1 - margin)
        return bool(total_ok)


class ProfileTable:
    """The ProfileTable of Sec. 4.3 — pointer per context to profiled
    Capacity results."""

    def __init__(self, link: LinkSpec | None = None,
                 *, n_ticks: int = 60_000, tick_cycles: int = 8):
        self.entries: dict[str, CapacityEntry] = {}
        self.link = link or LinkSpec()
        self.n_ticks = n_ticks
        self.tick_cycles = tick_cycles

    # -- profiling ------------------------------------------------------
    def profile_context(self, accel: AcceleratorSpec,
                        flows: list[tuple[Path, int, float]],
                        *, seed: int = 0) -> CapacityEntry:
        key = context_key(accel.name, flows)
        if key in self.entries:
            return self.entries[key]
        specs = [
            FlowSpec(i, i, p, 0,
                     TrafficPattern(msg_bytes=m, load=max(l, 0.99),
                                    process="poisson"),
                     SLO.gbps(1e9), weight=1.0)
            for i, (p, m, l) in enumerate(flows)
        ]
        fset = FlowSet.build(specs)
        atab = AccelTable.build([accel])
        cfg = SimConfig(n_ticks=self.n_ticks, tick_cycles=self.tick_cycles,
                        shaping=SHAPING_NONE, arbiter=ARB_RR)
        ref = {i: accel.peak_gbps for i in range(len(specs))}
        arr_t, arr_sz = gen_arrivals(fset, cfg, seed=seed, load_ref_gbps=ref)
        tbs = baselines.make_tb_state(baselines.HOST_NO_TS,
                                      [tb.TBParams(1, 1, 1)] * len(specs))
        res = simulate(fset, atab, self.link, cfg, tbs, arr_t, arr_sz)
        per = [res.mean_ingress_gbps(i, fset) for i in range(len(specs))]
        x = np.asarray(per)
        fair = float((x.sum() ** 2) / (len(x) * (x ** 2).sum() + 1e-12))
        entry = CapacityEntry(float(x.sum()), per, fair, key)
        self.entries[key] = entry
        return entry

    def sweep(self, accel: AcceleratorSpec, *, paths=(Path.FUNCTION_CALL,),
              msg_sizes=(64, 512, 4096), loads=(0.9,),
              n_flows=(1, 2)) -> None:
        """Offline sweep: "all contention cases are swept and recorded"."""
        for n in n_flows:
            combos = itertools.combinations_with_replacement(
                itertools.product(paths, msg_sizes, loads), n)
            for combo in combos:
                self.profile_context(accel, list(combo))

    # -- queries --------------------------------------------------------
    def lookup(self, accel_name: str,
               flows: list[tuple[Path, int, float]]) -> CapacityEntry | None:
        return self.entries.get(context_key(accel_name, flows))

    def capacity(self, accel: AcceleratorSpec,
                 flows: list[tuple[Path, int, float]]) -> CapacityEntry:
        """Lookup; profile on miss (the paper sweeps offline — on-miss
        profiling keeps the repo usable without a pre-baked table)."""
        hit = self.lookup(accel.name, flows)
        return hit if hit is not None else self.profile_context(accel, flows)

    # -- persistence ----------------------------------------------------
    def to_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({k: dataclasses.asdict(v)
                       for k, v in self.entries.items()}, f, indent=1)

    @classmethod
    def from_json(cls, path: str, link: LinkSpec | None = None
                  ) -> "ProfileTable":
        t = cls(link)
        with open(path) as f:
            for k, v in json.load(f).items():
                t.entries[k] = CapacityEntry(**v)
        return t
