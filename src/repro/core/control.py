"""Closed-loop shaping policies: WindowMetrics -> token-bucket plans.

The decision layer of the measurement -> policy -> actuation pipeline.
Arcus's shaping rates come from offline profiled capacities and only
change on admit/rebalance, so a mis-profiled or drifting tenant stays
wrong for its whole lifetime.  This module closes the loop bi-level
(Autothrottle-style): a cheap per-server fast tier nudges each tenant's
shaped rate every window toward its measured SLO slack, and a slow
global tier re-targets per-tenant budgets every K windows from the
placement layer's cached margins.

The contract with the controller:

* ``ControlPolicy.decide(window, servers)`` sees one ``ServerView`` per
  server — this window's ``WindowMetrics`` plus each rate-SLO tenant's
  profiled capacity ``Envelope`` — and returns per-server
  ``{flow_id: RatePlan}`` dicts (``None`` = hold that server steady).
* ``actuate`` turns plans into ``TBParams`` register values through the
  same ``params_for_gbps`` / ``params_for_iops`` path admission uses,
  and reports whether anything actually changed — an all-steady window
  keeps the controller's no-register-rewrite resume path.
* ``StaticHold`` decides nothing, computes nothing (not even
  envelopes): a ``StaticHold`` run is bitwise-identical to the
  pre-control-loop controller.

Every policy's plans are clamped to the profiled capacity envelope:
``floor`` is the rate the SLO requires (shaping below it would
manufacture violations), ``ceil`` the most the profiled capacity says
this tenant can take without stealing a co-tenant's SLO headroom.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core import token_bucket as tb
from repro.core.flow import SLOKind
from repro.core.profiler import canonical_order
from repro.core.shaper import reshape_decision
from repro.core.telemetry import WindowMetrics


@dataclasses.dataclass(frozen=True)
class RatePlan:
    """One tenant's shaped-rate decision, in the flow's own SLO unit
    (Gbps or IOPS).  ``burst_scale`` scales the bucket depth relative to
    the planner's default — a fractional depth paces bursts smoothly
    without touching the long-run rate (the Fig. 9 lever)."""

    rate: float
    burst_scale: float = 1.0


@dataclasses.dataclass(frozen=True)
class Envelope:
    """Profiled clamp for one tenant's shaped rate (SLO units).
    ``floor`` = the SLO-required rate; ``ceil`` = floor plus the
    capacity headroom the profile says the tenant may absorb."""

    floor: float
    ceil: float

    def clamp(self, rate: float) -> float:
        return min(max(rate, self.floor), self.ceil)


@dataclasses.dataclass
class ServerView:
    """What a policy sees of one server for one window."""

    server: int
    window_s: float
    metrics: dict[int, WindowMetrics]
    envelopes: dict[int, Envelope]     # rate-SLO tenants only
    margin: float | None = None        # cached placement margin (ScoreCache)


class ControlPolicy:
    """Protocol for between-window shaping policies.

    ``needs_envelopes=False`` lets a policy opt out of envelope (and
    placement-margin) computation entirely — the controller then skips
    every profile lookup on its behalf."""

    name = "base"
    needs_envelopes = True

    def reset(self) -> None:
        """Forget per-run state; called at the start of every run."""

    def decide(self, window: int, servers: Sequence[ServerView]
               ) -> list[dict[int, RatePlan] | None]:
        raise NotImplementedError


class StaticHold(ControlPolicy):
    """Keep every register exactly as admission configured it — the
    pre-control-loop behaviour, bitwise (no envelope computation, no
    actuation, no extra profile lookups)."""

    name = "static-hold"
    needs_envelopes = False

    def decide(self, window: int, servers: Sequence[ServerView]
               ) -> list[dict[int, RatePlan] | None]:
        return [None] * len(servers)


class SlackAIMD(ControlPolicy):
    """Per-server fast tier: AIMD on each tenant's granted slack.

    Each rate-SLO tenant's shaped rate lives at ``floor + frac * (ceil -
    floor)`` of its envelope.  A clear window (no co-located SLO
    violation — rate or latency — and every tenant's slack above the
    ``guard`` band) additively raises every tenant's ``frac`` by ``ai``
    toward the profiled ceiling; a violated window multiplicatively
    decays the *granted slack* (``frac *= md``) and shrinks bucket depth
    by ``burst_md`` — the floor is the SLO-required rate, so decrease
    never shapes a tenant below its own SLO.  In between (nothing
    violated, but some tenant inside the guard band) the state holds:
    plans repeat, ``actuate`` reports no change, and the window keeps
    the no-register-rewrite resume path.  Bucket depth recovers
    additively on clear windows.  By construction the rate never leaves
    ``[floor, ceil]`` and increases monotonically on a violation-free
    comfortable trace."""

    name = "slack-aimd"

    def __init__(self, *, ai: float = 0.25, md: float = 0.5,
                 burst_md: float = 0.5, burst_min: float = 0.05,
                 burst_ai: float = 0.25, start_frac: float = 0.0,
                 guard: float = 0.1):
        if not 0.0 < md <= 1.0 or not 0.0 < burst_md <= 1.0:
            raise ValueError("md / burst_md must be in (0, 1]")
        self.ai = float(ai)
        self.md = float(md)
        self.burst_md = float(burst_md)
        self.burst_min = float(burst_min)
        self.burst_ai = float(burst_ai)
        self.start_frac = float(start_frac)
        self.guard = float(guard)
        self._state: dict[tuple[int, int], list[float]] = {}

    def reset(self) -> None:
        self._state.clear()

    def _decide_server(self, sv: ServerView,
                       envelopes: dict[int, Envelope]
                       ) -> dict[int, RatePlan] | None:
        if not envelopes:
            return None
        violated = any(m.violated for m in sv.metrics.values())
        slacks = [m.slack for m in sv.metrics.values()
                  if m.slack == m.slack]          # NaN-aware
        clear = (not violated
                 and (not slacks or min(slacks) > self.guard))
        plans: dict[int, RatePlan] = {}
        for fid, env in envelopes.items():
            st = self._state.setdefault((sv.server, fid),
                                        [self.start_frac, 1.0])
            if clear:
                st[0] = min(1.0, st[0] + self.ai)
                st[1] = min(1.0, st[1] + self.burst_ai)
            elif violated:
                st[0] *= self.md
                st[1] = max(self.burst_min, st[1] * self.burst_md)
            # guard band: hold the state — the plan repeats and the
            # window stays on the no-register-rewrite resume path
            rate = env.clamp(env.floor + st[0] * (env.ceil - env.floor))
            plans[fid] = RatePlan(rate=rate, burst_scale=st[1])
        return plans

    def decide(self, window: int, servers: Sequence[ServerView]
               ) -> list[dict[int, RatePlan] | None]:
        return [self._decide_server(sv, sv.envelopes) for sv in servers]


class GlobalRetarget(ControlPolicy):
    """Slow global tier wrapping a fast per-server policy.

    Every ``period`` windows it re-targets the per-tenant slack budget
    before delegating to the inner policy: each server's total grant
    range (``sum(ceil - floor)``) is re-divided across its tenants in
    proportion to observed need (``1 + violation streak``, weighted by
    measured shortfall), and the whole budget is scaled down when the
    placement layer's cached margin for the server is thin.  Re-targeted
    ceilings never exceed the profiled per-tenant ceiling, so the inner
    policy's envelope guarantee is preserved; between re-target windows
    the last computed ceilings stay in force."""

    name = "global-retarget"

    def __init__(self, inner: ControlPolicy | None = None, *,
                 period: int = 4, margin_floor: float = 0.05):
        self.inner = inner if inner is not None else SlackAIMD()
        self.period = max(int(period), 1)
        self.margin_floor = float(margin_floor)
        self._ceilings: dict[tuple[int, int], float] = {}

    def reset(self) -> None:
        self._ceilings.clear()
        self.inner.reset()

    def _retarget(self, sv: ServerView) -> None:
        envs = sv.envelopes
        if not envs:
            return
        budget = sum(e.ceil - e.floor for e in envs.values())
        if sv.margin is not None and sv.margin < self.margin_floor:
            # the placement layer thinks this server is tight: hand out
            # proportionally less of the profiled headroom
            budget *= max(sv.margin, 0.0) / self.margin_floor
        weights = {}
        for fid in envs:
            m = sv.metrics.get(fid)
            need = 1.0 + (m.streak if m is not None else 0)
            if m is not None and m.slack == m.slack and m.slack < 0:
                need += -m.slack
            weights[fid] = need
        total = sum(weights.values())
        for fid, env in envs.items():
            share = budget * weights[fid] / total if total > 0 else 0.0
            self._ceilings[(sv.server, fid)] = min(env.ceil,
                                                   env.floor + share)

    def decide(self, window: int, servers: Sequence[ServerView]
               ) -> list[dict[int, RatePlan] | None]:
        if window % self.period == 0:
            for sv in servers:
                self._retarget(sv)
        shaped = []
        for sv in servers:
            envs = {fid: dataclasses.replace(
                        env, ceil=max(env.floor,
                                      self._ceilings.get((sv.server, fid),
                                                         env.ceil)))
                    for fid, env in sv.envelopes.items()}
            shaped.append(dataclasses.replace(sv, envelopes=envs))
        return self.inner.decide(window, shaped)


# ---------------------------------------------------------------------------
# Capacity envelopes (the profiled clamp)
# ---------------------------------------------------------------------------


def capacity_envelopes(rt) -> dict[int, Envelope]:
    """Per-tenant shaped-rate envelopes from the server's ProfileTable.

    For every accelerator group the current context's ``CapacityEntry``
    (a cache hit when admission pre-warmed it) yields each rate-SLO
    tenant's headroom: the Gbps it could additionally absorb without
    violating any capacity axis — the aggregate link capacity, its own
    contention ceiling (``n * per_flow``), and every extra shaped
    resource axis through the tenant's demand coefficient.  Converted to
    the flow's SLO unit: ``Envelope(floor=slo_rate, ceil=floor +
    headroom)``."""
    out: dict[int, Envelope] = {}
    by_accel: dict[int, list] = {}
    for fid in sorted(rt.table):
        by_accel.setdefault(rt.table[fid].spec.accel_id, []).append(fid)
    margin = 0.02
    for a, fids in by_accel.items():
        accel = rt.accel_specs[a]
        peers = [rt.table[f].spec for f in fids]
        ctx = [(s.path, s.pattern.msg_bytes, s.pattern.load)
               + ((s.res_demand,) if s.res_demand else ())
               for s in peers]
        entry = rt.profile.capacity(accel, ctx)
        order = canonical_order(ctx)
        slo_gbps = [rt._slo_gbps(peers[i]) for i in order]
        pos_of = {order[j]: j for j in range(len(order))}
        agg_head = entry.capacity[0] * (1 - margin) - sum(slo_gbps)
        for i, fid in enumerate(fids):
            spec = peers[i]
            if spec.slo.kind == SLOKind.LATENCY:
                continue
            j = pos_of[i]
            n = len(entry.per_flow[0])
            head = max(agg_head, 0.0)
            if n == len(slo_gbps) and j < n:
                ceil_i = n * entry.per_flow[0][j] * (1 - margin)
                head = min(head, max(ceil_i - slo_gbps[j], 0.0))
            for r in range(1, len(entry.capacity)):
                coefs = entry.per_flow[r]
                coef = (coefs[j] if n and len(slo_gbps) == len(coefs)
                        else max(coefs, default=1.0))
                lim = entry.capacity[r] * (1 - margin)
                head_r = lim - entry._axis_demand(r, slo_gbps)
                head = min(head, max(head_r, 0.0) / max(coef, 1e-12))
            floor = float(spec.slo.target)
            if spec.slo.kind == SLOKind.IOPS:
                head = head * 1e9 / (8 * max(spec.pattern.msg_bytes, 1))
            out[fid] = Envelope(floor=floor, ceil=floor + max(head, 0.0))
    return out


# ---------------------------------------------------------------------------
# Actuation: RatePlan -> TBParams register values
# ---------------------------------------------------------------------------


def plan_params(rt, st, plan: RatePlan) -> tb.TBParams:
    """Token-bucket registers realizing a plan — the exact
    ``reshape_decision`` planner admission uses (with the plan's rate as
    the SLO target), so an adaptive rate at the envelope floor
    reproduces admission's registers bit-for-bit, message splitting
    included.  ``burst_scale`` then shrinks/keeps the bucket depth,
    clamped so one refill quantum (and one message, in Gbps mode)
    always fits."""
    spec = st.spec
    decision = reshape_decision(
        rt.accel_specs[spec.accel_id],
        dataclasses.replace(spec.slo, target=plan.rate),
        spec.pattern.msg_bytes, clock_hz=rt.clock_hz)
    params = decision.params
    if plan.burst_scale != 1.0:
        min_bkt = (1 if spec.slo.kind == SLOKind.IOPS
                   else spec.pattern.msg_bytes)
        bkt = int(round(params.bkt_size * plan.burst_scale))
        params = dataclasses.replace(
            params, bkt_size=max(bkt, params.refill_rate, min_bkt))
    return params


def actuate(rt, plans: dict[int, RatePlan]) -> bool:
    """Commit one server's plans to its PerFlowStatusTable registers.

    Returns True iff some register value actually changed — the
    controller re-packs (and rewrites) that server's TBState next window
    only then, so policies that hold steady keep the
    no-register-rewrite resume path."""
    changed = False
    for fid, plan in plans.items():
        st = rt.table.get(fid)
        if st is None or st.spec.slo.kind == SLOKind.LATENCY:
            continue
        params = plan_params(rt, st, plan)
        if params != st.params:
            st.params = params
            st.reconfigs += 1
            changed = True
    return changed
