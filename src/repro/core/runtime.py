"""Arcus control-plane runtime — Algorithm 1 (Sec. 4.3).

Runs on every client server; periodically:
  * reads per-flow hardware counters (SLOViolationChecker),
  * re-adjusts shaping (ReAdjustPattern = PathSelection + ReshapeDecision,
    committed to the parameter registers without stopping the dataplane),
  * admits/rejects new registrations (AdmissionControl + CapacityPlanning
    over the ProfileTable and PerFlowStatusTable).

The dataplane is the jitted simulator (`repro.core.sim`); register writes
are the carry's TBState parameter fields — the MMIO analogue.

Fleet scale: the tenant-lifecycle controller
(``repro.core.controller.FleetController``) drives B client servers'
managed dataplanes as ONE compiled program and owns admission placement,
departure and rebalancing.  The module-level ``register_fleet`` /
``place_fleet`` / ``run_managed_batch`` entry points remain as thin
deprecation shims delegating to it (decision- and counter-bitwise
compatible); this module keeps the per-server primitives the controller
composes: ``ArcusRuntime`` (register/deregister, the Algorithm 1 window
pass) and the fleet measurement helpers.
"""
from __future__ import annotations

import dataclasses
import itertools
import warnings
from typing import Any, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import placement, telemetry
from repro.core import token_bucket as tb
from repro.core.accelerator import AccelTable, AcceleratorSpec
from repro.core.flow import (PATH_INGRESS_DIR, FlowSet, FlowSpec, Path,
                             SLOKind)
from repro.core.interconnect import ARB_RR, LinkSpec
from repro.core.profiler import ProfileTable, canonical_order
from repro.core.shaper import reshape_decision
from repro.core.sim import SHAPING_HW, SimConfig, gen_arrivals, simulate


#: process-unique ArcusRuntime ids (never reused, unlike ``id()``)
_RUNTIME_UID = itertools.count()


@dataclasses.dataclass
class FlowStatus:
    """One PerFlowStatusTable entry (Sec. 4.3 "Capacity planning")."""

    spec: FlowSpec                    # VM id, path id, accelerator id, SLO
    params: tb.TBParams               # mechanism parameters configured
    headroom: float = 1.0             # control-knob: pacing over-provision
    measured: float = float("nan")    # current SLO status (hw counters)
    violations: int = 0
    reconfigs: int = 0
    accepted: bool = True
    streak: int = 0                   # consecutive violated windows (incl.
                                      # latency-SLO violations, which feed
                                      # WindowMetrics but never `violations`)


@dataclasses.dataclass
class WindowReport:
    """One window's Algorithm 1 outcome.

    The legacy fields (``measured`` .. ``path_changes``) keep their
    exact pre-telemetry semantics; ``metrics`` carries the per-tenant
    ``telemetry.WindowMetrics`` digest (SLO slack, violation streak,
    mean latency, per-resource-axis utilization) that control policies
    and benchmarks consume — one schema instead of each re-deriving
    from raw counters.  ``to_json`` / ``from_json`` round-trip the whole
    report."""

    t_end_s: float
    measured: dict[int, float]
    violated: list[int]
    reconfigured: list[int]
    path_changes: list[tuple[int, int, int]]
    metrics: dict[int, telemetry.WindowMetrics] = \
        dataclasses.field(default_factory=dict)

    def to_json(self) -> dict:
        return {
            "t_end_s": self.t_end_s,
            "measured": {str(k): v for k, v in self.measured.items()},
            "violated": list(self.violated),
            "reconfigured": list(self.reconfigured),
            "path_changes": [list(pc) for pc in self.path_changes],
            "metrics": {str(k): m.to_json()
                        for k, m in self.metrics.items()},
        }

    @staticmethod
    def from_json(d: dict) -> "WindowReport":
        return WindowReport(
            t_end_s=float(d["t_end_s"]),
            measured={int(k): float(v)
                      for k, v in d.get("measured", {}).items()},
            violated=[int(f) for f in d.get("violated", [])],
            reconfigured=[int(f) for f in d.get("reconfigured", [])],
            path_changes=[tuple(int(x) for x in pc)
                          for pc in d.get("path_changes", [])],
            metrics={int(k): telemetry.WindowMetrics.from_json(m)
                     for k, m in d.get("metrics", {}).items()})


class ArcusRuntime:
    """SLO manager for one client server (Algorithm 1)."""

    def __init__(self, accels: list[AcceleratorSpec],
                 link: LinkSpec | None = None,
                 profile_table: ProfileTable | None = None,
                 *, clock_hz: float = 250e6, slo_tol: float = 0.02,
                 alt_paths: dict[int, list[Path]] | None = None):
        self.accel_specs = accels
        self.clock_hz = clock_hz
        # the runtime clock threads into every config the runtime builds
        # itself: a default link (and the ProfileTable riding on it) runs
        # on the control clock, so dataplane rates, profiled capacities
        # and window seconds share one clock.  An explicitly passed link
        # or profile table wins — it is the caller's override.
        self.link = link if link is not None else LinkSpec(clock_hz=clock_hz)
        self.profile = profile_table or ProfileTable(self.link)
        self.slo_tol = slo_tol
        self.alt_paths = alt_paths or {}
        self.table: dict[int, FlowStatus] = {}   # PerFlowStatusTable
        self._prev_counters: dict[str, np.ndarray] | None = None
        self._uid = next(_RUNTIME_UID)   # process-unique identity for
                                         # ScoreCache guards (id() can be
                                         # reused after gc; this cannot)
        self._version = 0        # bumped on register/deregister/path
                                 # changes — the placement.ScoreCache
                                 # invalidation guard

    # ------------------------------------------------------------------
    # Registration path (Algorithm 1 lines 7-10)
    # ------------------------------------------------------------------
    def register(self, spec: FlowSpec) -> bool:
        if not self._admission_control(spec):
            return False                       # Reject registration (line 9)
        decision = reshape_decision(self.accel_specs[spec.accel_id],
                                    spec.slo, spec.pattern.msg_bytes,
                                    clock_hz=self.clock_hz)
        self.table[spec.flow_id] = FlowStatus(spec=spec,
                                              params=decision.params)
        self._version += 1
        return True

    def deregister(self, flow_id: int) -> FlowStatus:
        """Tenant departure: drop the flow from the PerFlowStatusTable.

        Capacity planning sees the shrunk context immediately (the next
        admission's would-be context no longer includes the tenant, so an
        admit→depart→admit of the same spec reproduces the original
        decision from the same cached profile entries).  Raises
        ``KeyError`` for an unknown flow.  Callers running a live fleet
        should go through ``FleetController.depart`` — it also frees the
        tenant's dataplane lane."""
        st = self.table.pop(flow_id)
        self._version += 1
        return st

    @property
    def lifecycle_version(self) -> int:
        """Monotonic counter of membership changes (register/deregister);
        ``placement.ScoreCache`` entries are valid only while the version
        they were scored at still matches."""
        return self._version

    def _admission_context(self, spec: FlowSpec
                           ) -> tuple[AcceleratorSpec, list[FlowSpec],
                                      list[tuple[Path, int, float]]]:
        """The would-be CapacityPlanning context if ``spec`` registered:
        (accelerator, peer specs incl. the candidate, profiler context).
        Single source of truth — ``register_fleet`` pre-profiles exactly
        this context, so its cache warming always matches admission."""
        accel = self.accel_specs[spec.accel_id]
        peers = [s.spec for s in self.table.values()
                 if s.spec.accel_id == spec.accel_id] + [spec]
        # a tenant's resource-demand hint rides the context as a 4th tuple
        # element (re-keying its profiled contexts); hint-free tenants keep
        # the 3-tuple form so every existing context key stays bit-stable
        ctx = [(s.path, s.pattern.msg_bytes, s.pattern.load)
               + ((s.res_demand,) if s.res_demand else ())
               for s in peers]
        return accel, peers, ctx

    def _admission_check(self, spec: FlowSpec, _context=None):
        """CapacityPlanning(CHECK) with its evidence: (SLO-Friendly?,
        CapacityEntry, canonical-order SLO vector, slo_margin, per-axis
        slo_margins).  ``place_fleet`` scores candidates with exactly this
        tuple — and passes back the (accel, peers, ctx) triple it already
        built for profiling — so a feasible candidate is by construction
        one ``register`` will accept."""
        accel, peers, ctx = (_context if _context is not None
                             else self._admission_context(spec))
        entry = self.profile.capacity(accel, ctx)
        # per-flow SLO vector in the entry's canonical context order
        slo_gbps = [self._slo_gbps(peers[i]) for i in canonical_order(ctx)]
        margin_res = entry.slo_margins(slo_gbps)
        margin = margin_res[0]
        for v in margin_res[1:]:
            margin = min(margin, v)
        # slo_tag is defined as slo_margin >= 0 — one decision, one copy
        return margin >= 0, entry, slo_gbps, margin, tuple(margin_res)

    def _admission_control(self, spec: FlowSpec) -> bool:
        """CapacityPlanning(CHECK): the profiled capacity of the would-be
        context must cover every flow's SLO — in aggregate, and per flow
        (a small-message flow cannot be promised more than contention lets
        one flow reach, see ``CapacityEntry.slo_tag``)."""
        return self._admission_check(spec)[0]

    def _slo_gbps(self, spec: FlowSpec) -> float:
        if spec.slo.kind == SLOKind.GBPS:
            return spec.slo.target
        if spec.slo.kind == SLOKind.IOPS:
            return spec.slo.target * spec.pattern.msg_bytes * 8 / 1e9
        return 0.0  # latency SLOs are enforced by shaping others, not pacing

    # ------------------------------------------------------------------
    # Managed execution: dataplane windows + periodic Algorithm 1 pass
    # ------------------------------------------------------------------
    def run_managed(self, *, total_ticks: int, window_ticks: int,
                    tick_cycles: int = 8, seed: int = 0,
                    arrivals: tuple[np.ndarray, np.ndarray] | None = None,
                    load_ref_gbps: dict[int, float] | None = None,
                    sim_kwargs: dict[str, Any] | None = None):
        """Run the dataplane with periodic SLO management.

        Every window runs the same compiled engine: the static signature
        (SimConfig + shapes) is identical across windows, so windows 1..W-1
        are pure cache hits — register writes, path changes and the rolling
        carry are all traced arguments.  The carry is donated to the engine
        each window (device buffers are reused in place, never copied back
        to the host between windows).

        A trailing partial window (``total_ticks % window_ticks != 0``) runs
        as one final short window — a second engine-cache entry, not a
        silently dropped tail.

        Returns (SimResult of the last window — containing the full
        completion history ring — and the list of WindowReports)."""
        flows = self._flowset()
        atab = AccelTable.build(self.accel_specs, self.clock_hz)
        # the dataplane runs on the runtime's clock: arrival rates, link
        # bandwidth, window seconds and report timestamps all derive from
        # the same SimConfig clock (an explicit sim_kwargs clock still wins)
        sim_kw = dict(sim_kwargs or {})
        sim_kw.setdefault("clock_hz", self.clock_hz)
        cfg = SimConfig(n_ticks=window_ticks, tick_cycles=tick_cycles,
                        shaping=SHAPING_HW, arbiter=ARB_RR, **sim_kw)
        full_cfg = dataclasses.replace(cfg, n_ticks=total_ticks)
        if arrivals is None:
            arrivals = gen_arrivals(flows, full_cfg, seed=seed,
                                    load_ref_gbps=load_ref_gbps)
        # place the full-horizon trace on device once; per-window calls
        # then pass the same committed buffers (no host->device copies)
        arr_t, arr_sz = (jnp.asarray(a) for a in arrivals)
        carry = None
        reports: list[WindowReport] = []
        result = None
        self._prev_counters = None
        n_full, rem = divmod(total_ticks, window_ticks)
        windows = [(w * window_ticks, cfg) for w in range(n_full)]
        if rem:
            windows.append((n_full * window_ticks,
                            dataclasses.replace(cfg, n_ticks=rem)))
        for t0, wcfg in windows:
            tbs = tb.pack([self.table[f].params for f in sorted(self.table)])
            result, carry = simulate(
                flows, atab, self.link, wcfg, tbs, arr_t, arr_sz,
                t0_ticks=t0, carry=carry, return_carry=True)
            reports.append(self._algorithm1_pass(result, wcfg))
            flows = self._flowset()   # path changes take effect next window
        return result, reports

    def _flowset(self) -> FlowSet:
        return FlowSet.build([self.table[f].spec for f in sorted(self.table)])

    # ------------------------------------------------------------------
    # Algorithm 1 main loop body (lines 3-6)
    # ------------------------------------------------------------------
    def _algorithm1_pass(self, result, cfg: SimConfig) -> WindowReport:
        window_s = cfg.seconds   # the dataplane clock (== self.clock_hz
                                 # unless sim_kwargs overrode it)
        cur = {k: np.array(v) for k, v in result.counters.items()}
        prev = self._prev_counters or {k: np.zeros_like(v)
                                       for k, v in cur.items()}
        self._prev_counters = cur
        kind = np.array([int(self.table[fid].spec.slo.kind)
                         for fid in sorted(self.table)], np.int32)
        measured_row = _measured_rates(cur, prev, kind, window_s)
        return self._window_pass(cur, prev, window_s, result.seconds,
                                 measured_row)

    def _window_pass(self, cur, prev, window_s: float, t_end_s: float,
                     measured_row: np.ndarray,
                     lane_of: dict[int, int] | None = None) -> WindowReport:
        """Per-flow half of the Algorithm 1 window pass: violation check +
        ReAdjustPattern + report assembly.  The single body shared by the
        serial and fleet paths — the fleet's bitwise-equality contract
        rides on there being exactly one copy of these decisions.

        ``lane_of`` maps flow id -> dataplane lane index in the counter
        rows; ``None`` means lanes follow sorted-flow-id order (the serial
        layout).  The lifecycle controller passes its persistent layout,
        which can differ once departures punch holes.

        Besides the legacy report fields the pass assembles each
        tenant's ``telemetry.WindowMetrics`` — the measurement layer the
        control policies consume.  Metrics are derived from the same
        counter deltas with the same float64 ops, so serial and fleet
        paths produce identical digests; latency-SLO violations exist
        only in the metrics (``_slo_ok`` still always passes them),
        keeping the legacy violated/reconfigured lists bit-stable."""
        measured, violated, reconfigured, path_changes = {}, [], [], []
        metrics: dict[int, telemetry.WindowMetrics] = {}
        lat_row = telemetry.mean_latency_s(cur, prev, self.clock_hz)
        adm_row = telemetry.admitted_gbps(cur, prev, window_s)
        for i, fid in enumerate(sorted(self.table)):
            lane = i if lane_of is None else lane_of[fid]
            st = self.table[fid]
            st.measured = float(measured_row[lane])
            measured[fid] = st.measured
            util = telemetry.flow_axis_util(
                st.spec, self.accel_specs[st.spec.accel_id], self.link,
                float(adm_row[lane]))
            m = telemetry.flow_metrics(st.spec, lane, st.measured,
                                       float(lat_row[lane]), st.streak,
                                       util, self.slo_tol)
            st.streak = m.streak
            metrics[fid] = m
            if not self._slo_ok(st):
                st.violations += 1
                violated.append(fid)
                old_path = int(st.spec.path)
                changed = self._re_adjust_pattern(st, cur, prev, window_s,
                                                  lane_of)
                if changed:
                    reconfigured.append(fid)
                    if changed == "path":
                        path_changes.append(
                            (fid, old_path, int(st.spec.path)))
        return WindowReport(t_end_s, measured, violated, reconfigured,
                            path_changes, metrics)

    def _slo_ok(self, st: FlowStatus) -> bool:
        """SLOViolationChecker (lines 11-13)."""
        slo = st.spec.slo
        if slo.kind == SLOKind.LATENCY:
            return True  # checked from completion records by callers
        return st.measured >= slo.target * (1 - self.slo_tol)

    def _re_adjust_pattern(self, st: FlowStatus, cur, prev, window_s: float,
                           lane_of: dict[int, int] | None = None):
        """ReAdjustPattern (lines 17-21)."""
        changed = None
        new_path = self._path_selection(st, cur, prev, window_s, lane_of)
        if new_path is not None:
            st.spec = dataclasses.replace(st.spec, path=new_path)
            # a path change re-keys this flow's would-be contexts, so any
            # ScoreCache margins for this server are stale now
            self._version += 1
            changed = "path"
        # ReshapeDecision: widen pacing headroom toward the observed deficit
        target = (st.spec.slo.target if st.spec.slo.kind != SLOKind.LATENCY
                  else None)
        if target:
            deficit = target / max(st.measured, 1e-9)
            st.headroom = float(np.clip(st.headroom * min(deficit, 1.25),
                                        1.0, 2.0))
            decision = reshape_decision(self.accel_specs[st.spec.accel_id],
                                        st.spec.slo, st.spec.pattern.msg_bytes,
                                        clock_hz=self.clock_hz,
                                        headroom=st.headroom)
            if decision.params != st.params:
                st.params = decision.params   # register write next window
                st.reconfigs += 1
                changed = changed or "params"
        return changed

    def _path_selection(self, st: FlowStatus, cur, prev, window_s: float,
                        lane_of: dict[int, int] | None = None) -> Path | None:
        """PathSelection (line 18): move to a less-loaded path if the current
        ingress direction is saturated and an alternative exists."""
        alts = self.alt_paths.get(st.spec.accel_id, [])
        if not alts:
            return None
        util = self._direction_util(cur, prev, window_s, lane_of)
        cur_dir = PATH_INGRESS_DIR[st.spec.path]
        if cur_dir == 2 or util[cur_dir] < 0.9:
            return None
        for p in alts:
            d = PATH_INGRESS_DIR[p]
            if p != st.spec.path and (d == 2 or util[d] < 0.7):
                return p
        return None

    def _direction_util(self, cur, prev, window_s: float,
                        lane_of: dict[int, int] | None = None) -> np.ndarray:
        h2d_bps = self.link.h2d_gbps * self.link.efficiency * 1e9 / 8
        d2h_bps = self.link.d2h_gbps * self.link.efficiency * 1e9 / 8
        by_dir = np.zeros(3)
        for i, fid in enumerate(sorted(self.table)):
            lane = i if lane_of is None else lane_of[fid]
            st = self.table[fid]
            b = (cur["c_adm_bytes"][lane]
                 - prev["c_adm_bytes"][lane]) / window_s
            d = PATH_INGRESS_DIR[st.spec.path]
            by_dir[d] += b
        return np.array([by_dir[0] / h2d_bps, by_dir[1] / d2h_bps, 0.0])


# ---------------------------------------------------------------------------
# Fleet-scale managed execution: B client servers, one compiled program
# ---------------------------------------------------------------------------

# The measurement layer lives in ``repro.core.telemetry`` now; these
# module-level names remain as import-compatible aliases (the fleet MMIO
# poll keys and the shared counter-delta helpers).
_FLEET_POLL_KEYS = telemetry.FLEET_POLL_KEYS
_fleet_counters = telemetry.fleet_counters
_measured_rates = telemetry.measured_rates


def run_managed_batch(runtimes: Sequence[ArcusRuntime], *,
                      total_ticks: int, window_ticks: int,
                      tick_cycles: int = 8,
                      seeds: Sequence[int] | None = None,
                      arrivals: Sequence[tuple[np.ndarray, np.ndarray]]
                      | None = None,
                      load_ref_gbps: Sequence[dict[int, float] | None]
                      | dict[int, float] | None = None,
                      sim_kwargs: dict[str, Any] | None = None,
                      _force_rebuild: bool = False):
    """Deprecated shim — use ``FleetController(runtimes).run(...)``.

    Runs B client servers' managed dataplanes as ONE compiled program via
    the lifecycle controller's window loop (static tenant set: no churn
    events).  Counters, WindowReports and post-run control state are
    bitwise-equal to B serial ``run_managed(seed=seeds[b], ...)`` calls —
    exactly the contract this entry point always had; the controller's
    event-free path IS this code path now."""
    warnings.warn(
        "runtime.run_managed_batch is deprecated; use "
        "repro.core.controller.FleetController(runtimes).run(...)",
        DeprecationWarning, stacklevel=2)
    from repro.core.controller import FleetController
    return FleetController(runtimes).run(
        total_ticks=total_ticks, window_ticks=window_ticks,
        tick_cycles=tick_cycles, seeds=seeds, arrivals=arrivals,
        load_ref_gbps=load_ref_gbps, sim_kwargs=sim_kwargs,
        _force_rebuild=_force_rebuild)


def register_fleet(runtimes: Sequence[ArcusRuntime],
                   fleet_specs: Sequence[Sequence[FlowSpec]]
                   ) -> list[list[bool]]:
    """Deprecated shim — use ``FleetController(runtimes).admit_fleet``.

    Registers per-server FlowSpec lists across a fleet, batching each
    admission round's CapacityPlanning profiling into one compiled engine
    call; accept/reject decisions are identical to serial registration."""
    warnings.warn(
        "runtime.register_fleet is deprecated; use "
        "repro.core.controller.FleetController(runtimes).admit_fleet(...)",
        DeprecationWarning, stacklevel=2)
    from repro.core.controller import FleetController
    return FleetController(runtimes).admit_fleet(fleet_specs)


# ---------------------------------------------------------------------------
# Fleet admission placement: one fleet making one admission decision
# ---------------------------------------------------------------------------


def _compatible_accels(rt: ArcusRuntime, spec: FlowSpec,
                       accel_name: str | None) -> list[int]:
    """Accelerator indices on ``rt`` the spec may land on: every
    complement member with the required accelerator name when one is
    given, else the spec's own positional ``accel_id`` (the per-server
    interpretation ``register_fleet`` uses)."""
    if accel_name is None:
        return ([spec.accel_id]
                if 0 <= spec.accel_id < len(rt.accel_specs) else [])
    return [a for a, s in enumerate(rt.accel_specs) if s.name == accel_name]


def place_fleet(runtimes: Sequence[ArcusRuntime],
                specs: Sequence[FlowSpec], *,
                policy: placement.PlacementPolicy | None = None,
                pinned: Sequence[int | None] | None = None,
                accel_names: Sequence[str | None] | None = None,
                score_cache: "placement.ScoreCache | None" = None
                ) -> list[placement.Placement]:
    """Deprecated shim — use ``FleetController(runtimes).place(...)``.

    Fleet-level admission placement: one admission round per tenant, the
    round's whole cross-server candidate set profiled through ONE batched
    ``profile_contexts_multi`` engine call, the winner registered via the
    ordinary per-server path.  Pinned first-fit reproduces
    ``register_fleet`` decisions exactly (the parity contract).  The
    controller threads a ``placement.ScoreCache`` through the rounds, so
    servers untouched since the previous round reuse their scored margins
    instead of being re-scored from scratch; pass ``score_cache`` to
    share one across calls."""
    warnings.warn(
        "runtime.place_fleet is deprecated; use "
        "repro.core.controller.FleetController(runtimes).place(...)",
        DeprecationWarning, stacklevel=2)
    from repro.core.controller import FleetController
    return FleetController(runtimes,
                           policy=policy or placement.FirstFit()).place(
        specs, pinned=pinned, accel_names=accel_names,
        score_cache=score_cache)
