"""Arcus control-plane runtime — Algorithm 1 (Sec. 4.3).

Runs on every client server; periodically:
  * reads per-flow hardware counters (SLOViolationChecker),
  * re-adjusts shaping (ReAdjustPattern = PathSelection + ReshapeDecision,
    committed to the parameter registers without stopping the dataplane),
  * admits/rejects new registrations (AdmissionControl + CapacityPlanning
    over the ProfileTable and PerFlowStatusTable).

The dataplane is the jitted simulator (`repro.core.sim`); register writes
are the carry's TBState parameter fields — the MMIO analogue.

Fleet scale: ``run_managed_batch`` drives B client servers' managed
dataplanes as ONE compiled program — per-server FlowSets (ragged flow
counts), accelerator complements (ragged accel counts), SLO vectors and
TBState registers stack along a fleet axis through
``engine.run_window_batch``; between engine windows the Algorithm 1
measurement/violation pass runs fleet-vectorized over ``[B, n_max]``
counter arrays.  ``register_fleet`` batches each admission round's
CapacityPlanning profiling the same way.  Counters and WindowReports are
bitwise-equal to B serial ``run_managed`` calls.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, placement, sim
from repro.core import token_bucket as tb
from repro.core.accelerator import AccelTable, AcceleratorSpec
from repro.core.flow import (PATH_INGRESS_DIR, FlowSet, FlowSpec, Path,
                             SLOKind)
from repro.core.interconnect import ARB_RR, LinkSpec
from repro.core.profiler import (ProfileTable, canonical_order,
                                 profile_contexts_multi)
from repro.core.shaper import reshape_decision
from repro.core.sim import (SHAPING_HW, SimConfig, gen_arrivals, simulate,
                            stack_arrivals)


@dataclasses.dataclass
class FlowStatus:
    """One PerFlowStatusTable entry (Sec. 4.3 "Capacity planning")."""

    spec: FlowSpec                    # VM id, path id, accelerator id, SLO
    params: tb.TBParams               # mechanism parameters configured
    headroom: float = 1.0             # control-knob: pacing over-provision
    measured: float = float("nan")    # current SLO status (hw counters)
    violations: int = 0
    reconfigs: int = 0
    accepted: bool = True


@dataclasses.dataclass
class WindowReport:
    t_end_s: float
    measured: dict[int, float]
    violated: list[int]
    reconfigured: list[int]
    path_changes: list[tuple[int, int, int]]


class ArcusRuntime:
    """SLO manager for one client server (Algorithm 1)."""

    def __init__(self, accels: list[AcceleratorSpec],
                 link: LinkSpec | None = None,
                 profile_table: ProfileTable | None = None,
                 *, clock_hz: float = 250e6, slo_tol: float = 0.02,
                 alt_paths: dict[int, list[Path]] | None = None):
        self.accel_specs = accels
        self.link = link or LinkSpec()
        self.profile = profile_table or ProfileTable(self.link)
        self.clock_hz = clock_hz
        self.slo_tol = slo_tol
        self.alt_paths = alt_paths or {}
        self.table: dict[int, FlowStatus] = {}   # PerFlowStatusTable
        self._prev_counters: dict[str, np.ndarray] | None = None

    # ------------------------------------------------------------------
    # Registration path (Algorithm 1 lines 7-10)
    # ------------------------------------------------------------------
    def register(self, spec: FlowSpec) -> bool:
        if not self._admission_control(spec):
            return False                       # Reject registration (line 9)
        decision = reshape_decision(self.accel_specs[spec.accel_id],
                                    spec.slo, spec.pattern.msg_bytes,
                                    clock_hz=self.clock_hz)
        self.table[spec.flow_id] = FlowStatus(spec=spec,
                                              params=decision.params)
        return True

    def _admission_context(self, spec: FlowSpec
                           ) -> tuple[AcceleratorSpec, list[FlowSpec],
                                      list[tuple[Path, int, float]]]:
        """The would-be CapacityPlanning context if ``spec`` registered:
        (accelerator, peer specs incl. the candidate, profiler context).
        Single source of truth — ``register_fleet`` pre-profiles exactly
        this context, so its cache warming always matches admission."""
        accel = self.accel_specs[spec.accel_id]
        peers = [s.spec for s in self.table.values()
                 if s.spec.accel_id == spec.accel_id] + [spec]
        ctx = [(s.path, s.pattern.msg_bytes, s.pattern.load) for s in peers]
        return accel, peers, ctx

    def _admission_check(self, spec: FlowSpec, _context=None):
        """CapacityPlanning(CHECK) with its evidence: (SLO-Friendly?,
        CapacityEntry, canonical-order SLO vector, slo_margin).
        ``place_fleet`` scores candidates with exactly this tuple — and
        passes back the (accel, peers, ctx) triple it already built for
        profiling — so a feasible candidate is by construction one
        ``register`` will accept."""
        accel, peers, ctx = (_context if _context is not None
                             else self._admission_context(spec))
        entry = self.profile.capacity(accel, ctx)
        # per-flow SLO vector in the entry's canonical context order
        slo_gbps = [self._slo_gbps(peers[i]) for i in canonical_order(ctx)]
        margin = entry.slo_margin(slo_gbps)
        # slo_tag is defined as slo_margin >= 0 — one decision, one copy
        return margin >= 0, entry, slo_gbps, margin

    def _admission_control(self, spec: FlowSpec) -> bool:
        """CapacityPlanning(CHECK): the profiled capacity of the would-be
        context must cover every flow's SLO — in aggregate, and per flow
        (a small-message flow cannot be promised more than contention lets
        one flow reach, see ``CapacityEntry.slo_tag``)."""
        return self._admission_check(spec)[0]

    def _slo_gbps(self, spec: FlowSpec) -> float:
        if spec.slo.kind == SLOKind.GBPS:
            return spec.slo.target
        if spec.slo.kind == SLOKind.IOPS:
            return spec.slo.target * spec.pattern.msg_bytes * 8 / 1e9
        return 0.0  # latency SLOs are enforced by shaping others, not pacing

    # ------------------------------------------------------------------
    # Managed execution: dataplane windows + periodic Algorithm 1 pass
    # ------------------------------------------------------------------
    def run_managed(self, *, total_ticks: int, window_ticks: int,
                    tick_cycles: int = 8, seed: int = 0,
                    arrivals: tuple[np.ndarray, np.ndarray] | None = None,
                    load_ref_gbps: dict[int, float] | None = None,
                    sim_kwargs: dict[str, Any] | None = None):
        """Run the dataplane with periodic SLO management.

        Every window runs the same compiled engine: the static signature
        (SimConfig + shapes) is identical across windows, so windows 1..W-1
        are pure cache hits — register writes, path changes and the rolling
        carry are all traced arguments.  The carry is donated to the engine
        each window (device buffers are reused in place, never copied back
        to the host between windows).

        A trailing partial window (``total_ticks % window_ticks != 0``) runs
        as one final short window — a second engine-cache entry, not a
        silently dropped tail.

        Returns (SimResult of the last window — containing the full
        completion history ring — and the list of WindowReports)."""
        flows = self._flowset()
        atab = AccelTable.build(self.accel_specs, self.clock_hz)
        # the dataplane runs on the runtime's clock: arrival rates, link
        # bandwidth, window seconds and report timestamps all derive from
        # the same SimConfig clock (an explicit sim_kwargs clock still wins)
        sim_kw = dict(sim_kwargs or {})
        sim_kw.setdefault("clock_hz", self.clock_hz)
        cfg = SimConfig(n_ticks=window_ticks, tick_cycles=tick_cycles,
                        shaping=SHAPING_HW, arbiter=ARB_RR, **sim_kw)
        full_cfg = dataclasses.replace(cfg, n_ticks=total_ticks)
        if arrivals is None:
            arrivals = gen_arrivals(flows, full_cfg, seed=seed,
                                    load_ref_gbps=load_ref_gbps)
        # place the full-horizon trace on device once; per-window calls
        # then pass the same committed buffers (no host->device copies)
        arr_t, arr_sz = (jnp.asarray(a) for a in arrivals)
        carry = None
        reports: list[WindowReport] = []
        result = None
        self._prev_counters = None
        n_full, rem = divmod(total_ticks, window_ticks)
        windows = [(w * window_ticks, cfg) for w in range(n_full)]
        if rem:
            windows.append((n_full * window_ticks,
                            dataclasses.replace(cfg, n_ticks=rem)))
        for t0, wcfg in windows:
            tbs = tb.pack([self.table[f].params for f in sorted(self.table)])
            result, carry = simulate(
                flows, atab, self.link, wcfg, tbs, arr_t, arr_sz,
                t0_ticks=t0, carry=carry, return_carry=True)
            reports.append(self._algorithm1_pass(result, wcfg))
            flows = self._flowset()   # path changes take effect next window
        return result, reports

    def _flowset(self) -> FlowSet:
        return FlowSet.build([self.table[f].spec for f in sorted(self.table)])

    # ------------------------------------------------------------------
    # Algorithm 1 main loop body (lines 3-6)
    # ------------------------------------------------------------------
    def _algorithm1_pass(self, result, cfg: SimConfig) -> WindowReport:
        window_s = cfg.seconds   # the dataplane clock (== self.clock_hz
                                 # unless sim_kwargs overrode it)
        cur = {k: np.array(v) for k, v in result.counters.items()}
        prev = self._prev_counters or {k: np.zeros_like(v)
                                       for k, v in cur.items()}
        self._prev_counters = cur
        kind = np.array([int(self.table[fid].spec.slo.kind)
                         for fid in sorted(self.table)], np.int32)
        measured_row = _measured_rates(cur, prev, kind, window_s)
        return self._window_pass(cur, prev, window_s, result.seconds,
                                 measured_row)

    def _window_pass(self, cur, prev, window_s: float, t_end_s: float,
                     measured_row: np.ndarray) -> WindowReport:
        """Per-flow half of the Algorithm 1 window pass: violation check +
        ReAdjustPattern + report assembly.  The single body shared by the
        serial and fleet paths — the fleet's bitwise-equality contract
        rides on there being exactly one copy of these decisions."""
        measured, violated, reconfigured, path_changes = {}, [], [], []
        for i, fid in enumerate(sorted(self.table)):
            st = self.table[fid]
            st.measured = float(measured_row[i])
            measured[fid] = st.measured
            if not self._slo_ok(st):
                st.violations += 1
                violated.append(fid)
                old_path = int(st.spec.path)
                changed = self._re_adjust_pattern(st, cur, prev, window_s)
                if changed:
                    reconfigured.append(fid)
                    if changed == "path":
                        path_changes.append(
                            (fid, old_path, int(st.spec.path)))
        return WindowReport(t_end_s, measured, violated, reconfigured,
                            path_changes)

    def _slo_ok(self, st: FlowStatus) -> bool:
        """SLOViolationChecker (lines 11-13)."""
        slo = st.spec.slo
        if slo.kind == SLOKind.LATENCY:
            return True  # checked from completion records by callers
        return st.measured >= slo.target * (1 - self.slo_tol)

    def _re_adjust_pattern(self, st: FlowStatus, cur, prev, window_s: float):
        """ReAdjustPattern (lines 17-21)."""
        changed = None
        new_path = self._path_selection(st, cur, prev, window_s)
        if new_path is not None:
            st.spec = dataclasses.replace(st.spec, path=new_path)
            changed = "path"
        # ReshapeDecision: widen pacing headroom toward the observed deficit
        target = (st.spec.slo.target if st.spec.slo.kind != SLOKind.LATENCY
                  else None)
        if target:
            deficit = target / max(st.measured, 1e-9)
            st.headroom = float(np.clip(st.headroom * min(deficit, 1.25),
                                        1.0, 2.0))
            decision = reshape_decision(self.accel_specs[st.spec.accel_id],
                                        st.spec.slo, st.spec.pattern.msg_bytes,
                                        clock_hz=self.clock_hz,
                                        headroom=st.headroom)
            if decision.params != st.params:
                st.params = decision.params   # register write next window
                st.reconfigs += 1
                changed = changed or "params"
        return changed

    def _path_selection(self, st: FlowStatus, cur, prev,
                        window_s: float) -> Path | None:
        """PathSelection (line 18): move to a less-loaded path if the current
        ingress direction is saturated and an alternative exists."""
        alts = self.alt_paths.get(st.spec.accel_id, [])
        if not alts:
            return None
        util = self._direction_util(cur, prev, window_s)
        cur_dir = PATH_INGRESS_DIR[st.spec.path]
        if cur_dir == 2 or util[cur_dir] < 0.9:
            return None
        for p in alts:
            d = PATH_INGRESS_DIR[p]
            if p != st.spec.path and (d == 2 or util[d] < 0.7):
                return p
        return None

    def _direction_util(self, cur, prev, window_s: float) -> np.ndarray:
        h2d_bps = self.link.h2d_gbps * self.link.efficiency * 1e9 / 8
        d2h_bps = self.link.d2h_gbps * self.link.efficiency * 1e9 / 8
        by_dir = np.zeros(3)
        for i, fid in enumerate(sorted(self.table)):
            st = self.table[fid]
            b = (cur["c_adm_bytes"][i] - prev["c_adm_bytes"][i]) / window_s
            d = PATH_INGRESS_DIR[st.spec.path]
            by_dir[d] += b
        return np.array([by_dir[0] / h2d_bps, by_dir[1] / d2h_bps, 0.0])


# ---------------------------------------------------------------------------
# Fleet-scale managed execution: B client servers, one compiled program
# ---------------------------------------------------------------------------

#: per-window counter reads (the fleet MMIO poll) — the completion rings
#: stay on device until the final window, so the control plane's per-window
#: device_get is a few [B, n_max] arrays, not the multi-megabyte history
_FLEET_POLL_KEYS = ("c_adm_msgs", "c_adm_b_lo", "c_adm_b_hi", "c_done_msgs",
                    "c_done_b_lo", "c_done_b_hi", "c_drops", "c_lat_sum")


def _fleet_counters(host: dict) -> dict[str, np.ndarray]:
    """[B, n_max] counter arrays in the exact form serial ``SimResult``
    counters take (hi/lo byte counters recombined into int64)."""
    cur = {k: np.asarray(host[k])
           for k in ("c_adm_msgs", "c_done_msgs", "c_drops", "c_lat_sum")}
    cur["c_adm_bytes"] = sim.combine_byte_counters(host["c_adm_b_hi"],
                                                   host["c_adm_b_lo"])
    cur["c_done_bytes"] = sim.combine_byte_counters(host["c_done_b_hi"],
                                                    host["c_done_b_lo"])
    return cur


def _measured_rates(cur: dict, prev: dict, kind: np.ndarray,
                    window_s: float) -> np.ndarray:
    """SLOViolationChecker measurement (Algorithm 1 lines 11-13),
    vectorized over trailing flow axes: per-flow achieved rate in the
    flow's own SLO unit (IOPS or Gbps of ingress payload).  Elementwise
    float64 — one server's row is bitwise-identical whether computed
    serially ([n]) or as a fleet slab ([B, n_max])."""
    meas_iops = (cur["c_done_msgs"] - prev["c_done_msgs"]) / window_s
    meas_gbps = ((cur["c_done_bytes"] - prev["c_done_bytes"])
                 * 8 / window_s / 1e9)
    return np.where(kind == int(SLOKind.IOPS), meas_iops, meas_gbps)


def _fleet_algorithm1(runtimes: Sequence[ArcusRuntime],
                      flowsets: Sequence[FlowSet], host: dict,
                      prev: dict | None, cfg: SimConfig, t0_ticks: int,
                      reports: list[list[WindowReport]]) -> dict:
    """One fleet-wide Algorithm 1 pass between engine windows.

    Measurement runs vectorized over the whole fleet (one ``[B, n_max]``
    ``_measured_rates`` slab); the per-flow violation/ReAdjustPattern body
    is the exact serial code path (``ArcusRuntime._window_pass``), so
    fleet decisions are the serial decisions by construction."""
    cur = _fleet_counters(host)
    if prev is None:
        prev = {k: np.zeros_like(v) for k, v in cur.items()}
    window_s = cfg.seconds
    t_end_s = (t0_ticks + cfg.n_ticks) * cfg.tick_cycles / cfg.clock_hz
    B, n_max = cur["c_done_msgs"].shape
    kind = np.full((B, n_max), -1, np.int32)
    for b, rt in enumerate(runtimes):
        for i, fid in enumerate(sorted(rt.table)):
            kind[b, i] = int(rt.table[fid].spec.slo.kind)
    measured = _measured_rates(cur, prev, kind, window_s)
    for b, rt in enumerate(runtimes):
        n_b = flowsets[b].n
        cur_b = {k: v[b, :n_b] for k, v in cur.items()}
        prev_b = {k: v[b, :n_b] for k, v in prev.items()}
        reports[b].append(rt._window_pass(cur_b, prev_b, window_s, t_end_s,
                                          measured[b]))
        rt._prev_counters = cur_b
    return cur


def run_managed_batch(runtimes: Sequence[ArcusRuntime], *,
                      total_ticks: int, window_ticks: int,
                      tick_cycles: int = 8,
                      seeds: Sequence[int] | None = None,
                      arrivals: Sequence[tuple[np.ndarray, np.ndarray]]
                      | None = None,
                      load_ref_gbps: Sequence[dict[int, float] | None]
                      | dict[int, float] | None = None,
                      sim_kwargs: dict[str, Any] | None = None,
                      _force_rebuild: bool = False):
    """Run B client servers' managed dataplanes as ONE compiled program.

    The serial ``ArcusRuntime.run_managed`` drives one dataplane per call;
    this lifts the identical window loop across a *fleet*: per-server
    FlowSets (different flow counts allowed), accelerator tables (different
    accelerator counts allowed), arrival traces and TBState registers stack
    along a leading fleet axis into ``engine.run_window_batch``, and every
    window's register writes resume the same donated batched carry.  All
    servers must share ``clock_hz`` and the structural SimConfig (windows,
    queue depths) — that shared signature is exactly what makes the whole
    heterogeneous fleet one compiled engine entry.

    Between windows the Algorithm 1 pass (measurement, violation check,
    token-bucket re-provisioning, path selection) runs fleet-vectorized
    (see ``_fleet_algorithm1``).  A trailing partial window runs as one
    final short window, exactly like the serial path.  Register re-packs
    and FlowSet rebuilds happen per server only after a window that
    reconfigured that server; a window after which NO server changed
    resumes the donated carry without any register rewrite at all.

    Counters, WindowReports and the runtimes' post-run control state are
    bitwise-equal to B serial ``run_managed(seed=seeds[b], ...)`` calls.

    Returns ``(results, reports)``: one last-window ``SimResult`` (with the
    full completion-history ring) and one ``list[WindowReport]`` per
    server."""
    B = len(runtimes)
    if B == 0:
        return [], []
    clock_hz = runtimes[0].clock_hz
    if any(rt.clock_hz != clock_hz for rt in runtimes):
        raise ValueError("fleet servers must share clock_hz")
    if any(not rt.table for rt in runtimes):
        raise ValueError("every fleet server needs at least one "
                         "registered flow")
    seeds_l = list(seeds) if seeds is not None else [0] * B
    refs_l = (list(load_ref_gbps)
              if isinstance(load_ref_gbps, (list, tuple))
              else [load_ref_gbps] * B)
    if not (len(seeds_l) == B and len(refs_l) == B):
        raise ValueError("seeds / load_ref_gbps must have one entry "
                         "per server")
    sim_kw = dict(sim_kwargs or {})
    sim_kw.setdefault("clock_hz", clock_hz)   # see run_managed
    cfg = SimConfig(n_ticks=window_ticks, tick_cycles=tick_cycles,
                    shaping=SHAPING_HW, arbiter=ARB_RR, **sim_kw)
    full_cfg = dataclasses.replace(cfg, n_ticks=total_ticks)
    flowsets = [rt._flowset() for rt in runtimes]
    atabs = [AccelTable.build(rt.accel_specs, rt.clock_hz)
             for rt in runtimes]
    links = [rt.link for rt in runtimes]
    if arrivals is None:
        arrivals = [gen_arrivals(flowsets[b], full_cfg, seed=seeds_l[b],
                                 load_ref_gbps=refs_l[b])
                    for b in range(B)]
    # one host->device upload of the stacked full-horizon traces; windows
    # then pass the same committed buffers
    arr_t, arr_sz = (jnp.asarray(a) for a in stack_arrivals(list(arrivals)))
    n_full, rem = divmod(total_ticks, window_ticks)
    windows = [(w * window_ticks, cfg) for w in range(n_full)]
    if rem:
        windows.append((n_full * window_ticks,
                        dataclasses.replace(cfg, n_ticks=rem)))
    carry = None
    prev = None
    reports: list[list[WindowReport]] = [[] for _ in range(B)]
    for rt in runtimes:
        rt._prev_counters = None
    # per-server re-pack / rebuild only when that server's previous window
    # actually committed a register write or path change; when NO server
    # did, the engine resumes the carry without any register rewrite at
    # all (bitwise no-op either way: unchanged registers rewrite their own
    # values, and refills clamp tokens at bkt_size inside the engine)
    tbss: list = [None] * B
    dirty = [False] * B            # the flowsets built above are fresh
    for t0, wcfg in windows:
        for b, rt in enumerate(runtimes):
            if tbss[b] is None or dirty[b]:
                tbss[b] = tb.pack([rt.table[f].params
                                   for f in sorted(rt.table)])
                if dirty[b]:
                    flowsets[b] = rt._flowset()
        writes = tbss if (carry is None or any(dirty)
                          or _force_rebuild) else None
        carry = engine.run_window_batch(flowsets, atabs, links, wcfg,
                                        writes, arr_t, arr_sz, t0_ticks=t0,
                                        carry=carry)
        host = jax.device_get({k: carry[k] for k in _FLEET_POLL_KEYS})
        prev = _fleet_algorithm1(runtimes, flowsets, host, prev, wcfg, t0,
                                 reports)
        dirty = [_force_rebuild or bool(reports[b][-1].reconfigured
                                        or reports[b][-1].path_changes)
                 for b in range(B)]
    host = jax.device_get({k: carry[k] for k in sim._RESULT_KEYS})
    t0_last, wcfg_last = windows[-1]
    results = []
    for b in range(B):
        el = {k: v[b] for k, v in host.items()}
        for k in sim._PER_FLOW_KEYS:
            el[k] = el[k][:flowsets[b].n]
        results.append(sim._collect_result(el, wcfg_last, t0_last))
    return results, reports


def register_fleet(runtimes: Sequence[ArcusRuntime],
                   fleet_specs: Sequence[Sequence[FlowSpec]]
                   ) -> list[list[bool]]:
    """Register per-server FlowSpec lists across a fleet, batching the
    admission-control profiling.

    Round r considers the r-th spec of every server at once: each server's
    would-be CapacityPlanning context (its accepted peers on the target
    accelerator plus the candidate) is profiled through
    ``profile_contexts_multi`` — one compiled engine call per round instead
    of one serial profiling simulation per (server, flow).  The subsequent
    ``ArcusRuntime.register`` calls then hit the warmed ProfileTable
    caches, so accept/reject decisions are identical to serial
    registration.  Returns per-server accept/reject lists.

    An empty per-server list is valid (that server registers nothing);
    a ``fleet_specs``/``runtimes`` length mismatch is rejected before any
    profiling or registration starts."""
    if len(fleet_specs) != len(runtimes):
        raise ValueError(
            f"fleet_specs must have one spec list per server "
            f"(got {len(fleet_specs)} lists for {len(runtimes)} servers)")
    results: list[list[bool]] = [[] for _ in runtimes]
    rounds = max((len(s) for s in fleet_specs), default=0)
    for r in range(rounds):
        jobs = []
        for b, rt in enumerate(runtimes):
            if r >= len(fleet_specs[b]):
                continue
            accel, _peers, ctx = rt._admission_context(fleet_specs[b][r])
            jobs.append((rt.profile, accel, ctx))
        profile_contexts_multi(jobs)
        for b, rt in enumerate(runtimes):
            if r < len(fleet_specs[b]):
                results[b].append(rt.register(fleet_specs[b][r]))
    return results


# ---------------------------------------------------------------------------
# Fleet admission placement: one fleet making one admission decision
# ---------------------------------------------------------------------------


def _compatible_accels(rt: ArcusRuntime, spec: FlowSpec,
                       accel_name: str | None) -> list[int]:
    """Accelerator indices on ``rt`` the spec may land on: every
    complement member with the required accelerator name when one is
    given, else the spec's own positional ``accel_id`` (the per-server
    interpretation ``register_fleet`` uses)."""
    if accel_name is None:
        return ([spec.accel_id]
                if 0 <= spec.accel_id < len(rt.accel_specs) else [])
    return [a for a, s in enumerate(rt.accel_specs) if s.name == accel_name]


def place_fleet(runtimes: Sequence[ArcusRuntime],
                specs: Sequence[FlowSpec], *,
                policy: placement.PlacementPolicy | None = None,
                pinned: Sequence[int | None] | None = None,
                accel_names: Sequence[str | None] | None = None
                ) -> list[placement.Placement]:
    """Fleet-level admission placement (the CapacityPlanning admission of
    Algorithm 1, shopped across every client server).

    Tenants are placed one admission round each, in order.  A round
    enumerates every compatible (server, accelerator) landing option —
    all servers, or only ``pinned[i]`` when given; the accelerator
    matching ``accel_names[i]`` on each server, or the spec's positional
    ``accel_id`` when no name is given — and profiles ALL their would-be
    Capacity(t, X, N) contexts through ONE
    ``profiler.profile_contexts_multi`` engine call (B servers x
    candidate contexts, ragged flow and accel counts).  The policy then
    picks among the profiled candidates (``placement.FirstFit`` /
    ``BestFit`` / ``SLOAware``); the winner is registered on its server
    via the ordinary ``ArcusRuntime.register`` path (a warmed-cache hit,
    so placement can never admit what per-server admission would
    reject).  A tenant is rejected only when NO server fits.

    Parity contract: with ``policy=FirstFit()`` and every spec pinned to
    its original server this reproduces ``register_fleet``'s
    accept/reject decisions exactly — fleet placement strictly widens
    per-server admission, never changes it.

    Returns one ``placement.Placement`` per input spec."""
    policy = policy or placement.FirstFit()
    B = len(runtimes)
    specs = list(specs)
    pins = list(pinned) if pinned is not None else [None] * len(specs)
    names = (list(accel_names) if accel_names is not None
             else [None] * len(specs))
    if not (len(pins) == len(specs) and len(names) == len(specs)):
        raise ValueError(
            "pinned / accel_names must have one entry per spec")
    if any(p is not None and not 0 <= p < B for p in pins):
        raise ValueError("pinned server index out of range")
    out: list[placement.Placement] = []
    for spec, pin, name in zip(specs, pins, names):
        meta = []
        for b in (range(B) if pin is None else [pin]):
            rt = runtimes[b]
            for a in _compatible_accels(rt, spec, name):
                cand_spec = dataclasses.replace(spec, accel_id=a)
                meta.append((b, a, cand_spec,
                             rt._admission_context(cand_spec)))
        if meta:
            # ONE batched engine call profiles the whole round's
            # cross-server candidate set (cache hits simulate nothing)
            profile_contexts_multi([(runtimes[b].profile, ctx[0], ctx[2])
                                    for b, _a, _s, ctx in meta])
        cands = []
        for b, a, cand_spec, ctx in meta:
            ok, entry, slo, margin = runtimes[b]._admission_check(
                cand_spec, ctx)
            cands.append(placement.Candidate(
                server=b, accel_id=a, spec=cand_spec, entry=entry,
                slo_gbps=tuple(slo), feasible=ok, margin=margin,
                residual=entry.residual_gbps(slo),
                server_key=placement.server_key(runtimes[b])))
        chosen = policy.select(cands)
        if chosen is not None and not chosen.feasible:
            raise ValueError(
                f"policy {policy.name!r} selected an infeasible candidate "
                f"(server {chosen.server}, accel {chosen.accel_id}) — "
                "select() must return a feasible candidate or None")
        accepted = False
        if chosen is not None:
            accepted = runtimes[chosen.server].register(chosen.spec)
            if not accepted:
                # feasibility came from the same cached entry register()
                # re-reads, so a feasible candidate can only bounce if
                # register() drifts from _admission_check
                raise RuntimeError(
                    f"server {chosen.server} rejected a candidate scored "
                    "feasible — register() and _admission_check diverged")
        out.append(placement.Placement(
            spec=spec,
            server=None if chosen is None else chosen.server,
            accel_id=None if chosen is None else chosen.accel_id,
            accepted=accepted,
            n_candidates=len(cands),
            n_feasible=sum(c.feasible for c in cands)))
    return out
