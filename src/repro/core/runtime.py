"""Arcus control-plane runtime — Algorithm 1 (Sec. 4.3).

Runs on every client server; periodically:
  * reads per-flow hardware counters (SLOViolationChecker),
  * re-adjusts shaping (ReAdjustPattern = PathSelection + ReshapeDecision,
    committed to the parameter registers without stopping the dataplane),
  * admits/rejects new registrations (AdmissionControl + CapacityPlanning
    over the ProfileTable and PerFlowStatusTable).

The dataplane is the jitted simulator (`repro.core.sim`); register writes
are the carry's TBState parameter fields — the MMIO analogue.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core import token_bucket as tb
from repro.core.accelerator import AccelTable, AcceleratorSpec
from repro.core.flow import (PATH_EGRESS_DIR, PATH_INGRESS_DIR, SLO, FlowSet,
                             FlowSpec, Path, SLOKind)
from repro.core.interconnect import ARB_RR, LinkSpec
from repro.core.profiler import ProfileTable, canonical_order
from repro.core.shaper import reshape_decision
from repro.core.sim import SHAPING_HW, SimConfig, gen_arrivals, simulate


@dataclasses.dataclass
class FlowStatus:
    """One PerFlowStatusTable entry (Sec. 4.3 "Capacity planning")."""

    spec: FlowSpec                    # VM id, path id, accelerator id, SLO
    params: tb.TBParams               # mechanism parameters configured
    headroom: float = 1.0             # control-knob: pacing over-provision
    measured: float = float("nan")    # current SLO status (hw counters)
    violations: int = 0
    reconfigs: int = 0
    accepted: bool = True


@dataclasses.dataclass
class WindowReport:
    t_end_s: float
    measured: dict[int, float]
    violated: list[int]
    reconfigured: list[int]
    path_changes: list[tuple[int, int, int]]


class ArcusRuntime:
    """SLO manager for one client server (Algorithm 1)."""

    def __init__(self, accels: list[AcceleratorSpec],
                 link: LinkSpec | None = None,
                 profile_table: ProfileTable | None = None,
                 *, clock_hz: float = 250e6, slo_tol: float = 0.02,
                 alt_paths: dict[int, list[Path]] | None = None):
        self.accel_specs = accels
        self.link = link or LinkSpec()
        self.profile = profile_table or ProfileTable(self.link)
        self.clock_hz = clock_hz
        self.slo_tol = slo_tol
        self.alt_paths = alt_paths or {}
        self.table: dict[int, FlowStatus] = {}   # PerFlowStatusTable
        self._prev_counters: dict[str, np.ndarray] | None = None

    # ------------------------------------------------------------------
    # Registration path (Algorithm 1 lines 7-10)
    # ------------------------------------------------------------------
    def register(self, spec: FlowSpec) -> bool:
        if not self._admission_control(spec):
            return False                       # Reject registration (line 9)
        decision = reshape_decision(self.accel_specs[spec.accel_id],
                                    spec.slo, spec.pattern.msg_bytes,
                                    clock_hz=self.clock_hz)
        self.table[spec.flow_id] = FlowStatus(spec=spec,
                                              params=decision.params)
        return True

    def _admission_control(self, spec: FlowSpec) -> bool:
        """CapacityPlanning(CHECK): the profiled capacity of the would-be
        context must cover every flow's SLO — in aggregate, and per flow
        (a small-message flow cannot be promised more than contention lets
        one flow reach, see ``CapacityEntry.slo_tag``)."""
        accel = self.accel_specs[spec.accel_id]
        peers = [s.spec for s in self.table.values()
                 if s.spec.accel_id == spec.accel_id] + [spec]
        ctx = [(s.path, s.pattern.msg_bytes, s.pattern.load) for s in peers]
        entry = self.profile.capacity(accel, ctx)
        # per-flow SLO vector in the entry's canonical context order
        return entry.slo_tag([self._slo_gbps(peers[i])
                              for i in canonical_order(ctx)])

    def _slo_gbps(self, spec: FlowSpec) -> float:
        if spec.slo.kind == SLOKind.GBPS:
            return spec.slo.target
        if spec.slo.kind == SLOKind.IOPS:
            return spec.slo.target * spec.pattern.msg_bytes * 8 / 1e9
        return 0.0  # latency SLOs are enforced by shaping others, not pacing

    # ------------------------------------------------------------------
    # Managed execution: dataplane windows + periodic Algorithm 1 pass
    # ------------------------------------------------------------------
    def run_managed(self, *, total_ticks: int, window_ticks: int,
                    tick_cycles: int = 8, seed: int = 0,
                    arrivals: tuple[np.ndarray, np.ndarray] | None = None,
                    load_ref_gbps: dict[int, float] | None = None,
                    sim_kwargs: dict[str, Any] | None = None):
        """Run the dataplane with periodic SLO management.

        Every window runs the same compiled engine: the static signature
        (SimConfig + shapes) is identical across windows, so windows 1..W-1
        are pure cache hits — register writes, path changes and the rolling
        carry are all traced arguments.  The carry is donated to the engine
        each window (device buffers are reused in place, never copied back
        to the host between windows).

        A trailing partial window (``total_ticks % window_ticks != 0``) runs
        as one final short window — a second engine-cache entry, not a
        silently dropped tail.

        Returns (SimResult of the last window — containing the full
        completion history ring — and the list of WindowReports)."""
        flows = self._flowset()
        atab = AccelTable.build(self.accel_specs, self.clock_hz)
        cfg = SimConfig(n_ticks=window_ticks, tick_cycles=tick_cycles,
                        shaping=SHAPING_HW, arbiter=ARB_RR,
                        **(sim_kwargs or {}))
        full_cfg = dataclasses.replace(cfg, n_ticks=total_ticks)
        if arrivals is None:
            arrivals = gen_arrivals(flows, full_cfg, seed=seed,
                                    load_ref_gbps=load_ref_gbps)
        # place the full-horizon trace on device once; per-window calls
        # then pass the same committed buffers (no host->device copies)
        arr_t, arr_sz = (jnp.asarray(a) for a in arrivals)
        carry = None
        reports: list[WindowReport] = []
        result = None
        self._prev_counters = None
        n_full, rem = divmod(total_ticks, window_ticks)
        windows = [(w * window_ticks, cfg) for w in range(n_full)]
        if rem:
            windows.append((n_full * window_ticks,
                            dataclasses.replace(cfg, n_ticks=rem)))
        for t0, wcfg in windows:
            tbs = tb.pack([self.table[f].params for f in sorted(self.table)])
            result, carry = simulate(
                flows, atab, self.link, wcfg, tbs, arr_t, arr_sz,
                t0_ticks=t0, carry=carry, return_carry=True)
            reports.append(self._algorithm1_pass(result, wcfg))
            flows = self._flowset()   # path changes take effect next window
        return result, reports

    def _flowset(self) -> FlowSet:
        return FlowSet.build([self.table[f].spec for f in sorted(self.table)])

    # ------------------------------------------------------------------
    # Algorithm 1 main loop body (lines 3-6)
    # ------------------------------------------------------------------
    def _algorithm1_pass(self, result, cfg: SimConfig) -> WindowReport:
        window_s = cfg.n_ticks * cfg.tick_cycles / self.clock_hz
        cur = {k: np.array(v) for k, v in result.counters.items()}
        prev = self._prev_counters or {k: np.zeros_like(v)
                                       for k, v in cur.items()}
        self._prev_counters = cur
        measured, violated, reconfigured, path_changes = {}, [], [], []
        for i, fid in enumerate(sorted(self.table)):
            st = self.table[fid]
            if st.spec.slo.kind == SLOKind.IOPS:
                meas = (cur["c_done_msgs"][i] - prev["c_done_msgs"][i]) / window_s
            else:
                meas = ((cur["c_done_bytes"][i] - prev["c_done_bytes"][i])
                        * 8 / window_s / 1e9)
            st.measured = float(meas)
            measured[fid] = st.measured
            if not self._slo_ok(st):
                st.violations += 1
                violated.append(fid)
                changed = self._re_adjust_pattern(st, cur, prev, window_s)
                if changed:
                    reconfigured.append(fid)
                    if changed == "path":
                        path_changes.append(
                            (fid, int(st.spec.path), int(st.spec.path)))
        return WindowReport(result.seconds, measured, violated,
                            reconfigured, path_changes)

    def _slo_ok(self, st: FlowStatus) -> bool:
        """SLOViolationChecker (lines 11-13)."""
        slo = st.spec.slo
        if slo.kind == SLOKind.LATENCY:
            return True  # checked from completion records by callers
        return st.measured >= slo.target * (1 - self.slo_tol)

    def _re_adjust_pattern(self, st: FlowStatus, cur, prev, window_s: float):
        """ReAdjustPattern (lines 17-21)."""
        changed = None
        new_path = self._path_selection(st, cur, prev, window_s)
        if new_path is not None:
            st.spec = dataclasses.replace(st.spec, path=new_path)
            changed = "path"
        # ReshapeDecision: widen pacing headroom toward the observed deficit
        target = (st.spec.slo.target if st.spec.slo.kind != SLOKind.LATENCY
                  else None)
        if target:
            deficit = target / max(st.measured, 1e-9)
            st.headroom = float(np.clip(st.headroom * min(deficit, 1.25),
                                        1.0, 2.0))
            decision = reshape_decision(self.accel_specs[st.spec.accel_id],
                                        st.spec.slo, st.spec.pattern.msg_bytes,
                                        clock_hz=self.clock_hz,
                                        headroom=st.headroom)
            if decision.params != st.params:
                st.params = decision.params   # register write next window
                st.reconfigs += 1
                changed = changed or "params"
        return changed

    def _path_selection(self, st: FlowStatus, cur, prev,
                        window_s: float) -> Path | None:
        """PathSelection (line 18): move to a less-loaded path if the current
        ingress direction is saturated and an alternative exists."""
        alts = self.alt_paths.get(st.spec.accel_id, [])
        if not alts:
            return None
        util = self._direction_util(cur, prev, window_s)
        cur_dir = PATH_INGRESS_DIR[st.spec.path]
        if cur_dir == 2 or util[cur_dir] < 0.9:
            return None
        for p in alts:
            d = PATH_INGRESS_DIR[p]
            if p != st.spec.path and (d == 2 or util[d] < 0.7):
                return p
        return None

    def _direction_util(self, cur, prev, window_s: float) -> np.ndarray:
        h2d_bps = self.link.h2d_gbps * self.link.efficiency * 1e9 / 8
        d2h_bps = self.link.d2h_gbps * self.link.efficiency * 1e9 / 8
        by_dir = np.zeros(3)
        for i, fid in enumerate(sorted(self.table)):
            st = self.table[fid]
            b = (cur["c_adm_bytes"][i] - prev["c_adm_bytes"][i]) / window_s
            d = PATH_INGRESS_DIR[st.spec.path]
            by_dir[d] += b
        return np.array([by_dir[0] / h2d_bps, by_dir[1] / d2h_bps, 0.0])
