"""Heterogeneous accelerator models (Arcus §2.2 "non-linearity").

Each accelerator has (1) a non-linear compute-throughput vs. input-message-
size curve (Fig. 7(a): logarithmic / exponential / ad-hoc) and (2) an
egress/ingress bandwidth ratio R = Eb/Ib in {=1, >1, <1, fixed-egress}
(AES, decompression, compression, SHA-3-512 respectively).

The simulator consumes these as pure arrays: for the jitted dataplane we
pre-tabulate service time and egress size as functions of message size on a
log2 grid and interpolate inside the scan.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

CURVE_LINEAR = "linear"
CURVE_LOG = "log"
CURVE_EXP = "exp"
CURVE_ADHOC = "adhoc"

R_EQUAL = "equal"        # R = 1        (e.g. AES-256-CTR)
R_EXPAND = "expand"      # R > 1        (decompression)
R_SHRINK = "shrink"      # R < 1        (compression)
R_FIXED = "fixed"        # Eb fixed     (SHA-3-512: 64B digest)


@dataclasses.dataclass(frozen=True)
class AcceleratorSpec:
    name: str
    peak_gbps: float               # max compute throughput at ideal msg size
    curve: str = CURVE_EXP
    curve_ref_bytes: float = 1024.0  # knee of the curve
    r_kind: str = R_EQUAL
    r_value: float = 1.0           # egress = r_value * ingress (expand/shrink)
    fixed_egress_bytes: int = 64   # for R_FIXED
    overhead_ns: float = 120.0     # fixed per-message pipeline overhead
    parallelism: int = 1           # independent lanes
    # optional explicit service-time anchors ((bytes, us), ...): overrides
    # the curve; log-space interpolated.  Used for devices whose cost is
    # operation- rather than bandwidth-dominated (e.g. SSD reads vs writes).
    service_us_at: tuple = ()
    # per-resource demand overrides: ((resource_name, per_ingress_byte,
    # per_egress_byte), ...).  Axes without an override charge 1.0 per byte
    # in each direction — combined with the device's egress curve that
    # already makes R_EXPAND devices egress/memory-heavy (2.5 egress bytes
    # per ingress byte on 'decompress') and fixed-egress SHA-style devices
    # ingress-heavy (64B digests).  Explicit overrides model devices whose
    # shared-resource footprint is decoupled from their message bytes
    # (e.g. a compute-bound systolic engine barely touching memory bw).
    res_demand: tuple = ()

    # ------------------------------------------------------------------
    def resource_demand(self, resource_name: str) -> tuple[float, float]:
        """(per-ingress-byte, per-egress-byte) demand coefficients of this
        device on the named resource axis (see ``res_demand``)."""
        for nm, ic, ec in self.res_demand:
            if nm == resource_name:
                return float(ic), float(ec)
        return 1.0, 1.0

    # ------------------------------------------------------------------
    def throughput_gbps(self, msg_bytes: np.ndarray) -> np.ndarray:
        """Compute throughput sustained when fed messages of this size."""
        m = np.asarray(msg_bytes, np.float64)
        ref = self.curve_ref_bytes
        if self.curve == CURVE_LINEAR:
            f = np.ones_like(m)
        elif self.curve == CURVE_LOG:
            # saturates slowly; small messages very inefficient
            f = np.log2(1.0 + m / ref) / np.log2(1.0 + 65536.0 / ref)
            f = np.minimum(f, 1.0)
        elif self.curve == CURVE_EXP:
            f = 1.0 - np.exp(-m / ref)
        elif self.curve == CURVE_ADHOC:
            # uniquely ad-hoc (Fig. 7a): efficiency dips when messages are
            # not multiples of the internal block (e.g. 4KB) + slow ramp.
            base = 1.0 - np.exp(-m / ref)
            block = 4096.0
            frag = np.where(m >= block, (m % block) / block, 0.0)
            f = base * (1.0 - 0.35 * frag)
        else:
            raise ValueError(self.curve)
        return self.peak_gbps * np.maximum(f, 1e-3)

    def service_time_s(self, msg_bytes: np.ndarray) -> np.ndarray:
        """Time one lane takes to process a message of the given size."""
        m = np.asarray(msg_bytes, np.float64)
        if self.service_us_at:
            xs = np.log2([b for b, _ in self.service_us_at])
            ys = np.log2([u * 1e-6 for _, u in self.service_us_at])
            return np.exp2(np.interp(np.log2(np.maximum(m, 1.0)), xs, ys))
        bps = self.throughput_gbps(m) * 1e9 / 8.0
        return m / bps + self.overhead_ns * 1e-9

    def effective_gbps(self, msg_bytes) -> float:
        """Sustained single-lane throughput incl. per-message overhead."""
        m = float(np.asarray(msg_bytes, np.float64))
        return m * 8 / float(self.service_time_s(m)) / 1e9 * self.parallelism

    def egress_bytes(self, msg_bytes: np.ndarray) -> np.ndarray:
        m = np.asarray(msg_bytes, np.float64)
        if self.r_kind == R_FIXED:
            return np.full_like(m, float(self.fixed_egress_bytes))
        return m * self.r_value


# ---------------------------------------------------------------------------
# Catalogue used across the paper's experiments
# ---------------------------------------------------------------------------

CATALOG = {
    # The 32 Gbps IPSec accelerator of Sec 3.1 (full load at MTU-size msgs;
    # tiny messages collapse throughput, Fig. 3b).
    "ipsec32": AcceleratorSpec("ipsec32", peak_gbps=32.0, curve=CURVE_EXP,
                               curve_ref_bytes=200.0, r_kind=R_EQUAL,
                               overhead_ns=10.0),
    # Synthetic 50 Gbps accelerator of CaseP studies (linear, no interface
    # effects — isolates communication contention).
    "synthetic50": AcceleratorSpec("synthetic50", peak_gbps=50.0,
                                   curve=CURVE_LINEAR, r_kind=R_EQUAL,
                                   overhead_ns=40.0),
    "aes256": AcceleratorSpec("aes256", peak_gbps=40.0, curve=CURVE_EXP,
                              curve_ref_bytes=512.0, r_kind=R_EQUAL),
    "sha3_512": AcceleratorSpec("sha3_512", peak_gbps=24.0, curve=CURVE_LOG,
                                curve_ref_bytes=2048.0, r_kind=R_FIXED,
                                fixed_egress_bytes=64),
    "compress": AcceleratorSpec("compress", peak_gbps=20.0, curve=CURVE_ADHOC,
                                curve_ref_bytes=4096.0, r_kind=R_SHRINK,
                                r_value=0.4),
    "decompress": AcceleratorSpec("decompress", peak_gbps=20.0,
                                  curve=CURVE_ADHOC, curve_ref_bytes=4096.0,
                                  r_kind=R_EXPAND, r_value=2.5),
    # pipelined packet-rate crypto engines (SmartNIC datapath: good at
    # small messages, unlike the bulk-oriented log/exp engines above)
    "sha1_hmac": AcceleratorSpec("sha1_hmac", peak_gbps=28.0, curve=CURVE_EXP,
                                 curve_ref_bytes=48.0, r_kind=R_FIXED,
                                 fixed_egress_bytes=20, overhead_ns=100.0,
                                 parallelism=2),
    "aes128_cbc": AcceleratorSpec("aes128_cbc", peak_gbps=36.0, curve=CURVE_EXP,
                                  curve_ref_bytes=48.0, r_kind=R_EQUAL,
                                  overhead_ns=100.0, parallelism=2),
    # NVMe-backed storage engine for the FIO / storage experiments: service
    # time dominated by ~100us flash access, hidden by deep queue
    # parallelism (RAID-0 x4 x QD16).
    "nvme_raid0": AcceleratorSpec("nvme_raid0", peak_gbps=26.0,
                                  curve=CURVE_LINEAR, r_kind=R_EQUAL,
                                  overhead_ns=100_000.0, parallelism=64),
    # Checksum accelerator for the RocksDB offload experiment.
    "crc32c": AcceleratorSpec("crc32c", peak_gbps=48.0, curve=CURVE_EXP,
                              curve_ref_bytes=256.0, r_kind=R_FIXED,
                              fixed_egress_bytes=4),
}


# ---------------------------------------------------------------------------
# Tabulation for the jitted dataplane
# ---------------------------------------------------------------------------

#: log2-spaced grid of message sizes used for in-scan interpolation
GRID_LOG2_MIN, GRID_LOG2_MAX, GRID_N = 5, 20, 31  # 32B ... 1MB


def size_grid() -> np.ndarray:
    return np.logspace(GRID_LOG2_MIN, GRID_LOG2_MAX, GRID_N, base=2.0)


@dataclasses.dataclass
class AccelTable:
    """Pre-tabulated per-accelerator service curves for A accelerators."""

    n: int
    service_cycles: np.ndarray   # [A, GRID_N] float32 — service time in cycles
    egress_bytes: np.ndarray     # [A, GRID_N] float32
    parallelism: np.ndarray      # [A] int32
    names: Sequence[str] = dataclasses.field(default_factory=list)
    # host-side source specs (resource-demand derivation); hand-built or
    # padded tables may carry fewer specs than rows — spec_of() guards.
    specs: Sequence[AcceleratorSpec] = dataclasses.field(default_factory=list)

    def spec_of(self, accel_id: int) -> AcceleratorSpec | None:
        return (self.specs[accel_id]
                if 0 <= accel_id < len(self.specs) else None)

    @staticmethod
    def build(specs: Sequence[AcceleratorSpec], clock_hz: float = 250e6
              ) -> "AccelTable":
        grid = size_grid()
        sc = np.stack([s.service_time_s(grid) * clock_hz for s in specs])
        eg = np.stack([s.egress_bytes(grid) for s in specs])
        return AccelTable(
            n=len(specs),
            service_cycles=sc.astype(np.float32),
            egress_bytes=eg.astype(np.float32),
            parallelism=np.array([s.parallelism for s in specs], np.int32),
            names=[s.name for s in specs],
            specs=list(specs),
        )


def interp_grid(table_row_major, accel_id, msg_bytes):
    """Interpolate a [A, GRID_N] table at (accel_id, msg_bytes) — jnp ok."""
    import jax.numpy as jnp
    m = jnp.maximum(jnp.asarray(msg_bytes, jnp.float32), 1.0)
    x = (jnp.log2(m) - GRID_LOG2_MIN) / (GRID_LOG2_MAX - GRID_LOG2_MIN) * (GRID_N - 1)
    x = jnp.clip(x, 0.0, GRID_N - 1.001)
    i0 = x.astype(jnp.int32)
    frac = x - i0
    row = table_row_major[accel_id]
    v0 = jnp.take_along_axis(row, i0[..., None], axis=-1)[..., 0] if row.ndim > 1 \
        else row[i0]
    v1 = jnp.take_along_axis(row, (i0 + 1)[..., None], axis=-1)[..., 0] if row.ndim > 1 \
        else row[i0 + 1]
    return v0 * (1 - frac) + v1 * frac
