"""Cycle-accurate Arcus dataplane simulator (jitted, jax.lax.scan).

This is the JAX-native stand-in for the paper's FPGA testbed: it executes the
Arcus dataplane protocol (Sec. 4.1) at cycle granularity:

    per-flow queues -> [token-bucket shaper] -> arbiter -> ingress link
        -> heterogeneous accelerator (lanes, non-linear service curve)
        -> egress link -> completion

vectorized over flows, scanned over time (1 tick = `tick_cycles` FPGA cycles
at 250 MHz, matching the paper's prototype clock).  Everything that the
paper's hardware measures (per-flow counters, completion latencies) is
accumulated in the scan carry so the control plane can read it back, exactly
like the paper's MMIO counter reads.

Shaping modes:
  SHAPING_NONE — no traffic shaping (Host_noTS / Bypassed_noTS_panic)
  SHAPING_HW   — Arcus: cycle-accurate token buckets in 'hardware'
  SHAPING_SW   — software shaping (ReFlex/Firecracker-style): the same token
                 buckets, but timer refills and admissions stall whenever the
                 host is descheduled (stall mask), and every message pays a
                 jittered host-processing delay.  (Sec. 4.2: "even
                 high-resolution timers in today's software cannot guarantee
                 such accuracy"; Sec. 5.2: CPU interference.)
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import token_bucket as tb
from repro.core.accelerator import AccelTable, interp_grid
from repro.core.flow import FlowSet
from repro.core.interconnect import (ARB_PRIORITY, ARB_RR, ARB_WFQ, ARB_WRR,
                                     LinkSpec, arbiter_weights)

SHAPING_NONE = 0
SHAPING_HW = 1
SHAPING_SW = 2

INF_I32 = np.int32(2**31 - 1)
_LCG_A = np.int32(1103515245)
_LCG_C = np.int32(12345)


@dataclasses.dataclass(frozen=True)
class SimConfig:
    n_ticks: int
    tick_cycles: int = 8
    clock_hz: float = 250e6
    qlen: int = 256            # per-flow queue slots
    aq_len: int = 256          # per-accelerator queue slots
    aq_byte_cap: int = 1 << 20  # shared accel input buffer (bytes) — large
                                # messages congest it (Sec. 3.1 / Fig. 8)
    eq_len: int = 2048         # per-direction egress queue slots
    comp_cap: int = 1 << 15    # completion record ring capacity
    k_arr: int = 4             # max arrivals drained per flow per tick
    k_grant: int = 4           # max arbiter grants per tick
    k_srv: int = 2             # service starts per accelerator per tick
    k_eg: int = 4              # egress pops per direction per tick
    lmax: int = 16             # max accelerator lanes
    shaping: int = SHAPING_HW
    arbiter: int = ARB_RR
    # software-shaping pathology model
    sw_host_delay_cycles: int = 500      # ~2 us base host processing delay
    sw_jitter_cycles: int = 2500         # up to +10 us heavy-tail jitter

    @property
    def seconds(self) -> float:
        return self.n_ticks * self.tick_cycles / self.clock_hz


# ---------------------------------------------------------------------------
# Arrival-trace generation (host side, numpy)
# ---------------------------------------------------------------------------


def gen_arrivals(flows: FlowSet, cfg: SimConfig, *, seed: int = 0,
                 load_ref_gbps: dict[int, float] | None = None,
                 max_msgs: int = 1 << 18) -> tuple[np.ndarray, np.ndarray]:
    """Pre-generate per-flow arrival traces.

    Returns (times[N, M] int32 cycles, sizes[N, M] int32 bytes), padded with
    INF_I32 / 0 past the end of each flow's trace.
    """
    rng = np.random.default_rng(seed)
    horizon_cycles = cfg.n_ticks * cfg.tick_cycles
    horizon_s = horizon_cycles / cfg.clock_hz
    per_flow_t, per_flow_s = [], []
    for i, spec in enumerate(flows.specs):
        pat = spec.pattern
        ref = (load_ref_gbps or {}).get(i, 32.0)
        rate = pat.rate_msgs_per_sec(ref)
        m = int(min(max_msgs, np.ceil(rate * horizon_s) + 16))
        if pat.process == "cbr":
            gaps = np.full(m, 1.0 / max(rate, 1e-9))
        elif pat.process == "poisson":
            gaps = rng.exponential(1.0 / max(rate, 1e-9), m)
        elif pat.process == "onoff":
            period = pat.burst_len / max(rate, 1e-9)
            on_gap = pat.duty * period / pat.burst_len
            gaps = np.full(m, on_gap)
            # idle gap closes each burst so the average rate stays `rate`
            gaps[pat.burst_len - 1::pat.burst_len] = (1 - pat.duty) * period + on_gap
        else:
            raise ValueError(pat.process)
        t = np.cumsum(gaps) * cfg.clock_hz
        sizes = np.full(m, pat.msg_bytes, np.int64)
        if pat.p2 > 0:
            mask = rng.random(m) < pat.p2
            sizes[mask] = pat.msg_bytes2
        valid = t < horizon_cycles
        t, sizes = t[valid], sizes[valid]
        per_flow_t.append(t.astype(np.int64))
        per_flow_s.append(sizes)
    M = max(1, max(len(t) for t in per_flow_t))
    times = np.full((flows.n, M), INF_I32, np.int32)
    szs = np.zeros((flows.n, M), np.int32)
    for i, (t, s) in enumerate(zip(per_flow_t, per_flow_s)):
        times[i, :len(t)] = np.minimum(t, INF_I32 - 1)
        szs[i, :len(s)] = s
    return times, szs


def gen_stall_mask(cfg: SimConfig, *, seed: int = 1,
                   stall_rate_hz: float = 2000.0,
                   stall_us: tuple[float, float] = (2.0, 40.0)) -> np.ndarray:
    """Host-descheduling process for SHAPING_SW: bursts of stalled ticks.

    `stall_rate_hz` stall events per second, each lasting Uniform(stall_us)
    microseconds — the context-switch / interrupt / softirq interference
    regime of Sec. 5.2.  Time-denominated so results are independent of
    tick_cycles."""
    rng = np.random.default_rng(seed)
    tick_s = cfg.tick_cycles / cfg.clock_hz
    mask = np.zeros(cfg.n_ticks, bool)
    p_start = stall_rate_hz * tick_s
    t = 0
    while t < cfg.n_ticks:
        if rng.random() < p_start:
            dur_s = rng.uniform(*stall_us) * 1e-6
            d = max(1, int(dur_s / tick_s))
            mask[t:t + d] = True
            t += d
        else:
            t += 1
    return mask


# ---------------------------------------------------------------------------
# Carry construction
# ---------------------------------------------------------------------------


def _init_carry(flows: FlowSet, accels: AccelTable, cfg: SimConfig,
                tb_state: tb.TBState) -> dict[str, Any]:
    N, A = flows.n, accels.n
    lanes_busy = np.zeros((A, cfg.lmax), np.float32)
    for a in range(A):
        lanes_busy[a, accels.parallelism[a]:] = np.float32(3e38)  # lane disabled
    return dict(
        # per-flow ingress queues
        q_sz=jnp.zeros((N, cfg.qlen), jnp.int32),
        q_at=jnp.zeros((N, cfg.qlen), jnp.int32),
        q_head=jnp.zeros((N,), jnp.int32),
        q_cnt=jnp.zeros((N,), jnp.int32),
        arr_ptr=jnp.zeros((N,), jnp.int32),
        # shaper
        tb=tb_state,
        sw_pend=jnp.zeros((N,), jnp.int32),
        # arbiter
        rr_ptr=jnp.zeros((), jnp.int32),
        vft=jnp.zeros((N,), jnp.float32),
        # link / credits
        lres=jnp.zeros((2,), jnp.float32),
        credits_used=jnp.zeros((), jnp.int32),
        # accelerator queues + lanes
        aq_sz=jnp.zeros((A, cfg.aq_len), jnp.int32),
        aq_fl=jnp.zeros((A, cfg.aq_len), jnp.int32),
        aq_at=jnp.zeros((A, cfg.aq_len), jnp.int32),
        aq_head=jnp.zeros((A,), jnp.int32),
        aq_cnt=jnp.zeros((A,), jnp.int32),
        aq_bytes=jnp.zeros((A,), jnp.int32),
        lanes=jnp.asarray(lanes_busy),
        # egress queues, one per direction (0 h2d, 1 d2h, 2 off-fabric)
        eq_sz=jnp.zeros((3, cfg.eq_len), jnp.int32),
        eq_isz=jnp.zeros((3, cfg.eq_len), jnp.int32),  # original ingress bytes
        eq_fl=jnp.zeros((3, cfg.eq_len), jnp.int32),
        eq_at=jnp.zeros((3, cfg.eq_len), jnp.int32),
        eq_rd=jnp.zeros((3, cfg.eq_len), jnp.int32),
        eq_head=jnp.zeros((3,), jnp.int32),
        eq_cnt=jnp.zeros((3,), jnp.int32),
        # telemetry ("hardware counters", Arcus step 7)
        c_adm_msgs=jnp.zeros((N,), jnp.int32),
        # exact byte counters, split lo (20 bits) / hi to stay in int32
        c_adm_b_lo=jnp.zeros((N,), jnp.int32),
        c_adm_b_hi=jnp.zeros((N,), jnp.int32),
        c_done_msgs=jnp.zeros((N,), jnp.int32),
        c_done_b_lo=jnp.zeros((N,), jnp.int32),
        c_done_b_hi=jnp.zeros((N,), jnp.int32),
        c_drops=jnp.zeros((N,), jnp.int32),
        c_lat_sum=jnp.zeros((N,), jnp.float32),
        # completion record ring (one scratch slot at index comp_cap)
        comp_fl=jnp.zeros((cfg.comp_cap + 1,), jnp.int32),
        comp_lat=jnp.zeros((cfg.comp_cap + 1,), jnp.int32),
        comp_t=jnp.zeros((cfg.comp_cap + 1,), jnp.int32),
        comp_sz=jnp.zeros((cfg.comp_cap + 1,), jnp.int32),
        comp_n=jnp.zeros((), jnp.int32),
        rng=jnp.asarray(np.int32(0x1234567)),
    )


# ---------------------------------------------------------------------------
# The tick body
# ---------------------------------------------------------------------------


def _make_tick_fn(flows: FlowSet, accels: AccelTable, link: LinkSpec,
                  cfg: SimConfig, arr_t, arr_sz, stall):
    from repro.core.flow import Path
    N, A = flows.n, accels.n
    fl_accel = jnp.asarray(flows.accel_id)
    fl_in_dir = jnp.asarray(flows.ingress_dir)
    fl_eg_dir = jnp.asarray(flows.egress_dir)
    # inline-NIC-RX delivers the full payload to the host no matter what the
    # accelerator emits; other paths transfer the accelerator's output.
    fl_eg_full = jnp.asarray(flows.path == int(Path.INLINE_NIC_RX))
    ovh = jnp.float32(link.msg_overhead_bytes)
    fl_prio = jnp.asarray(flows.priority)
    fl_w = jnp.asarray(np.maximum(flows.weight, 1e-3))
    svc_tab = jnp.asarray(accels.service_cycles)
    eg_tab = jnp.asarray(accels.egress_bytes)
    h2d_bpc, d2h_bpc = link.bytes_per_cycle()
    bpc = jnp.asarray([h2d_bpc, d2h_bpc], jnp.float32)
    iota_n = jnp.arange(N, dtype=jnp.int32)
    shaped = cfg.shaping in (SHAPING_HW, SHAPING_SW)

    def tick(carry, t):
        now = t * cfg.tick_cycles
        now_end = now + cfg.tick_cycles
        is_stall = stall[t] if cfg.shaping == SHAPING_SW else jnp.asarray(False)

        # -- 1. token-bucket timers ------------------------------------
        if cfg.shaping == SHAPING_SW:
            # host descheduled: refills deferred, catch up on wakeup
            pend = carry["sw_pend"] + cfg.tick_cycles
            elapsed = jnp.where(is_stall, 0, pend)
            carry["sw_pend"] = jnp.where(is_stall, pend, 0)
            carry["tb"] = tb.advance(carry["tb"], elapsed)
        elif cfg.shaping == SHAPING_HW:
            carry["tb"] = tb.advance(carry["tb"], cfg.tick_cycles)

        # -- 2. arrivals -> per-flow queues ------------------------------
        def arr_body(_, c):
            ptr = c["arr_ptr"]
            nxt_t = arr_t[iota_n, jnp.minimum(ptr, arr_t.shape[1] - 1)]
            nxt_s = arr_sz[iota_n, jnp.minimum(ptr, arr_t.shape[1] - 1)]
            due = jnp.logical_and(nxt_t < now_end, ptr < arr_t.shape[1])
            room = c["q_cnt"] < cfg.qlen
            take = jnp.logical_and(due, room)
            drop = jnp.logical_and(due, jnp.logical_not(room))
            slot = (c["q_head"] + c["q_cnt"]) % cfg.qlen
            c["q_sz"] = c["q_sz"].at[iota_n, slot].set(
                jnp.where(take, nxt_s, c["q_sz"][iota_n, slot]))
            c["q_at"] = c["q_at"].at[iota_n, slot].set(
                jnp.where(take, nxt_t, c["q_at"][iota_n, slot]))
            c["q_cnt"] = c["q_cnt"] + take.astype(jnp.int32)
            c["arr_ptr"] = ptr + jnp.logical_or(take, drop).astype(jnp.int32)
            c["c_drops"] = c["c_drops"] + drop.astype(jnp.int32)
            return c

        carry = jax.lax.fori_loop(0, cfg.k_arr, arr_body, carry)

        # -- 3. per-tick link budgets ------------------------------------
        budget = bpc * cfg.tick_cycles + carry["lres"]  # [2] bytes

        # -- 4. shaper + arbiter grants ----------------------------------
        def grant_body(_, st):
            c, budget = st
            head_sz = c["q_sz"][iota_n, c["q_head"]]
            head_at = c["q_at"][iota_n, c["q_head"]]
            have = c["q_cnt"] > 0
            cost = tb.cost_of(c["tb"], head_sz)
            if shaped:
                tok_ok = c["tb"].tokens >= cost
            else:
                tok_ok = jnp.ones((N,), bool)
            a_of = fl_accel
            aq_room = jnp.logical_and(
                c["aq_cnt"][a_of] < cfg.aq_len,
                c["aq_bytes"][a_of] + head_sz <= cfg.aq_byte_cap)
            cred_ok = c["credits_used"] < link.credits
            # A message may start whenever the link has *any* remaining
            # budget; it then drives the budget negative, which models its
            # serialization time (the link stays busy / in debt until the
            # per-tick replenishment pays it off).
            bud_f = jnp.where(fl_in_dir == 2, jnp.float32(3e38),
                              budget[jnp.minimum(fl_in_dir, 1)])
            bud_ok = bud_f > 0.0
            elig = have & tok_ok & aq_room & cred_ok & bud_ok
            if cfg.shaping == SHAPING_SW:
                elig = jnp.logical_and(elig, jnp.logical_not(is_stall))

            # arbiter key (lower = served first)
            rr_key = ((iota_n - c["rr_ptr"] - 1) % N).astype(jnp.float32)
            if cfg.arbiter == ARB_RR:
                key = rr_key
            elif cfg.arbiter in (ARB_WRR, ARB_WFQ):
                key = c["vft"] + 1e-6 * rr_key
            elif cfg.arbiter == ARB_PRIORITY:
                key = -fl_prio.astype(jnp.float32) * 1e6 + rr_key
            else:
                raise ValueError(cfg.arbiter)
            key = jnp.where(elig, key, jnp.float32(3e38))
            g = jnp.argmin(key).astype(jnp.int32)
            ok = elig[g]

            sz = head_sz[g]
            at = head_at[g]
            onehot = (iota_n == g) & ok
            # consume tokens
            if shaped:
                c["tb"] = c["tb"]._replace(
                    tokens=c["tb"].tokens - jnp.where(onehot, cost, 0))
            # pop flow queue
            c["q_head"] = (c["q_head"] + onehot) % cfg.qlen
            c["q_cnt"] = c["q_cnt"] - onehot
            # link budget + credits (per-message fabric overhead included)
            dir_idx = jnp.minimum(fl_in_dir[g], 1)
            spend = jnp.where((fl_in_dir[g] != 2) & ok,
                              sz.astype(jnp.float32) + ovh, 0.0)
            budget = budget.at[dir_idx].add(-spend)
            c["credits_used"] = c["credits_used"] + ok.astype(jnp.int32)
            # accel queue push
            a = fl_accel[g]
            slot = (c["aq_head"][a] + c["aq_cnt"][a]) % cfg.aq_len
            c["aq_sz"] = c["aq_sz"].at[a, slot].set(jnp.where(ok, sz, c["aq_sz"][a, slot]))
            c["aq_fl"] = c["aq_fl"].at[a, slot].set(jnp.where(ok, g, c["aq_fl"][a, slot]))
            c["aq_at"] = c["aq_at"].at[a, slot].set(jnp.where(ok, at, c["aq_at"][a, slot]))
            c["aq_cnt"] = c["aq_cnt"].at[a].add(ok.astype(jnp.int32))
            c["aq_bytes"] = c["aq_bytes"].at[a].add(jnp.where(ok, sz, 0))
            # arbiter state.  WRR is message-granular (one packet per flow
            # per round — how the paper's Host_noTS FPGA arbiter behaves,
            # letting large messages steal bytes); WFQ is byte-granular.
            c["rr_ptr"] = jnp.where(ok, g, c["rr_ptr"])
            if cfg.arbiter == ARB_WRR:
                c["vft"] = c["vft"] + jnp.where(onehot, 1.0 / fl_w, 0.0)
            else:
                c["vft"] = c["vft"] + jnp.where(
                    onehot, sz.astype(jnp.float32) / fl_w, 0.0)
            # counters
            c["c_adm_msgs"] = c["c_adm_msgs"] + onehot.astype(jnp.int32)
            lo = c["c_adm_b_lo"] + jnp.where(onehot, sz, 0)
            c["c_adm_b_hi"] = c["c_adm_b_hi"] + (lo >> 20)
            c["c_adm_b_lo"] = lo & 0xFFFFF
            return c, budget

        carry, budget = jax.lax.fori_loop(0, cfg.k_grant, grant_body,
                                          (carry, budget))

        # -- 5. accelerator service (one accel per iteration) -------------
        def srv_body(i, c):
            a = i % A
            lanes_a = c["lanes"][a]
            lane = jnp.argmin(lanes_a).astype(jnp.int32)
            # a lane that frees during this tick may chain back-to-back
            # (no tick-quantization idle gap between messages)
            free = lanes_a[lane] < jnp.float32(now_end)
            ok = free & (c["aq_cnt"][a] > 0)
            h = c["aq_head"][a]
            sz = c["aq_sz"][a, h]
            fl = c["aq_fl"][a, h]
            at = c["aq_at"][a, h]
            svc = interp_grid(svc_tab, a, sz.astype(jnp.float32))
            esz = interp_grid(eg_tab, a, sz.astype(jnp.float32))
            esz = jnp.where(fl_eg_full[fl], sz.astype(jnp.float32), esz)
            end = jnp.maximum(lanes_a[lane], jnp.float32(now)) + svc
            c["lanes"] = c["lanes"].at[a, lane].set(jnp.where(ok, end, lanes_a[lane]))
            c["aq_head"] = c["aq_head"].at[a].add(ok.astype(jnp.int32)) % cfg.aq_len
            c["aq_cnt"] = c["aq_cnt"].at[a].add(-ok.astype(jnp.int32))
            c["aq_bytes"] = c["aq_bytes"].at[a].add(jnp.where(ok, -sz, 0))
            # host-processing delay (software-mediated shaping only)
            if cfg.shaping == SHAPING_SW:
                r = c["rng"] * _LCG_A + _LCG_C
                c["rng"] = r
                u = (jnp.abs(r) % 65536).astype(jnp.float32) / 65536.0
                hostd = cfg.sw_host_delay_cycles + (u ** 4) * cfg.sw_jitter_cycles
            else:
                hostd = jnp.float32(0.0)
            ready = (end + hostd).astype(jnp.int32)
            # egress queue push
            d = fl_eg_dir[fl]
            slot = (c["eq_head"][d] + c["eq_cnt"][d]) % cfg.eq_len
            full = c["eq_cnt"][d] >= cfg.eq_len
            okq = ok & jnp.logical_not(full)
            c["eq_sz"] = c["eq_sz"].at[d, slot].set(
                jnp.where(okq, jnp.maximum(esz.astype(jnp.int32), 1), c["eq_sz"][d, slot]))
            c["eq_isz"] = c["eq_isz"].at[d, slot].set(
                jnp.where(okq, sz, c["eq_isz"][d, slot]))
            c["eq_fl"] = c["eq_fl"].at[d, slot].set(jnp.where(okq, fl, c["eq_fl"][d, slot]))
            c["eq_at"] = c["eq_at"].at[d, slot].set(jnp.where(okq, at, c["eq_at"][d, slot]))
            c["eq_rd"] = c["eq_rd"].at[d, slot].set(jnp.where(okq, ready, c["eq_rd"][d, slot]))
            c["eq_cnt"] = c["eq_cnt"].at[d].add(okq.astype(jnp.int32))
            return c

        carry = jax.lax.fori_loop(0, A * cfg.k_srv, srv_body, carry)

        # -- 6. egress link + completions ----------------------------------
        dirs = jnp.arange(3, dtype=jnp.int32)

        def eg_body(_, st):
            c, budget = st
            h = c["eq_head"]                       # [3]
            sz = c["eq_sz"][dirs, h]
            isz = c["eq_isz"][dirs, h]
            fl = c["eq_fl"][dirs, h]
            at = c["eq_at"][dirs, h]
            rd = c["eq_rd"][dirs, h]
            have = c["eq_cnt"] > 0
            ready = rd < now_end
            bud3 = jnp.concatenate([budget, jnp.asarray([3e38], jnp.float32)])
            bud_ok = bud3[dirs] > 0.0
            pop = have & ready & bud_ok            # [3]
            c["eq_head"] = (c["eq_head"] + pop) % cfg.eq_len
            c["eq_cnt"] = c["eq_cnt"] - pop
            spend = jnp.where(pop[:2], sz[:2].astype(jnp.float32) + ovh, 0.0)
            budget = budget - spend
            c["credits_used"] = c["credits_used"] - pop.sum().astype(jnp.int32)
            # completion = transfer start + own serialization delay
            ser = jnp.where(dirs < 2,
                            sz.astype(jnp.float32) / bpc[jnp.minimum(dirs, 1)],
                            0.0)
            comp_time = jnp.maximum(rd, now) + ser.astype(jnp.int32)
            lat = comp_time - at
            # record (scratch slot comp_cap for non-pops)
            base = c["comp_n"]
            offs = jnp.cumsum(pop.astype(jnp.int32)) - pop.astype(jnp.int32)
            idx = jnp.where(pop, (base + offs) % cfg.comp_cap, cfg.comp_cap)
            c["comp_fl"] = c["comp_fl"].at[idx].set(fl)
            c["comp_lat"] = c["comp_lat"].at[idx].set(lat)
            c["comp_t"] = c["comp_t"].at[idx].set(comp_time)
            c["comp_sz"] = c["comp_sz"].at[idx].set(isz)
            c["comp_n"] = base + pop.sum().astype(jnp.int32)
            # per-flow counters (SLO accounting is on ingress payload bytes,
            # as the paper's traffic generator measures)
            add = jax.ops.segment_sum(pop.astype(jnp.int32), fl, num_segments=N)
            addb = jax.ops.segment_sum(
                jnp.where(pop, isz, 0), fl, num_segments=N)
            addl = jax.ops.segment_sum(
                jnp.where(pop, lat.astype(jnp.float32), 0.0), fl, num_segments=N)
            c["c_done_msgs"] = c["c_done_msgs"] + add
            lo = c["c_done_b_lo"] + addb
            c["c_done_b_hi"] = c["c_done_b_hi"] + (lo >> 20)
            c["c_done_b_lo"] = lo & 0xFFFFF
            c["c_lat_sum"] = c["c_lat_sum"] + addl
            return c, budget

        carry, budget = jax.lax.fori_loop(0, cfg.k_eg, eg_body, (carry, budget))

        # Positive leftover budget is lost (a link cannot save idle time);
        # negative budget (serialization debt of in-flight messages) carries.
        carry["lres"] = jnp.minimum(budget, 0.0)
        return carry, None

    return tick


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SimResult:
    counters: dict[str, np.ndarray]
    comp_flow: np.ndarray
    comp_lat_s: np.ndarray
    comp_t_s: np.ndarray
    comp_sz: np.ndarray
    seconds: float
    clock_hz: float

    # -- post-processing helpers (paper metrics) -----------------------
    def flow_latencies(self, flow_id: int) -> np.ndarray:
        return np.sort(self.comp_lat_s[self.comp_flow == flow_id])

    def latency_percentiles(self, flow_id: int, qs=(95, 99, 99.9)) -> dict:
        lat = self.flow_latencies(flow_id)
        if len(lat) == 0:
            return {q: float("nan") for q in qs}
        return {q: float(np.percentile(lat, q)) for q in qs}

    def throughput_samples(self, flow_id: int, window_msgs: int = 500,
                           kind: str = "iops",
                           warmup_s: float = 0.0) -> np.ndarray:
        """Fig. 6 methodology: sample throughput every `window_msgs` requests."""
        sel = (self.comp_flow == flow_id) & (self.comp_t_s >= warmup_s)
        t = np.sort(self.comp_t_s[sel])
        sz = self.comp_sz[sel]
        if len(t) < 2 * window_msgs:
            return np.array([])
        n_win = len(t) // window_msgs
        out = []
        for w in range(n_win - 1):
            dt = t[(w + 1) * window_msgs] - t[w * window_msgs]
            if dt <= 0:
                continue
            if kind == "iops":
                out.append(window_msgs / dt)
            else:  # gbps of ingress payload
                b = sz[w * window_msgs:(w + 1) * window_msgs].sum()
                out.append(b * 8 / dt / 1e9)
        return np.asarray(out)

    def mean_rate(self, flow_id: int, kind: str = "iops",
                  warmup_s: float = 0.0) -> float:
        sel = (self.comp_flow == flow_id) & (self.comp_t_s >= warmup_s)
        n = sel.sum()
        dur = self.seconds - warmup_s
        if kind == "iops":
            return float(n / dur)
        return float(self.comp_sz[sel].sum() * 8 / dur / 1e9)

    def mean_ingress_gbps(self, flow_id: int, flows: FlowSet,
                          warmup_s: float = 0.0) -> float:
        """Accelerator goodput measured at ingress (SLO accounting uses the
        input-side bytes, as the paper's traffic generator does)."""
        del flows
        return float(self.counters["c_done_bytes"][flow_id] * 8
                     / self.seconds / 1e9)


def simulate(flows: FlowSet, accels: AccelTable, link: LinkSpec,
             cfg: SimConfig, tb_state: tb.TBState,
             arr_t: np.ndarray, arr_sz: np.ndarray,
             stall_mask: np.ndarray | None = None,
             *, t0_ticks: int = 0, carry: dict | None = None,
             return_carry: bool = False):
    """Run the jitted dataplane for cfg.n_ticks ticks starting at t0_ticks.

    Passing back the returned carry resumes the dataplane without resetting
    queues/buckets — the control plane uses this to reconfigure shaping
    parameters *between windows* while traffic keeps flowing, mirroring the
    paper's live MMIO reconfiguration (Sec. 5.3.1 "Dynamism").
    """
    if stall_mask is None:
        stall_mask = np.zeros(t0_ticks + cfg.n_ticks, bool)
    if carry is None:
        carry = _init_carry(flows, accels, cfg, tb_state)
    else:
        # Live reconfiguration: write only the parameter "registers"
        # (Refill_Rate / Bkt_Size / Interval / mode); in-flight tokens and
        # timers are hardware state and keep running.
        carry = dict(carry)
        old = carry["tb"]
        carry["tb"] = old._replace(
            refill_rate=tb_state.refill_rate,
            bkt_size=tb_state.bkt_size,
            interval=tb_state.interval,
            mode=tb_state.mode,
            tokens=jnp.minimum(old.tokens, tb_state.bkt_size),
        )
    tick = _make_tick_fn(flows, accels, link, cfg,
                         jnp.asarray(arr_t), jnp.asarray(arr_sz),
                         jnp.asarray(stall_mask))

    @jax.jit
    def run(carry):
        carry, _ = jax.lax.scan(
            tick, carry,
            jnp.arange(t0_ticks, t0_ticks + cfg.n_ticks, dtype=jnp.int32))
        return carry

    raw = run(carry)
    out = jax.device_get(raw)
    n = int(out["comp_n"])
    cap = cfg.comp_cap
    k = min(n, cap)
    # unroll ring order (oldest first) and trim scratch slot
    if n <= cap:
        order = np.arange(k)
    else:
        start = n % cap
        order = (np.arange(cap) + start) % cap
    counters = {key: out[key] for key in
                ("c_adm_msgs", "c_done_msgs", "c_drops", "c_lat_sum")}
    counters["c_adm_bytes"] = (out["c_adm_b_hi"].astype(np.int64) << 20) \
        + out["c_adm_b_lo"]
    counters["c_done_bytes"] = (out["c_done_b_hi"].astype(np.int64) << 20) \
        + out["c_done_b_lo"]
    result = SimResult(
        counters=counters,
        comp_flow=out["comp_fl"][:cap][order],
        comp_lat_s=out["comp_lat"][:cap][order] / cfg.clock_hz,
        comp_t_s=out["comp_t"][:cap][order] / cfg.clock_hz,
        comp_sz=out["comp_sz"][:cap][order],
        seconds=(t0_ticks + cfg.n_ticks) * cfg.tick_cycles / cfg.clock_hz,
        clock_hz=cfg.clock_hz,
    )
    if return_carry:
        return result, raw
    return result
