"""Cycle-accurate Arcus dataplane simulator (jitted, jax.lax.scan).

This is the JAX-native stand-in for the paper's FPGA testbed: it executes the
Arcus dataplane protocol (Sec. 4.1) at cycle granularity:

    per-flow queues -> [token-bucket shaper] -> arbiter -> ingress link
        -> heterogeneous accelerator (lanes, non-linear service curve)
        -> egress link -> completion

vectorized over flows, scanned over time (1 tick = `tick_cycles` FPGA cycles
at 250 MHz, matching the paper's prototype clock).  Everything that the
paper's hardware measures (per-flow counters, completion latencies) is
accumulated in the scan carry so the control plane can read it back, exactly
like the paper's MMIO counter reads.

The compiled tick loop itself lives in ``repro.core.engine``: a module-level
cache of jitted scans keyed on the static (SimConfig, shapes) signature, with
the carry donated between windows and a ``jax.vmap`` batch entry point.  This
module keeps the host-side surface: trace generation, result collection, and
the ``simulate`` / ``simulate_batch`` entry points.

Shaping modes:
  SHAPING_NONE — no traffic shaping (Host_noTS / Bypassed_noTS_panic)
  SHAPING_HW   — Arcus: cycle-accurate token buckets in 'hardware'
  SHAPING_SW   — software shaping (ReFlex/Firecracker-style): the same token
                 buckets, but timer refills and admissions stall whenever the
                 host is descheduled (stall mask), and every message pays a
                 jittered host-processing delay.  (Sec. 4.2: "even
                 high-resolution timers in today's software cannot guarantee
                 such accuracy"; Sec. 5.2: CPU interference.)
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core import engine
from repro.core import token_bucket as tb
from repro.core.accelerator import AccelTable
from repro.core.engine import (INF_I32, SHAPING_HW,  # noqa: F401 (re-export)
                               SHAPING_NONE, SHAPING_SW, SimConfig)
from repro.core.flow import FlowSet
from repro.core.interconnect import LinkSpec

# ---------------------------------------------------------------------------
# Arrival-trace generation (host side, numpy — vectorized over flows)
# ---------------------------------------------------------------------------
#
# Arrival processes are pluggable: ``register_process`` maps a
# ``TrafficPattern.process`` name to a gap generator, so workload packages
# (``repro.workloads.generators``) add production-shaped processes without
# editing this module.  The built-in cbr/poisson/onoff handlers below
# reproduce the pre-registry vectorized code byte-for-byte: handlers run in
# REGISTRATION order and draw from the one shared ``rng`` stream, so a
# FlowSet containing only built-in processes consumes the exact same random
# numbers as before (the pinned same-seed trace digests gate this).


@dataclasses.dataclass(frozen=True)
class ArrivalProcess:
    """One registered arrival process.

    ``gaps(pats, rates, rng, M0, horizon_s)`` receives the subset of
    patterns using this process (flow order), their nominal mean rates
    (msgs/s), the shared generator, the trace width and the horizon in
    seconds.  It returns inter-arrival gaps ``[k, M0]`` in seconds — or a
    ``(gaps, sizes)`` tuple when the process also draws message sizes
    (``sizes`` int64 bytes ``[k, M0]``; ``None`` keeps the default
    msg_bytes/bimodal sizing).

    ``budget(pattern, rate, horizon_s)`` returns the message-budget factor
    vs the nominal ``rate * horizon`` count — a bursty process whose peak
    rate exceeds its mean must claim the extra columns here or its trace
    is silently truncated at the nominal budget.
    """

    name: str
    gaps: "callable"
    budget: "callable | float" = 1.0

    def budget_factor(self, pattern, rate: float, horizon_s: float) -> float:
        if callable(self.budget):
            return float(self.budget(pattern, rate, horizon_s))
        return float(self.budget)


#: name -> ArrivalProcess, in registration order (= handler draw order)
_PROCESSES: dict[str, ArrivalProcess] = {}


def register_process(name: str, gaps, *, budget=1.0,
                     replace: bool = False) -> ArrivalProcess:
    """Register an arrival process for ``TrafficPattern(process=name)``.

    Handlers draw from ``gen_arrivals``'s shared rng in registration
    order, so registering a new process never perturbs the random stream
    of traces that do not use it (pinned same-seed digests stay pinned).
    Re-registering an existing name raises unless ``replace`` is set."""
    if name in _PROCESSES and not replace:
        raise ValueError(f"arrival process {name!r} is already registered "
                         "(pass replace=True to override)")
    proc = ArrivalProcess(name, gaps, budget)
    _PROCESSES[name] = proc
    return proc


def registered_processes() -> tuple[str, ...]:
    """Registered process names, in registration (= draw) order."""
    return tuple(_PROCESSES)


def _cbr_gaps(pats, rates, rng, M0, horizon_s):
    return np.broadcast_to(1.0 / rates[:, None], (len(pats), M0))


def _poisson_gaps(pats, rates, rng, M0, horizon_s):
    return rng.exponential(1.0, (len(pats), M0)) / rates[:, None]


def _onoff_gaps(pats, rates, rng, M0, horizon_s):
    col = np.arange(M0)
    bl = np.array([p.burst_len for p in pats])[:, None]
    duty = np.array([p.duty for p in pats])[:, None]
    period = bl / rates[:, None]
    on_gap = duty * period / bl
    # idle gap closes each burst so the average rate stays `rate`
    idle = (col[None, :] % bl) == bl - 1
    return on_gap + idle * (1 - duty) * period


register_process("cbr", _cbr_gaps)
register_process("poisson", _poisson_gaps)
register_process("onoff", _onoff_gaps)


def trace_budget(pattern, rate: float, horizon_s: float) -> int:
    """Message-column budget for one flow's trace: the nominal
    ``ceil(rate * horizon) + 16`` scaled by the process's declared burst
    factor.  Shared by ``gen_arrivals`` and the controller's mid-run
    ARRIVE reservation so spliced bursty tenants are never truncated."""
    proc = _PROCESSES.get(pattern.process)
    fac = 1.0 if proc is None else proc.budget_factor(pattern, rate,
                                                      horizon_s)
    return int(np.ceil(max(rate, 1e-9) * fac * horizon_s)) + 16


def gen_arrivals(flows: FlowSet, cfg: SimConfig, *, seed: int = 0,
                 load_ref_gbps: dict[int, float] | None = None,
                 max_msgs: int = 1 << 18) -> tuple[np.ndarray, np.ndarray]:
    """Pre-generate per-flow arrival traces.

    Returns (times[N, M] int32 cycles, sizes[N, M] int32 bytes), padded with
    INF_I32 / 0 past the end of each flow's trace.
    """
    rng = np.random.default_rng(seed)
    horizon_cycles = cfg.n_ticks * cfg.tick_cycles
    horizon_s = horizon_cycles / cfg.clock_hz
    N = flows.n
    pats = [s.pattern for s in flows.specs]
    refs = np.array([(load_ref_gbps or {}).get(i, 32.0) for i in range(N)])
    rates = np.array([max(p.rate_msgs_per_sec(r), 1e-9)
                      for p, r in zip(pats, refs)])
    procs = np.array([p.process for p in pats])
    unknown = sorted(set(procs) - set(_PROCESSES))
    if unknown:
        raise ValueError(
            f"unknown arrival process(es) {unknown}; registered: "
            f"{sorted(_PROCESSES)} (workload processes register via "
            "repro.core.sim.register_process — import "
            "repro.workloads.generators for the production-shaped set)")
    # dense [N, M0] generation sized by the fastest flow: slow rows draw
    # more randomness than their m_i needs, but flow counts here are small
    # (tens) and M0 is capped by max_msgs, so the vectorization win
    # dominates the over-draw.  Burst-factor 1.0 (every built-in process)
    # keeps ``rates * fac`` float-identical to the pre-registry budget.
    fac = np.array([_PROCESSES[p.process].budget_factor(p, r, horizon_s)
                    for p, r in zip(pats, rates)])
    ms = np.minimum(max_msgs,
                    np.ceil(rates * fac * horizon_s) + 16).astype(np.int64)
    M0 = int(max(1, ms.max()))
    col = np.arange(M0)

    gaps = np.empty((N, M0))
    size_over: dict[int, np.ndarray] = {}
    for name, proc in _PROCESSES.items():
        idx = np.flatnonzero(procs == name)
        if idx.size == 0:
            continue
        out = proc.gaps([pats[i] for i in idx], rates[idx], rng, M0,
                        horizon_s)
        g, sz = out if isinstance(out, tuple) else (out, None)
        gaps[idx] = g
        if sz is not None:
            for j, i in enumerate(idx):
                size_over[i] = sz[j]

    t = np.cumsum(gaps, axis=1) * cfg.clock_hz
    sizes = np.broadcast_to(
        np.array([p.msg_bytes for p in pats], np.int64)[:, None],
        (N, M0)).copy()
    p2 = np.array([p.p2 for p in pats])
    bim = p2 > 0
    if bim.any():
        mask = rng.random((int(bim.sum()), M0)) < p2[bim, None]
        sz2 = np.array([p.msg_bytes2 for p in pats], np.int64)[bim, None]
        sizes[bim] = np.where(mask, np.broadcast_to(sz2, mask.shape),
                              sizes[bim])
    for i, sz in size_over.items():
        sizes[i] = np.maximum(sz, 1)

    valid = (t < horizon_cycles) & (col[None, :] < ms[:, None])
    M = int(max(1, valid.sum(axis=1).max()))
    times = np.where(valid, np.minimum(t, INF_I32 - 1), INF_I32) \
        .astype(np.int32)[:, :M]
    szs = np.where(valid, sizes, 0).astype(np.int32)[:, :M]
    return times, szs


def stack_arrivals(arrs: list[tuple[np.ndarray, np.ndarray]]
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Pad a list of (times, sizes) traces to common flow-count and trace
    length and stack to [B, N_max, M] for ``simulate_batch``.

    Ragged flow counts pad with empty lanes (arrival time INF, size 0):
    a padded lane never receives a message, so the engine's ``fl_mask``
    keeps it inert."""
    N = max(t.shape[0] for t, _ in arrs)
    M = max(t.shape[1] for t, _ in arrs)
    times = np.full((len(arrs), N, M), INF_I32, np.int32)
    sizes = np.zeros_like(times)
    for b, (t, s) in enumerate(arrs):
        times[b, :t.shape[0], :t.shape[1]] = t
        sizes[b, :s.shape[0], :s.shape[1]] = s
    return times, sizes


def gen_stall_mask(cfg: SimConfig, *, seed: int = 1,
                   stall_rate_hz: float = 2000.0,
                   stall_us: tuple[float, float] = (2.0, 40.0)) -> np.ndarray:
    """Host-descheduling process for SHAPING_SW: bursts of stalled ticks.

    `stall_rate_hz` stall events per second, each lasting Uniform(stall_us)
    microseconds — the context-switch / interrupt / softirq interference
    regime of Sec. 5.2.  Time-denominated so results are independent of
    tick_cycles."""
    rng = np.random.default_rng(seed)
    tick_s = cfg.tick_cycles / cfg.clock_hz
    mask = np.zeros(cfg.n_ticks, bool)
    p_start = stall_rate_hz * tick_s
    t = 0
    while t < cfg.n_ticks:
        if rng.random() < p_start:
            dur_s = rng.uniform(*stall_us) * 1e-6
            d = max(1, int(dur_s / tick_s))
            mask[t:t + d] = True
            t += d
        else:
            t += 1
    return mask


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SimResult:
    counters: dict[str, np.ndarray]
    comp_flow: np.ndarray
    comp_lat_s: np.ndarray
    comp_t_s: np.ndarray
    comp_sz: np.ndarray
    seconds: float
    clock_hz: float

    # -- post-processing helpers (paper metrics) -----------------------
    def flow_latencies(self, flow_id: int) -> np.ndarray:
        return np.sort(self.comp_lat_s[self.comp_flow == flow_id])

    def latency_percentiles(self, flow_id: int, qs=(95, 99, 99.9)) -> dict:
        lat = self.flow_latencies(flow_id)
        if len(lat) == 0:
            return {q: float("nan") for q in qs}
        return {q: float(np.percentile(lat, q)) for q in qs}

    def throughput_samples(self, flow_id: int, window_msgs: int = 500,
                           kind: str = "iops",
                           warmup_s: float = 0.0) -> np.ndarray:
        """Fig. 6 methodology: sample throughput every `window_msgs` requests."""
        sel = (self.comp_flow == flow_id) & (self.comp_t_s >= warmup_s)
        t = np.sort(self.comp_t_s[sel])
        sz = self.comp_sz[sel]
        if len(t) < 2 * window_msgs:
            return np.array([])
        n_win = len(t) // window_msgs
        out = []
        for w in range(n_win - 1):
            dt = t[(w + 1) * window_msgs] - t[w * window_msgs]
            if dt <= 0:
                continue
            if kind == "iops":
                out.append(window_msgs / dt)
            else:  # gbps of ingress payload
                b = sz[w * window_msgs:(w + 1) * window_msgs].sum()
                out.append(b * 8 / dt / 1e9)
        return np.asarray(out)

    def mean_rate(self, flow_id: int, kind: str = "iops",
                  warmup_s: float = 0.0) -> float:
        sel = (self.comp_flow == flow_id) & (self.comp_t_s >= warmup_s)
        n = sel.sum()
        dur = self.seconds - warmup_s
        if kind == "iops":
            return float(n / dur)
        return float(self.comp_sz[sel].sum() * 8 / dur / 1e9)

    def mean_ingress_gbps(self, flow_id: int, flows: FlowSet,
                          warmup_s: float = 0.0) -> float:
        """Accelerator goodput measured at ingress (SLO accounting uses the
        input-side bytes, as the paper's traffic generator does)."""
        del flows
        return float(self.counters["c_done_bytes"][flow_id] * 8
                     / self.seconds / 1e9)


#: carry keys the host actually needs — everything else (queues, lanes,
#: rings-in-progress) stays on device, so resumable windows never pay a
#: full-carry device_get.
_RESULT_KEYS = ("c_adm_msgs", "c_adm_b_lo", "c_adm_b_hi", "c_done_msgs",
                "c_done_b_lo", "c_done_b_hi", "c_drops", "c_lat_sum",
                "comp_fl", "comp_lat", "comp_t", "comp_sz", "comp_n")


def combine_byte_counters(hi, lo) -> np.ndarray:
    """Recombine the engine's split lo(20 bits)/hi byte counters into exact
    int64 byte counts — the single definition of the split, shared by
    ``_collect_result`` and the fleet control plane's counter poll."""
    return (np.asarray(hi).astype(np.int64) << 20) + np.asarray(lo)


def _collect_result(host: dict, cfg: SimConfig, t0_ticks: int) -> SimResult:
    n = int(host["comp_n"])
    cap = cfg.comp_cap
    k = min(n, cap)
    # unroll ring order (oldest first) and trim scratch slot
    if n <= cap:
        order = np.arange(k)
    else:
        start = n % cap
        order = (np.arange(cap) + start) % cap
    counters = {key: host[key] for key in
                ("c_adm_msgs", "c_done_msgs", "c_drops", "c_lat_sum")}
    counters["c_adm_bytes"] = combine_byte_counters(host["c_adm_b_hi"],
                                                    host["c_adm_b_lo"])
    counters["c_done_bytes"] = combine_byte_counters(host["c_done_b_hi"],
                                                     host["c_done_b_lo"])
    return SimResult(
        counters=counters,
        comp_flow=host["comp_fl"][:cap][order],
        comp_lat_s=host["comp_lat"][:cap][order] / cfg.clock_hz,
        comp_t_s=host["comp_t"][:cap][order] / cfg.clock_hz,
        comp_sz=host["comp_sz"][:cap][order],
        seconds=(t0_ticks + cfg.n_ticks) * cfg.tick_cycles / cfg.clock_hz,
        clock_hz=cfg.clock_hz,
    )


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def simulate(flows: FlowSet, accels: AccelTable, link: LinkSpec,
             cfg: SimConfig, tb_state: tb.TBState,
             arr_t: np.ndarray, arr_sz: np.ndarray,
             stall_mask: np.ndarray | None = None,
             *, t0_ticks: int = 0, carry: dict | None = None,
             return_carry: bool = False):
    """Run the jitted dataplane for cfg.n_ticks ticks starting at t0_ticks.

    Passing back the returned carry resumes the dataplane without resetting
    queues/buckets — the control plane uses this to reconfigure shaping
    parameters *between windows* while traffic keeps flowing, mirroring the
    paper's live MMIO reconfiguration (Sec. 5.3.1 "Dynamism").

    The compiled tick loop is fetched from the engine's module-level cache:
    repeated calls with the same (SimConfig, shapes) signature — including
    per-window calls with new TBState registers, arrival windows, or carry
    contents — reuse the first compilation.  The input carry is donated to
    the engine; do not reuse a carry object after passing it in (use the one
    returned with ``return_carry=True``)."""
    raw = engine.run_window(flows, accels, link, cfg, tb_state,
                            arr_t, arr_sz, stall_mask,
                            t0_ticks=t0_ticks, carry=carry)
    host = jax.device_get({k: raw[k] for k in _RESULT_KEYS})
    result = _collect_result(host, cfg, t0_ticks)
    if return_carry:
        return result, raw
    return result


#: per-flow counter keys: ragged batch elements are sliced back to their
#: unpadded flow count before result collection
_PER_FLOW_KEYS = ("c_adm_msgs", "c_adm_b_lo", "c_adm_b_hi", "c_done_msgs",
                  "c_done_b_lo", "c_done_b_hi", "c_drops", "c_lat_sum")


def simulate_batch(flows, accels, link, cfg,
                   tb_states, arr_t: np.ndarray, arr_sz: np.ndarray,
                   stall_mask: np.ndarray | None = None,
                   *, t0_ticks: int = 0) -> list[SimResult]:
    """Run B independent simulations in one compiled ``jax.vmap`` call.

    * ``tb_states``: sequence of B TBStates (per-element shaping registers);
    * ``arr_t`` / ``arr_sz``: [B, N_max, M] stacked traces
      (``stack_arrivals`` — it pads ragged flow counts);
    * ``flows``: one shared FlowSet, or a sequence of B FlowSets which may
      have *different flow counts* (padded + flow-masked in the engine);
    * ``cfg``: one shared SimConfig, or a sequence of B that differ only in
      the traced system fields (shaping mode, arbiter, software-delay
      model) — heterogeneous baseline systems batch into one engine call;
    * ``accels`` / ``link``: one shared value, or sequences of B for
      per-element accelerator tables / link specs; accelerator tables may
      have *different accelerator counts* (padded to ``n_accels_max`` and
      ``ac_mask``-masked in the engine — padded rows are inert);
    * ``stall_mask``: shared [T] mask or per-element [B, T].

    Returns one SimResult per batch element, each — counters included —
    bitwise-identical to what a serial ``simulate()`` call with the same
    (unpadded) inputs produces."""
    raw = engine.run_window_batch(flows, accels, link, cfg, tb_states,
                                  arr_t, arr_sz, stall_mask,
                                  t0_ticks=t0_ticks)
    host = jax.device_get({k: raw[k] for k in _RESULT_KEYS})
    B = host["comp_n"].shape[0]
    flows_l = flows if isinstance(flows, (list, tuple)) else [flows] * B
    cfg_l = cfg if isinstance(cfg, (list, tuple)) else [cfg] * B
    out = []
    for b in range(B):
        el = {k: v[b] for k, v in host.items()}
        n_b = flows_l[b].n
        for k in _PER_FLOW_KEYS:
            el[k] = el[k][:n_b]
        out.append(_collect_result(el, cfg_l[b], t0_ticks))
    return out
