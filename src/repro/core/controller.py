"""Tenant-lifecycle control plane: one ``FleetController`` surface for
admit / depart / rebalance over churn timelines.

Arcus's Algorithm 1 manages SLOs *continuously* as tenants come and go,
but the repo's control plane historically only grew: ``register``,
``register_fleet``, ``place_fleet``, ``run_managed`` and
``run_managed_batch`` accreted as separate entry points, and tenant
*departure* / re-balancing did not exist at all.  This module redesigns
the API around the tenant lifecycle:

* ``admit(spec)`` / ``place(specs)`` — cross-server SLO-aware admission:
  each round profiles the tenant's whole fleet-wide candidate set through
  ONE batched ``profiler.profile_contexts_multi`` engine call and a
  ``placement.PlacementPolicy`` picks the landing server.  A stateful
  ``placement.ScoreCache`` carries candidate margins between rounds, so
  servers whose tables did not change are not re-scored from scratch.
* ``depart(tenant_id)`` — deregistration.  The tenant's padded dataplane
  lane goes inert via ``fl_mask`` (a *traced* engine argument): shapes
  never change, so a live run — and the compiled engine entry shared by
  later runs — survives without recompiling.  Lane layouts re-pack
  (compact their holes, changing shapes and paying one recompile) only
  when fragmentation crosses ``repack_threshold``, and only between runs.
* ``rebalance()`` — migrate admitted tenants onto freed capacity: each
  tenant is transiently deregistered and re-scored fleet-wide with
  SLO-aware margins (ScoreCache reuses every untouched server's scores);
  it moves only when another server offers strictly more margin.
* ``run(total_ticks, window_ticks, events=[TenantEvent(...)])`` — the
  fleet's batched Algorithm 1 loop (the former ``run_managed_batch``
  internals): B servers' dataplanes run as ONE compiled program on a
  donated carry, and ARRIVE / DEPART events apply at window boundaries —
  an arriving tenant is placed, registered and handed a fresh lane (its
  arrival trace spliced into the committed device buffers); a departing
  tenant's lane is flushed and masked.  All of it on the same compiled
  engine entry, with the PR 4 rebuild-skip path untouched: a window after
  which nothing changed resumes the carry with no register rewrite.

The between-window path is an explicit measurement -> policy ->
actuation pipeline: ``repro.core.telemetry`` turns the window's counter
deltas into per-tenant ``WindowMetrics``, a ``repro.core.control``
``ControlPolicy`` (the ``control=`` constructor argument) turns metrics
into shaped-rate plans clamped to profiled capacity envelopes, and
``control.actuate`` commits plans as token-bucket register values
through the existing per-server re-pack path.  The default policy is
``StaticHold`` — decisions and registers bitwise-identical to the
pre-pipeline controller.

Parity contract: with a static tenant set (no events) ``run`` is
bit-for-bit the old ``run_managed_batch`` — counters, WindowReports and
post-run control state equal B serial ``run_managed`` calls — and the
old entry points remain as deprecation shims delegating here.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import control as ctl
from repro.core import engine, placement, sim, telemetry
from repro.core import token_bucket as tb
from repro.core.accelerator import AccelTable
from repro.core.engine import INF_I32
from repro.core.flow import SLO, FlowSet, FlowSpec, Path, TrafficPattern
from repro.core.interconnect import ARB_RR
from repro.core.profiler import profile_contexts_multi
from repro.core.runtime import _compatible_accels
from repro.core.sim import SHAPING_HW, SimConfig, gen_arrivals

ARRIVE = "arrive"
DEPART = "depart"


@dataclasses.dataclass(frozen=True)
class TenantEvent:
    """One lifecycle event applied at the START of window ``window``.

    ``arrive``: ``spec`` is placed by the controller's policy (or pinned
    to ``server``; ``accel_name`` lands it on a named accelerator), its
    lane allocated and its arrival trace generated over the remaining
    horizon (``seed`` overrides the derived per-event seed;
    ``ref_gbps`` the load-reference line rate).  ``depart``:
    ``tenant_id`` is deregistered and its lane freed."""

    window: int
    kind: str
    spec: FlowSpec | None = None
    tenant_id: int | None = None
    server: int | None = None
    accel_name: str | None = None
    ref_gbps: float | None = None
    seed: int | None = None

    @staticmethod
    def arrive(window: int, spec: FlowSpec, *, server: int | None = None,
               accel_name: str | None = None, ref_gbps: float | None = None,
               seed: int | None = None) -> "TenantEvent":
        return TenantEvent(window, ARRIVE, spec=spec, server=server,
                           accel_name=accel_name, ref_gbps=ref_gbps,
                           seed=seed)

    @staticmethod
    def depart(window: int, tenant_id: int) -> "TenantEvent":
        return TenantEvent(window, DEPART, tenant_id=tenant_id)


def _hole_spec(lane: int) -> FlowSpec:
    """Placeholder spec for an unoccupied lane: routes to accel 0 with the
    pad-fill flow attributes, injects nothing (1e-9 msgs/s keeps its trace
    empty without disturbing the shared rng stream — CBR draws none)."""
    return FlowSpec(-1 - lane, -1, Path.FUNCTION_CALL, 0,
                    TrafficPattern(msg_bytes=1024, rate_mps=1e-9,
                                   process="cbr"),
                    SLO.gbps(0.0), weight=1.0)


_HOLE_TB = tb.TBParams(1, 1, 1)


class FleetController:
    """SLO lifecycle manager for a fleet of client servers.

    Owns the per-server *lane layouts*: ``_lanes[b]`` maps dataplane lane
    index -> flow id (``None`` = hole).  Lanes are what the compiled
    engine sees; keeping them stable across membership changes is what
    lets churn ride one compiled entry.  A fresh controller adopts each
    runtime's registered flows in sorted-flow-id order — exactly the
    legacy layout, which is what makes the deprecation shims bitwise."""

    def __init__(self, runtimes: Sequence[Any], *,
                 policy: placement.PlacementPolicy | None = None,
                 repack_threshold: float = 0.5,
                 control: "ctl.ControlPolicy | None" = None,
                 reuse_lanes: bool = False):
        self.runtimes = list(runtimes)
        self.policy = policy or placement.SLOAware()
        self.repack_threshold = float(repack_threshold)
        self.score_cache = placement.ScoreCache()
        # the between-window shaping policy; the default StaticHold keeps
        # every run bitwise-identical to the pre-control-loop controller
        self.control = control if control is not None else ctl.StaticHold()
        # opt-in: let mid-run arrivals refill hole lanes.  Lane recycling
        # is measurement-safe now (engine.recycle_flow_lane and the run
        # loop both reset the lane's delta baseline), but refilling
        # changes lane layouts — and thus arbiter order and counter rows —
        # versus the historical always-append behaviour, so it stays off
        # by default to preserve the bitwise contract.
        self.reuse_lanes = bool(reuse_lanes)
        self._lanes: list[list[int | None]] = [sorted(rt.table)
                                               for rt in self.runtimes]
        self._tenants: dict[int, int] = {}      # flow id -> server index
        self._in_run = False     # mid-run arrivals take FRESH lanes unless
                                 # reuse_lanes opted into hole recycling
                                 # (see _assign_lane)
        self._envelopes: list[tuple[int, dict] | None] = \
            [None] * len(self.runtimes)   # per-server (version, envelopes)
        self.stats = {"admitted": 0, "rejected": 0, "departed": 0,
                      "migrated": 0, "repacks": 0}
        self.last_events: list[dict] = []

    # ------------------------------------------------------------------
    # Lane layout bookkeeping
    # ------------------------------------------------------------------
    def lane_map(self, server: int) -> list[int | None]:
        """Lane index -> flow id (None = hole) of one server — the row
        layout of that server's counters in ``run`` results."""
        return list(self._lanes[server])

    def _sync_layouts(self) -> None:
        """Reconcile layouts with the runtimes' tables: flows deregistered
        behind the controller's back become holes; unknown registered
        flows get lanes (in sorted order, matching the legacy layout)."""
        for b, rt in enumerate(self.runtimes):
            lanes = self._lanes[b]
            live = set(rt.table)
            lanes[:] = [f if (f is not None and f in live) else None
                        for f in lanes]
            known = {f for f in lanes if f is not None}
            for fid in sorted(live - known):
                self._assign_lane(b, fid)

    def _assign_lane(self, b: int, fid: int) -> int:
        """Give a flow a lane: holes first between runs (compactness) —
        and mid-run too when ``reuse_lanes`` is set, since lane surgery
        now resets the recycled lane's counters and measurement baseline
        (``engine.recycle_flow_lane`` + the run loop's prev-slab reset).
        The historical default appends a FRESH lane mid-run, preserving
        layout (and counter-row) compatibility bit-for-bit (a
        between-runs hole refill starts from a fresh carry anyway)."""
        lanes = self._lanes[b]
        if fid in lanes:
            return lanes.index(fid)
        if not self._in_run or self.reuse_lanes:
            for i, f in enumerate(lanes):
                if f is None:
                    lanes[i] = fid
                    return i
        lanes.append(fid)
        return len(lanes) - 1

    def _depart_core(self, tenant_id: int) -> tuple[int, int]:
        """The shared departure sequence (between-runs ``depart`` and the
        mid-run DEPART event): deregister, punch the lane hole, drop the
        tenant record.  Returns (server, freed lane)."""
        b = self._find_server(tenant_id)
        self.runtimes[b].deregister(tenant_id)
        lane = self._lanes[b].index(tenant_id)
        self._lanes[b][lane] = None
        self._tenants.pop(tenant_id, None)
        self.stats["departed"] += 1
        return b, lane

    def _maybe_repack(self, server: int | None = None,
                      force: bool = False) -> int:
        """Compact hole lanes out of layouts whose fragmentation crosses
        ``repack_threshold`` (always, with ``force``).  Compaction re-keys
        lanes and shrinks the batch width — i.e. the next run compiles a
        fresh engine signature — so it only ever happens between runs;
        below the threshold holes are kept and the next run reuses the
        previous compiled entry."""
        n = 0
        servers = range(len(self.runtimes)) if server is None else [server]
        for b in servers:
            lanes = self._lanes[b]
            holes = sum(f is None for f in lanes)
            if holes and (force
                          or holes / len(lanes) > self.repack_threshold):
                lanes[:] = [f for f in lanes if f is not None]
                self.stats["repacks"] += 1
                n += 1
        return n

    def _find_server(self, tenant_id: int) -> int:
        b = self._tenants.get(tenant_id)
        if b is not None and tenant_id in self.runtimes[b].table:
            return b
        hits = [b for b, rt in enumerate(self.runtimes)
                if tenant_id in rt.table]
        if not hits:
            raise KeyError(f"unknown tenant {tenant_id}")
        if len(hits) > 1:
            raise ValueError(
                f"tenant id {tenant_id} is registered on several servers "
                f"{hits} — lifecycle operations need fleet-unique ids")
        return hits[0]

    # ------------------------------------------------------------------
    # Admission: cross-server SLO-aware placement
    # ------------------------------------------------------------------
    def _score_round(self, spec: FlowSpec, pin: int | None,
                     name: str | None,
                     cache: placement.ScoreCache | None
                     ) -> list[placement.Candidate]:
        """Score one admission round's fleet-wide candidate set.

        Cache-missing candidates build their would-be contexts and run
        through ONE batched ``profile_contexts_multi`` call; cache hits
        (servers untouched since they were last scored) reuse the prior
        round's Candidate — same floats, same decision, no context
        rebuild."""
        B = len(self.runtimes)
        meta = []
        for b in (range(B) if pin is None else [pin]):
            rt = self.runtimes[b]
            for a in _compatible_accels(rt, spec, name):
                cand_spec = dataclasses.replace(spec, accel_id=a)
                cached = (cache.lookup(rt, b, a, cand_spec)
                          if cache is not None else None)
                ctx = None if cached is not None \
                    else rt._admission_context(cand_spec)
                meta.append((b, a, cand_spec, cached, ctx))
        if meta:
            # ONE batched engine call profiles the whole round's
            # cache-missing cross-server candidate set
            profile_contexts_multi(
                [(self.runtimes[b].profile, ctx[0], ctx[2])
                 for b, _a, _s, cached, ctx in meta if cached is None])
        cands = []
        for b, a, cand_spec, cached, ctx in meta:
            if cached is not None:
                cands.append(cached)
                continue
            ok, entry, slo, margin, margin_res = \
                self.runtimes[b]._admission_check(cand_spec, ctx)
            cand = placement.Candidate(
                server=b, accel_id=a, spec=cand_spec, entry=entry,
                slo_gbps=tuple(slo), feasible=ok, margin=margin,
                residual=entry.residual_gbps(slo),
                server_key=placement.server_key(self.runtimes[b]),
                margin_res=margin_res)
            if cache is not None:
                cache.store(self.runtimes[b], b, a, cand_spec, cand)
            cands.append(cand)
        return cands

    def place(self, specs: Sequence[FlowSpec], *,
              policy: placement.PlacementPolicy | None = None,
              pinned: Sequence[int | None] | None = None,
              accel_names: Sequence[str | None] | None = None,
              score_cache: "placement.ScoreCache | None" = None
              ) -> list[placement.Placement]:
        """Fleet-level admission placement — one admission round per
        tenant, in order (the CapacityPlanning admission of Algorithm 1,
        shopped across every client server).

        A round enumerates every compatible (server, accelerator) landing
        option — all servers, or only ``pinned[i]`` when given; the
        accelerator matching ``accel_names[i]`` on each server, or the
        spec's positional ``accel_id`` when no name is given — scores it
        (see ``_score_round``; the controller's ``ScoreCache`` carries
        untouched servers' margins between rounds), and lets the policy
        pick.  The winner registers via the ordinary per-server
        ``ArcusRuntime.register`` path (a warmed-cache hit, so placement
        can never admit what per-server admission would reject); a tenant
        is rejected only when NO server fits.

        Parity contract: ``policy=FirstFit()`` with every spec pinned to
        its original server reproduces ``admit_fleet`` accept/reject
        decisions exactly."""
        pol = policy or self.policy
        B = len(self.runtimes)
        specs = list(specs)
        pins = list(pinned) if pinned is not None else [None] * len(specs)
        names = (list(accel_names) if accel_names is not None
                 else [None] * len(specs))
        if not (len(pins) == len(specs) and len(names) == len(specs)):
            raise ValueError(
                "pinned / accel_names must have one entry per spec")
        if any(p is not None and not 0 <= p < B for p in pins):
            raise ValueError("pinned server index out of range")
        cache = score_cache if score_cache is not None else self.score_cache
        out: list[placement.Placement] = []
        for spec, pin, name in zip(specs, pins, names):
            cands = self._score_round(spec, pin, name, cache)
            chosen = pol.select(cands)
            if chosen is not None and not chosen.feasible:
                raise ValueError(
                    f"policy {pol.name!r} selected an infeasible candidate "
                    f"(server {chosen.server}, accel {chosen.accel_id}) — "
                    "select() must return a feasible candidate or None")
            accepted = False
            if chosen is not None:
                accepted = self.runtimes[chosen.server].register(chosen.spec)
                if not accepted:
                    # feasibility came from the same cached entry
                    # register() re-reads, so a feasible candidate can
                    # only bounce if register() drifts from
                    # _admission_check
                    raise RuntimeError(
                        f"server {chosen.server} rejected a candidate "
                        "scored feasible — register() and _admission_check "
                        "diverged")
                self._tenants[chosen.spec.flow_id] = chosen.server
                self._assign_lane(chosen.server, chosen.spec.flow_id)
                self.stats["admitted"] += 1
            else:
                self.stats["rejected"] += 1
            out.append(placement.Placement(
                spec=spec,
                server=None if chosen is None else chosen.server,
                accel_id=None if chosen is None else chosen.accel_id,
                accepted=accepted,
                n_candidates=len(cands),
                n_feasible=sum(c.feasible for c in cands)))
        return out

    def admit(self, spec: FlowSpec, *, server: int | None = None,
              accel_name: str | None = None) -> placement.Placement:
        """Admit one tenant (policy placement; ``server`` pins it).  The
        flow id must be fleet-unique so ``depart`` stays unambiguous."""
        if any(spec.flow_id in rt.table for rt in self.runtimes):
            raise ValueError(
                f"flow id {spec.flow_id} is already admitted somewhere in "
                "the fleet — lifecycle tenants need fleet-unique ids")
        return self.place([spec], pinned=[server],
                          accel_names=[accel_name])[0]

    def admit_fleet(self, fleet_specs: Sequence[Sequence[FlowSpec]]
                    ) -> list[list[bool]]:
        """Register per-server FlowSpec lists, batching the admission
        profiling: round r profiles the r-th spec of EVERY server through
        one ``profile_contexts_multi`` engine call, then registers via
        the warmed per-server path — accept/reject decisions identical to
        serial registration.  An empty per-server list is valid; a
        length mismatch is rejected before any work."""
        runtimes = self.runtimes
        if len(fleet_specs) != len(runtimes):
            raise ValueError(
                f"fleet_specs must have one spec list per server "
                f"(got {len(fleet_specs)} lists for {len(runtimes)} "
                "servers)")
        results: list[list[bool]] = [[] for _ in runtimes]
        rounds = max((len(s) for s in fleet_specs), default=0)
        for r in range(rounds):
            jobs = []
            for b, rt in enumerate(runtimes):
                if r >= len(fleet_specs[b]):
                    continue
                accel, _peers, ctx = rt._admission_context(fleet_specs[b][r])
                jobs.append((rt.profile, accel, ctx))
            profile_contexts_multi(jobs)
            for b, rt in enumerate(runtimes):
                if r < len(fleet_specs[b]):
                    ok = rt.register(fleet_specs[b][r])
                    results[b].append(ok)
                    if ok:
                        self._assign_lane(b, fleet_specs[b][r].flow_id)
                        self.stats["admitted"] += 1
                    else:
                        self.stats["rejected"] += 1
        return results

    # ------------------------------------------------------------------
    # Departure + rebalancing
    # ------------------------------------------------------------------
    def depart(self, tenant_id: int) -> int:
        """Deregister a tenant between runs; returns its server index.

        The tenant's lane becomes a hole: the next ``run`` masks it via
        ``fl_mask`` — same shapes, same compiled engine entry as the
        previous run.  The layout compacts (one recompile) only once its
        hole fraction crosses ``repack_threshold``."""
        self._sync_layouts()
        b, _lane = self._depart_core(tenant_id)
        self._maybe_repack(b)
        return b

    def rebalance(self, *, min_gain: float = 1e-6) -> list[dict]:
        """Migrate admitted tenants onto freed capacity.

        Each tenant (in (server, flow id) order) is transiently
        deregistered and its spec re-scored on every server carrying its
        accelerator type — the home candidate rebuilds the original
        context exactly, so a stay-put decision restores the tenant's
        FlowStatus (headroom, violation history) untouched.  It migrates
        only when the best foreign SLO-aware margin beats the home margin
        by more than ``min_gain`` (hysteresis against twin-server
        ping-pong).  The stateful ``ScoreCache`` makes the sweep cheap:
        a move touches two servers' tables; every other server's
        candidate scores replay from cache.  Returns one record per
        migration."""
        self._sync_layouts()
        moves: list[dict] = []
        tenants = [(b, fid) for b, rt in enumerate(self.runtimes)
                   for fid in sorted(rt.table)]
        for b, fid in tenants:
            rt = self.runtimes[b]
            st = rt.table[fid]
            name = rt.accel_specs[st.spec.accel_id].name
            st = rt.deregister(fid)
            cands = self._score_round(st.spec, None, name, self.score_cache)
            feasible = [c for c in cands if c.feasible]
            home = next((c for c in feasible if c.server == b), None)
            away = [c for c in feasible if c.server != b]
            best = (min(away, key=lambda c: (-c.margin,
                                             placement.PlacementPolicy
                                             ._tie_key(c)))
                    if away else None)
            if (best is None or home is not None
                    and best.margin <= home.margin + min_gain):
                # stay: restore the original FlowStatus bit-for-bit
                rt.table[fid] = st
                rt._version += 1
                continue
            ok = self.runtimes[best.server].register(best.spec)
            if not ok:       # same guard as place(): cannot happen unless
                rt.table[fid] = st          # scoring and register drift
                rt._version += 1
                raise RuntimeError(
                    f"server {best.server} rejected a migration scored "
                    "feasible")
            lane = self._lanes[b].index(fid)
            self._lanes[b][lane] = None
            self._assign_lane(best.server, fid)
            self._tenants[fid] = best.server
            self.stats["migrated"] += 1
            moves.append(dict(tenant=fid, src=b, dst=best.server,
                              accel_id=best.accel_id,
                              margin_before=None if home is None
                              else home.margin,
                              margin_after=best.margin))
        self._maybe_repack()
        return moves

    # ------------------------------------------------------------------
    # The managed fleet loop (the former run_managed_batch internals)
    # ------------------------------------------------------------------
    def _build_lane_args(self, b: int, width: int
                         ) -> tuple[FlowSet, np.ndarray, tb.TBState]:
        """One server's engine-side lane tables at the run's batch width:
        (FlowSet in lane order with hole placeholders, validity mask,
        packed TB registers — benign on holes)."""
        rt = self.runtimes[b]
        lanes = self._lanes[b]
        specs, params = [], []
        mask = np.zeros(width, bool)
        for i in range(width):
            fid = lanes[i] if i < len(lanes) else None
            if fid is None:
                specs.append(_hole_spec(i))
                params.append(_HOLE_TB)
            else:
                specs.append(rt.table[fid].spec)
                params.append(rt.table[fid].params)
                mask[i] = True
        return FlowSet.build(specs), mask, tb.pack(params)

    def _layout_arrivals(self, b: int, full_cfg: SimConfig, seed: int,
                         ref: dict[int, float] | None
                         ) -> tuple[np.ndarray, np.ndarray]:
        """Full-horizon arrival traces in lane order (holes stay silent).
        With a hole-free layout this is exactly the legacy per-server
        ``gen_arrivals`` call — same rng stream, same rows — which is
        what keeps the static-fleet path bitwise.

        ``ref`` keeps its legacy meaning — key k refers to the server's
        k-th LIVE flow — so it is remapped over the active lanes when
        departures have punched holes (with no holes the remap is the
        identity)."""
        rt = self.runtimes[b]
        lanes = self._lanes[b]
        specs = [rt.table[f].spec if f is not None else _hole_spec(i)
                 for i, f in enumerate(lanes)]
        if ref is not None:
            act = [i for i, f in enumerate(lanes) if f is not None]
            ref = {act[k]: v for k, v in ref.items()
                   if isinstance(k, int) and 0 <= k < len(act)}
        t, s = gen_arrivals(FlowSet.build(specs), full_cfg, seed=seed,
                            load_ref_gbps=ref)
        for i, f in enumerate(lanes):
            if f is None:                  # belt & braces: holes silent
                t[i] = INF_I32
                s[i] = 0
        return t, s

    def layout_arrivals(self, server: int, cfg: SimConfig, seed: int,
                        ref: dict[int, float] | None = None
                        ) -> tuple[np.ndarray, np.ndarray]:
        """Full-horizon arrival traces for one server in lane order — the
        exact rows ``run`` would generate itself from ``seeds``.  The
        public seam for replayable scenarios (``repro.workloads``): emit
        once, save, and pass back through ``run(arrivals=...)``."""
        return self._layout_arrivals(server, cfg, seed, ref)

    def _fleet_pass(self, host: dict, prev: dict | None, cfg: SimConfig,
                    t0_ticks: int, reports: list[list]) -> dict:
        """One fleet-wide Algorithm 1 pass between engine windows.

        Measurement runs vectorized over the whole fleet (one
        ``[B, width]`` ``telemetry.measured_rates`` slab); the per-flow
        violation / ReAdjustPattern body is the exact serial code path
        (``ArcusRuntime._window_pass`` with the controller's lane map), so
        fleet decisions are the serial decisions by construction."""
        cur = telemetry.fleet_counters(host)
        if prev is None:
            prev = {k: np.zeros_like(v) for k, v in cur.items()}
        window_s = cfg.seconds
        t_end_s = (t0_ticks + cfg.n_ticks) * cfg.tick_cycles / cfg.clock_hz
        B, width = cur["c_done_msgs"].shape
        kind = np.full((B, width), -1, np.int32)
        for b, rt in enumerate(self.runtimes):
            for lane, fid in enumerate(self._lanes[b]):
                if fid is not None:
                    kind[b, lane] = int(rt.table[fid].spec.slo.kind)
        measured = telemetry.measured_rates(cur, prev, kind, window_s)
        for b, rt in enumerate(self.runtimes):
            w_b = len(self._lanes[b])
            lane_of = {fid: i for i, fid in enumerate(self._lanes[b])
                       if fid is not None}
            cur_b = {k: v[b, :w_b] for k, v in cur.items()}
            prev_b = {k: v[b, :w_b] for k, v in prev.items()}
            reports[b].append(rt._window_pass(cur_b, prev_b, window_s,
                                              t_end_s, measured[b],
                                              lane_of))
            rt._prev_counters = cur_b
        return cur

    def _apply_event(self, ev: TenantEvent, ei: int, t0: int,
                     full_cfg: SimConfig, seeds_l: list[int],
                     arr_t, arr_sz, carry, width: int
                     ) -> tuple[Any, Any, Any, list[int],
                                list[tuple[int, int]]]:
        """Apply one ARRIVE/DEPART event at a window boundary.  Returns
        the (possibly updated) arrival buffers, carry, the list of
        servers whose lane tables must re-pack before the next window,
        and the (server, lane) pairs an ARRIVE spliced — the run loop
        resets those lanes' host-side measurement baseline so the first
        window's counter delta cannot mix a departed predecessor's
        totals into the newcomer's measured rate."""
        if ev.kind == DEPART:
            b, lane = self._depart_core(ev.tenant_id)
            # the lane goes dark: no future arrivals, queued-but-unadmitted
            # messages flushed; in-flight messages drain naturally
            arr_t = arr_t.at[b, lane].set(INF_I32)
            arr_sz = arr_sz.at[b, lane].set(0)
            if carry is not None:
                carry = engine.release_flow_lane(carry, b, lane)
            self.last_events.append(dict(
                window=ev.window, kind=DEPART, tenant=ev.tenant_id,
                server=b, lane=lane))
            return arr_t, arr_sz, carry, [b], []

        # ARRIVE — place, register, splice the lane in
        if any(ev.spec.flow_id in rt.table for rt in self.runtimes):
            raise ValueError(
                f"arriving flow id {ev.spec.flow_id} is already admitted "
                "— lifecycle tenants need fleet-unique ids")
        p = self.place([ev.spec], pinned=[ev.server],
                       accel_names=[ev.accel_name])[0]
        if not p.accepted:
            self.last_events.append(dict(
                window=ev.window, kind=ARRIVE, tenant=ev.spec.flow_id,
                server=None, lane=None))
            return arr_t, arr_sz, carry, [], []
        b = p.server
        lane = self._lanes[b].index(ev.spec.flow_id)
        if lane >= width:
            raise RuntimeError(
                f"lane {lane} exceeds the run's reserved width {width}")
        landed = dataclasses.replace(ev.spec, accel_id=p.accel_id)
        seed = (ev.seed if ev.seed is not None
                else (seeds_l[b] * 1_000_003 + 7919 * (ei + 1))
                % (2 ** 31 - 1))
        rest_cfg = dataclasses.replace(full_cfg,
                                       n_ticks=full_cfg.n_ticks - t0)
        t1, s1 = gen_arrivals(FlowSet.build([landed]), rest_cfg, seed=seed,
                              load_ref_gbps=None if ev.ref_gbps is None
                              else {0: ev.ref_gbps})
        off = t0 * full_cfg.tick_cycles
        M = arr_t.shape[2]
        row_t = np.full(M, INF_I32, np.int32)
        row_s = np.zeros(M, np.int32)
        k = min(t1.shape[1], M)
        tt = t1[0, :k].astype(np.int64)
        shifted = np.where(tt >= INF_I32, INF_I32, tt + off)
        row_t[:k] = shifted.astype(np.int32)
        row_s[:k] = np.where(tt >= INF_I32, 0, s1[0, :k])
        arr_t = arr_t.at[b, lane].set(row_t)
        arr_sz = arr_sz.at[b, lane].set(row_s)
        if carry is not None:
            carry = engine.recycle_flow_lane(carry, b, lane)
        self.last_events.append(dict(
            window=ev.window, kind=ARRIVE, tenant=ev.spec.flow_id,
            server=b, lane=lane))
        return arr_t, arr_sz, carry, [b], [(b, lane)]

    # ------------------------------------------------------------------
    # Control layer: WindowMetrics -> policy decisions -> register plans
    # ------------------------------------------------------------------
    def _server_envelopes(self, b: int) -> dict[int, "ctl.Envelope"]:
        """A server's profiled capacity envelopes, cached per membership
        version: policies re-read them every window, but the underlying
        ``ProfileTable.capacity`` lookups only re-run after a lifecycle
        or path change bumped the runtime's version."""
        rt = self.runtimes[b]
        hit = self._envelopes[b]
        if hit is not None and hit[0] == rt.lifecycle_version:
            return hit[1]
        env = ctl.capacity_envelopes(rt)
        self._envelopes[b] = (rt.lifecycle_version, env)
        return env

    def _control_decide(self, w: int, wcfg: SimConfig,
                        reports: list[list]) -> list[bool]:
        """One measurement -> policy -> actuation step after window ``w``:
        build each server's ``ServerView`` from the fresh WindowReport
        metrics, let ``self.control`` decide, and commit plans through
        ``control.actuate``.  Returns the per-server changed flags (a
        server whose registers did not change keeps the
        no-register-rewrite resume path).  ``StaticHold`` short-circuits
        everything — no envelopes, no margins, no actuation."""
        pol = self.control
        B = len(self.runtimes)
        views = []
        for b, rt in enumerate(self.runtimes):
            metrics = reports[b][-1].metrics if reports[b] else {}
            env = self._server_envelopes(b) if pol.needs_envelopes else {}
            margin = (self.score_cache.server_margin(b)
                      if pol.needs_envelopes else None)
            views.append(ctl.ServerView(server=b, window_s=wcfg.seconds,
                                        metrics=metrics, envelopes=env,
                                        margin=margin))
        plans = pol.decide(w, views)
        if len(plans) != B:
            raise ValueError(
                f"control policy {pol.name!r} returned {len(plans)} plans "
                f"for {B} servers")
        return [bool(plan) and ctl.actuate(self.runtimes[b], plan)
                for b, plan in enumerate(plans)]

    def run(self, *, total_ticks: int, window_ticks: int,
            tick_cycles: int = 8,
            seeds: Sequence[int] | None = None,
            arrivals: Sequence[tuple[np.ndarray, np.ndarray]] | None = None,
            load_ref_gbps: Sequence[dict[int, float] | None]
            | dict[int, float] | None = None,
            sim_kwargs: dict[str, Any] | None = None,
            events: Sequence[TenantEvent] = (),
            _force_rebuild: bool = False):
        """Drive the fleet's batched Algorithm 1 loop over a churn
        timeline.

        B servers' dataplanes run as ONE compiled program: per-server
        lane tables (ragged flow counts — and holes — masked via
        ``fl_mask``), accelerator complements (ragged accel counts),
        arrival traces and TBState registers stack along a fleet axis
        into ``engine.run_window_batch``; every window resumes the same
        donated carry, and register re-packs happen per server only after
        a window that reconfigured it (or a lifecycle event touched it) —
        an all-clean window resumes with NO register rewrite.

        ``events`` apply at window boundaries (the start of
        ``TenantEvent.window``); the batch width reserves one lane per
        ARRIVE event, so the whole timeline — arrivals, departures, the
        trailing partial window aside — shares one compiled engine entry.
        ARRIVE placement profiles through the servers' ProfileTables:
        pre-warmed contexts are pure cache hits (no engine call at all);
        cold contexts run batched profiling engine entries on the side.

        With no events this is bit-for-bit the legacy
        ``run_managed_batch``: counters, WindowReports, admission
        decisions and post-run control state equal B serial
        ``run_managed`` calls.

        Explicit ``arrivals`` must carry one trace row per LANE (holes
        included, in ``lane_map`` order) — a row count mismatching the
        layout is rejected rather than silently landing traffic on the
        wrong lane.

        After every window (except the last) the controller runs one
        measurement -> policy -> actuation step: the window's
        ``WindowMetrics`` feed ``self.control`` (a
        ``control.ControlPolicy``; default ``StaticHold`` — a bitwise
        no-op) and committed plans mark their server for a register
        re-pack; servers whose policies held steady keep the
        no-register-rewrite resume path.

        Returns ``(results, reports)``: one last-window ``SimResult`` per
        server (rows in lane order — see ``lane_map``; with no holes that
        is sorted-flow-id order; a mid-run arrival occupies a fresh lane
        — or, with ``reuse_lanes``, a recycled hole whose counters and
        measurement baseline were reset at splice — so each tenant's
        cumulative lane counters are its own) and one
        ``list[WindowReport]`` per server."""
        runtimes = self.runtimes
        B = len(runtimes)
        if B == 0:
            return [], []
        clock_hz = runtimes[0].clock_hz
        if any(rt.clock_hz != clock_hz for rt in runtimes):
            raise ValueError("fleet servers must share clock_hz")
        if any(not rt.table for rt in runtimes):
            raise ValueError("every fleet server needs at least one "
                             "registered flow")
        seeds_l = list(seeds) if seeds is not None else [0] * B
        refs_l = (list(load_ref_gbps)
                  if isinstance(load_ref_gbps, (list, tuple))
                  else [load_ref_gbps] * B)
        if not (len(seeds_l) == B and len(refs_l) == B):
            raise ValueError("seeds / load_ref_gbps must have one entry "
                             "per server")
        sim_kw = dict(sim_kwargs or {})
        sim_kw.setdefault("clock_hz", clock_hz)   # see run_managed
        cfg = SimConfig(n_ticks=window_ticks, tick_cycles=tick_cycles,
                        shaping=SHAPING_HW, arbiter=ARB_RR, **sim_kw)
        full_cfg = dataclasses.replace(cfg, n_ticks=total_ticks)
        n_full, rem = divmod(total_ticks, window_ticks)
        windows = [(w * window_ticks, cfg) for w in range(n_full)]
        if rem:
            windows.append((n_full * window_ticks,
                            dataclasses.replace(cfg, n_ticks=rem)))
        # -- lifecycle plan --------------------------------------------
        self._sync_layouts()
        self._maybe_repack()
        ev_by_w: dict[int, list[tuple[int, TenantEvent]]] = {}
        for ei, ev in enumerate(events):
            if ev.kind == ARRIVE and ev.spec is None:
                raise ValueError("ARRIVE event needs a spec")
            if ev.kind == DEPART and ev.tenant_id is None:
                raise ValueError("DEPART event needs a tenant_id")
            if ev.kind not in (ARRIVE, DEPART):
                raise ValueError(f"unknown event kind {ev.kind!r}")
            if not 0 <= ev.window < len(windows):
                raise ValueError(
                    f"event window {ev.window} outside the run's "
                    f"{len(windows)} windows")
            ev_by_w.setdefault(ev.window, []).append((ei, ev))
        n_arrive = sum(ev.kind == ARRIVE for ev in events)
        # fixed batch width: widest layout plus one reserve lane per
        # ARRIVE (any server may win any arrival) — the whole timeline
        # then shares one compiled signature
        width = max(len(lanes) for lanes in self._lanes) + n_arrive
        self.last_events = []
        # -- arrival traces --------------------------------------------
        if arrivals is None:
            arrivals = [self._layout_arrivals(b, full_cfg, seeds_l[b],
                                              refs_l[b])
                        for b in range(B)]
        else:
            arrivals = list(arrivals)
            for b, (t, _s) in enumerate(arrivals):
                if t.shape[0] != len(self._lanes[b]):
                    raise ValueError(
                        f"arrivals[{b}] has {t.shape[0]} rows but server "
                        f"{b}'s layout has {len(self._lanes[b])} lanes "
                        "(holes included) — pass traces in lane order")
        M = max(t.shape[1] for t, _ in arrivals)
        # reserve trace columns for event tenants too: an arriving spec
        # can inject faster than any incumbent, and its spliced row must
        # fit the committed [B, width, M] buffers (``sim.trace_budget``
        # caps a flow at ceil(rate * burst_factor * horizon) + 16
        # messages — the burst factor covers registered processes whose
        # peak rate exceeds their mean)
        for ev in events:
            if ev.kind != ARRIVE or ev.spec is None:
                continue
            horizon_s = ((total_ticks - ev.window * window_ticks)
                         * tick_cycles / cfg.clock_hz)
            rate = max(ev.spec.pattern.rate_msgs_per_sec(
                32.0 if ev.ref_gbps is None else ev.ref_gbps), 1e-9)
            M = max(M, sim.trace_budget(ev.spec.pattern, rate, horizon_s))
        arr_t_np = np.full((B, width, M), INF_I32, np.int32)
        arr_sz_np = np.zeros_like(arr_t_np)
        for b, (t, s) in enumerate(arrivals):
            arr_t_np[b, :t.shape[0], :t.shape[1]] = t
            arr_sz_np[b, :s.shape[0], :s.shape[1]] = s
        # one host->device upload of the stacked full-horizon traces;
        # windows (and event splices) then update the committed buffers
        arr_t = jnp.asarray(arr_t_np)
        arr_sz = jnp.asarray(arr_sz_np)
        # -- engine-side tables ----------------------------------------
        atabs = [AccelTable.build(rt.accel_specs, rt.clock_hz)
                 for rt in runtimes]
        links = [rt.link for rt in runtimes]
        flowsets: list = [None] * B
        masks: list = [None] * B
        tbss: list = [None] * B
        carry = None
        prev = None
        reports: list[list] = [[] for _ in range(B)]
        for rt in runtimes:
            rt._prev_counters = None
        # per-server re-pack / rebuild only when that server's previous
        # window committed a register write or path change, or a
        # lifecycle event touched it; when NO server did, the engine
        # resumes the carry without any register rewrite at all
        dirty = [False] * B
        self._in_run = True
        self.control.reset()
        try:
            for w, (t0, wcfg) in enumerate(windows):
                for ei, ev in ev_by_w.get(w, ()):
                    arr_t, arr_sz, carry, touched, spliced = \
                        self._apply_event(ev, ei, t0, full_cfg, seeds_l,
                                          arr_t, arr_sz, carry, width)
                    for b in touched:
                        dirty[b] = True
                    # baseline reset: a recycled lane's device counters
                    # restart from zero (engine.recycle_flow_lane), so
                    # the host-side previous snapshot must too — else the
                    # newcomer's first window delta would go negative /
                    # mix in the departed tenant's totals.  (device_get
                    # snapshots are read-only views; copy-on-write.)
                    if prev is not None:
                        for bb, ll in spliced:
                            for k, v in prev.items():
                                if not v.flags.writeable:
                                    v = prev[k] = v.copy()
                                v[bb, ll] = 0
                for b in range(B):
                    if tbss[b] is None or dirty[b]:
                        flowsets[b], masks[b], tbss[b] = \
                            self._build_lane_args(b, width)
                writes = tbss if (carry is None or any(dirty)
                                  or _force_rebuild) else None
                carry = engine.run_window_batch(
                    flowsets, atabs, links, wcfg, writes, arr_t, arr_sz,
                    t0_ticks=t0, carry=carry, fl_masks=masks)
                host = jax.device_get({k: carry[k]
                                       for k in telemetry.FLEET_POLL_KEYS})
                prev = self._fleet_pass(host, prev, wcfg, t0, reports)
                dirty = [_force_rebuild
                         or bool(reports[b][-1].reconfigured
                                 or reports[b][-1].path_changes)
                         for b in range(B)]
                if w + 1 < len(windows):
                    # control layer: metrics -> policy -> actuation (the
                    # last window has no next window to actuate into; not
                    # deciding there keeps post-run control state — and
                    # StaticHold runs entirely — bitwise)
                    for b, changed in enumerate(
                            self._control_decide(w, wcfg, reports)):
                        if changed:
                            dirty[b] = True
        finally:
            self._in_run = False
        host = jax.device_get({k: carry[k] for k in sim._RESULT_KEYS})
        t0_last, wcfg_last = windows[-1]
        results = []
        for b in range(B):
            el = {k: v[b] for k, v in host.items()}
            for k in sim._PER_FLOW_KEYS:
                el[k] = el[k][:len(self._lanes[b])]
            results.append(sim._collect_result(el, wcfg_last, t0_last))
        return results, reports
