"""Per-flow token-bucket rate limiter (Arcus §4.2), vectorized over flows.

The hardware mechanism pairs a token bucket with each per-flow queue.  Two
parameters are exposed as MMIO registers (`Refill_Rate`, `Bkt_Size`); a
hardware timer adds `Refill_Rate` tokens to the bucket every `Interval`
cycles.  Two shaping modes exist: Gbps (tokens = bytes) and IOPS
(tokens = messages).  This module is the pure-JAX reference used by the
cycle-accurate simulator and the serving scheduler; the Pallas kernel in
``repro.kernels.token_bucket`` implements the same semantics as the
"offloaded hardware" analogue and is validated against this code.

Semantics (exactly what the sim + kernel implement):
  * state: tokens[N] (int64-safe int32 range), cyc[N] residual cycle counter
  * advance by E cycles:  k = (cyc + E) // interval  refills happen,
      tokens <- min(bkt_size, tokens + k * refill_rate)
      cyc    <- (cyc + E) % interval
  * admit(msg_bytes): cost = msg_bytes (GBPS mode) or 1 (IOPS mode);
      admitted iff tokens >= cost; on admit tokens -= cost.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

MODE_GBPS = 0
MODE_IOPS = 1


class TBState(NamedTuple):
    """Vectorized bucket state + parameter 'registers' for N flows."""

    tokens: jax.Array       # [N] int32 current tokens
    cyc: jax.Array          # [N] int32 residual cycles since last refill
    refill_rate: jax.Array  # [N] int32 tokens added per interval ("register")
    bkt_size: jax.Array     # [N] int32 bucket capacity ("register")
    interval: jax.Array     # [N] int32 cycles between refills ("register")
    mode: jax.Array         # [N] int32 MODE_GBPS / MODE_IOPS


def init(refill_rate, bkt_size, interval, mode, start_full: bool = True) -> TBState:
    refill_rate = jnp.asarray(refill_rate, jnp.int32)
    bkt_size = jnp.asarray(bkt_size, jnp.int32)
    interval = jnp.asarray(interval, jnp.int32)
    mode = jnp.asarray(mode, jnp.int32)
    tokens = bkt_size if start_full else jnp.zeros_like(bkt_size)
    return TBState(tokens, jnp.zeros_like(bkt_size), refill_rate, bkt_size,
                   interval, mode)


def advance(state: TBState, elapsed_cycles) -> TBState:
    """Advance hardware timers by `elapsed_cycles`; perform due refills."""
    e = jnp.asarray(elapsed_cycles, jnp.int32)
    total = state.cyc + e
    k = total // state.interval
    cyc = total % state.interval
    # Clamp the number of applied refills so k * refill_rate cannot overflow
    # int32 even after long catch-up stalls: one bucket's worth of refills
    # already saturates the bucket.
    k = jnp.minimum(k, state.bkt_size // jnp.maximum(state.refill_rate, 1) + 1)
    tok = jnp.minimum(state.tokens + k * state.refill_rate, state.bkt_size)
    return state._replace(tokens=tok, cyc=cyc)


def cost_of(state: TBState, msg_bytes) -> jax.Array:
    msg_bytes = jnp.asarray(msg_bytes, jnp.int32)
    return jnp.where(state.mode == MODE_GBPS, msg_bytes, 1).astype(jnp.int32)


def try_admit(state: TBState, msg_bytes, want) -> tuple[TBState, jax.Array]:
    """Attempt to admit one head-of-line message per flow.

    want[N] bool: flow actually has a message to offer.
    Returns (new_state, admitted[N] bool)."""
    cost = cost_of(state, msg_bytes)
    ok = jnp.logical_and(jnp.asarray(want, bool), state.tokens >= cost)
    tok = jnp.where(ok, state.tokens - cost, state.tokens)
    return state._replace(tokens=tok), ok


def consume(state: TBState, amount) -> TBState:
    """Unconditionally consume tokens (used after an arbiter grant)."""
    return state._replace(tokens=state.tokens - jnp.asarray(amount, jnp.int32))


# ---------------------------------------------------------------------------
# Parameter planning (control plane; Arcus Table 2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TBParams:
    refill_rate: int
    bkt_size: int
    interval: int
    mode: int = MODE_GBPS


#: Arcus Table 2 — the paper's published parameter table for Gbps shaping at
#: 250 MHz (tokens = bytes).  Kept verbatim for the reproduction benchmark.
PAPER_TABLE2 = {
    1: TBParams(refill_rate=1024, bkt_size=512, interval=1000),
    10: TBParams(refill_rate=4096, bkt_size=4096, interval=800),
    100: TBParams(refill_rate=16384, bkt_size=65536, interval=320),
    1000: TBParams(refill_rate=32768, bkt_size=1048576, interval=64),
}


def params_for_gbps(slo_gbps: float, clock_hz: float = 250e6, *,
                    bkt_size: int | None = None,
                    max_interval: int = 1024) -> TBParams:
    """Derive (Refill_Rate, Interval, Bkt_Size) for a Gbps SLO.

    Follows the paper's recipe: fix Bkt_Size, then sweep Refill_Rate/Interval
    so that refill_rate / (interval / clock) == target bytes/sec, preferring
    the longest interval that keeps refill_rate in hardware range (the paper
    notes even 1000 Gbps only needs a 64-cycle interval)."""
    target_Bps = slo_gbps * 1e9 / 8.0
    per_cycle = target_Bps / clock_hz  # bytes per cycle
    best = None
    for interval in range(max_interval, 0, -1):
        refill = per_cycle * interval
        if refill < 1:
            continue
        r = int(round(refill))
        err = abs(r / interval - per_cycle) / per_cycle
        if best is None or err < best[0] - 1e-12:
            best = (err, r, interval)
        if err == 0.0:
            break
    assert best is not None, "SLO too small for cycle-level shaping"
    _, refill, interval = best
    if bkt_size is None:
        # Large-ish bucket: insensitive to bursts / size variation (paper §5.2)
        bkt_size = int(max(512, min(1 << 20, 16 * refill)))
    # invariant: a bucket smaller than one refill chunk clips the rate
    bkt_size = max(bkt_size, refill)
    return TBParams(refill, bkt_size, interval, MODE_GBPS)


def params_for_iops(slo_iops: float, clock_hz: float = 250e6, *,
                    burst: int = 64, max_interval: int = 1 << 28) -> TBParams:
    """IOPS mode: tokens are messages.  interval = refill * clock / iops for
    small refills, picking the pair with the least rate error."""
    best = None
    for refill in range(1, 65):
        interval = int(round(refill * clock_hz / slo_iops))
        if interval < 1 or interval > max_interval:
            continue
        err = abs(refill / interval * clock_hz - slo_iops) / slo_iops
        if best is None or err < best[0] - 1e-12:
            best = (err, refill, interval)
        if err == 0.0:
            break
    assert best is not None, (slo_iops, clock_hz)
    _, refill, interval = best
    return TBParams(refill, max(burst, refill), interval, MODE_IOPS)


def achieved_rate(params: TBParams, clock_hz: float = 250e6) -> float:
    """Long-run shaped rate (bytes/s or msgs/s) implied by the registers."""
    return params.refill_rate / params.interval * clock_hz


def pack(params_list: list[TBParams], *, start_full: bool = True) -> TBState:
    """Build a vectorized TBState from per-flow parameter plans."""
    return init(
        np.array([p.refill_rate for p in params_list], np.int32),
        np.array([p.bkt_size for p in params_list], np.int32),
        np.array([p.interval for p in params_list], np.int32),
        np.array([p.mode for p in params_list], np.int32),
        start_full=start_full,
    )
