"""Arcus core: SLO management for accelerators with proactive traffic
shaping (the paper's primary contribution), in JAX.

Layers:
  flow / token_bucket / accelerator / interconnect — abstractions & models
  sim        — cycle-accurate jitted dataplane (lax.scan)
  shaper     — ReshapeDecision: rate pacing + message re-sizing
  profiler   — offline Capacity(t, X, N) tables
  runtime    — Algorithm 1 control plane (admission, capacity, re-shaping)
  placement  — fleet admission placement policies over profiled capacities
  controller — tenant-lifecycle control plane (admit/depart/rebalance/run)
  baselines  — Host_noTS / Host_TS_* / Bypassed_noTS_panic configurations
  policies   — Reserved / OnDemand / ManagedBurst / Opportunistic SLOs
"""
from repro.core.controller import FleetController, TenantEvent
from repro.core.flow import (SLO, FlowSet, FlowSpec, Path, SLOKind,
                             TrafficPattern)
from repro.core.token_bucket import (MODE_GBPS, MODE_IOPS, PAPER_TABLE2,
                                     TBParams, TBState, params_for_gbps,
                                     params_for_iops)

__all__ = [
    "SLO", "FlowSet", "FlowSpec", "Path", "SLOKind", "TrafficPattern",
    "FleetController", "TenantEvent",
    "MODE_GBPS", "MODE_IOPS", "PAPER_TABLE2", "TBParams", "TBState",
    "params_for_gbps", "params_for_iops",
]
