"""Flow abstraction (Arcus §3.3).

Accelerator-related traffic is managed as *flows*, similar to network flows.
Each VM can trigger multiple flows; each physical channel sustains multiple
flows; flows are uni- or bidirectional and ride on a *path* (Arcus §2.2).

This module defines the host-side (python) description of flows and the
Structure-of-Arrays form (`FlowSet`) consumed by the jitted dataplane.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Sequence

import numpy as np

# ---------------------------------------------------------------------------
# Paths (Arcus Fig. 2)
# ---------------------------------------------------------------------------


class Path(enum.IntEnum):
    """Invocation paths. The direction flags encode which half of the
    full-duplex host<->device interconnect each stage of the path consumes
    (Arcus Sec 3.1: CaseP_multi_path exploits duplex; CaseP_same_path does
    not)."""

    FUNCTION_CALL = 0   # loopback: ingress = DMA read (h2d), egress = DMA write (d2h)
    INLINE_NIC_TX = 1   # host -> accel -> wire: ingress h2d, egress off-host (no d2h)
    INLINE_NIC_RX = 2   # wire -> accel -> host: ingress off-host, egress d2h
    INLINE_P2P = 3      # device -> accel -> device (e.g. NVMe): d2h then h2d via root complex


# ingress/egress direction per path: 0 = h2d, 1 = d2h, 2 = off-fabric (free)
PATH_INGRESS_DIR = {
    Path.FUNCTION_CALL: 0,
    Path.INLINE_NIC_TX: 0,
    Path.INLINE_NIC_RX: 2,
    Path.INLINE_P2P: 1,
}
PATH_EGRESS_DIR = {
    Path.FUNCTION_CALL: 1,
    Path.INLINE_NIC_TX: 2,
    Path.INLINE_NIC_RX: 1,
    Path.INLINE_P2P: 0,
}


# ---------------------------------------------------------------------------
# Traffic patterns (Arcus §2.2 "Diverse traffic pattern combinations")
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TrafficPattern:
    """A tenant's injection pattern: message size x injection process.

    ``load`` follows the paper's Table 1 convention: fraction of the line
    rate the traffic generator injects at (0.1 ... 0.9).  When ``rate_mps``
    (messages per second) is given it overrides load-based derivation.
    """

    msg_bytes: int = 1024
    load: float = 0.5
    rate_mps: float | None = None
    # any name in the sim's arrival-process registry (``cbr``, ``poisson``
    # and ``onoff`` ship built-in; ``repro.workloads.generators`` registers
    # the production-shaped set: mmpp, heavytail, diurnal, corrburst,
    # flash, adversarial)
    process: str = "cbr"
    # onoff: bursts of `burst_len` back-to-back msgs separated by idle gaps.
    burst_len: int = 32
    duty: float = 0.25
    # bimodal: alternate msg sizes (secondary size, probability)
    msg_bytes2: int = 0
    p2: float = 0.0
    # extra (name, value) pairs for registered processes that need knobs
    # beyond the fields above (MMPP state rates, Pareto shape, diurnal
    # period, ...).  A tuple of pairs keeps the dataclass frozen/hashable;
    # the empty default leaves every existing pattern bit-identical.
    params: tuple = ()

    def rate_msgs_per_sec(self, line_gbps: float) -> float:
        if self.rate_mps is not None:
            return self.rate_mps
        line_bps = line_gbps * 1e9 / 8.0
        return self.load * line_bps / max(self.msg_bytes, 1)

    def param(self, name: str, default=None):
        """Look up one ``params`` knob by name (first match wins)."""
        for k, v in self.params:
            if k == name:
                return v
        return default


# ---------------------------------------------------------------------------
# SLOs (Arcus §1: a precise performance number + low variance @ percentile)
# ---------------------------------------------------------------------------


class SLOKind(enum.IntEnum):
    GBPS = 0
    IOPS = 1
    LATENCY = 2  # tail-latency bound (used by use-case 2)


@dataclasses.dataclass(frozen=True)
class SLO:
    kind: SLOKind
    target: float              # Gbps, IOPS, or seconds depending on kind
    percentile: float = 99.0   # availability percentile of the guarantee

    @staticmethod
    def gbps(target: float, percentile: float = 99.0) -> "SLO":
        return SLO(SLOKind.GBPS, target, percentile)

    @staticmethod
    def iops(target: float, percentile: float = 99.0) -> "SLO":
        return SLO(SLOKind.IOPS, target, percentile)

    @staticmethod
    def latency(bound_s: float, percentile: float = 99.0) -> "SLO":
        return SLO(SLOKind.LATENCY, bound_s, percentile)


# ---------------------------------------------------------------------------
# Flow spec
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FlowSpec:
    flow_id: int
    vm_id: int
    path: Path
    accel_id: int
    pattern: TrafficPattern
    slo: SLO
    priority: int = 0          # higher = more important (PANIC baseline uses this)
    weight: float = 1.0        # WRR/WFQ weight
    # per-tenant resource-demand hints: ((resource_name, per_ingress_byte,
    # per_egress_byte), ...) overriding the accelerator's derived demand on
    # that axis for THIS flow (a tenant that declares its workload is
    # compute-bound, say).  Hints re-key the flow's profiling contexts; the
    # empty default keeps every context key bitwise-stable.
    res_demand: tuple = ()


@dataclasses.dataclass
class FlowSet:
    """SoA view of a set of flows, ready to feed the jitted dataplane."""

    n: int
    vm_id: np.ndarray          # [N] int32
    path: np.ndarray           # [N] int32
    ingress_dir: np.ndarray    # [N] int32 (0 h2d, 1 d2h, 2 off-fabric)
    egress_dir: np.ndarray     # [N] int32
    accel_id: np.ndarray       # [N] int32
    priority: np.ndarray       # [N] int32
    weight: np.ndarray         # [N] float32
    slo_kind: np.ndarray       # [N] int32
    slo_target: np.ndarray     # [N] float32
    specs: Sequence[FlowSpec] = dataclasses.field(default_factory=list)

    @staticmethod
    def build(specs: Sequence[FlowSpec]) -> "FlowSet":
        n = len(specs)
        return FlowSet(
            n=n,
            vm_id=np.array([s.vm_id for s in specs], np.int32),
            path=np.array([int(s.path) for s in specs], np.int32),
            ingress_dir=np.array([PATH_INGRESS_DIR[s.path] for s in specs], np.int32),
            egress_dir=np.array([PATH_EGRESS_DIR[s.path] for s in specs], np.int32),
            accel_id=np.array([s.accel_id for s in specs], np.int32),
            priority=np.array([s.priority for s in specs], np.int32),
            weight=np.array([s.weight for s in specs], np.float32),
            slo_kind=np.array([int(s.slo.kind) for s in specs], np.int32),
            slo_target=np.array([s.slo.target for s in specs], np.float32),
            specs=list(specs),
        )
