"""Baseline system configurations (Arcus §5.1 "Configurations").

Each baseline is expressed as (shaping mode, arbiter, stall process) knobs of
the same dataplane, exactly as the paper builds them on the same testbed:

* Host_noTS            — kernel-bypass host access, weighted-round-robin
                         arbitration on the device, no traffic shaping.
* Host_TS_firecracker  — on-host software shaping (Firecracker-style token
                         buckets in the VMM); suffers timer jitter + CPU
                         interference.
* Host_TS_reflex       — on-host software shaping (ReFlex-style request-level
                         pacing); same pathology, slightly tighter timers.
* Bypassed_noTS_panic  — hypervisor-bypassed PANIC interface: priority +
                         weighted-fair queuing, reactive, no shaping.
* Arcus                — hardware per-flow token buckets + RR, proactive.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import token_bucket as tb
from repro.core.interconnect import ARB_PRIORITY, ARB_RR, ARB_WRR
from repro.core.sim import (SHAPING_HW, SHAPING_NONE, SHAPING_SW, SimConfig,
                            gen_stall_mask, simulate_batch, stack_arrivals)


@dataclasses.dataclass(frozen=True)
class SystemConfig:
    name: str
    shaping: int
    arbiter: int
    sw_host_delay_cycles: int = 0
    sw_jitter_cycles: int = 0
    stall_rate_hz: float = 0.0          # host-desched events per second
    stall_us: tuple[float, float] = (2.0, 40.0)


HOST_NO_TS = SystemConfig("Host_noTS", SHAPING_NONE, ARB_WRR)
# VM CPU contention regime (Sec. 5.2: "CPU processing of VMs leads to
# imprecise software token buckets and software timers and unpredictable
# execution times"): shaping threads lose the core for scheduler-quantum
# scale bursts; per-message host processing adds jittered delay.
HOST_TS_FIRECRACKER = SystemConfig(
    "Host_TS_firecracker", SHAPING_SW, ARB_RR,
    sw_host_delay_cycles=650, sw_jitter_cycles=3000,   # ~2.6us + up to 12us
    stall_rate_hz=150.0, stall_us=(80.0, 600.0))
HOST_TS_REFLEX = SystemConfig(
    "Host_TS_reflex", SHAPING_SW, ARB_RR,
    sw_host_delay_cycles=450, sw_jitter_cycles=2500,   # ~1.8us + up to 10us
    stall_rate_hz=250.0, stall_us=(30.0, 300.0))
BYPASSED_NO_TS_PANIC = SystemConfig("Bypassed_noTS_panic", SHAPING_NONE,
                                    ARB_PRIORITY)
ARCUS = SystemConfig("Arcus", SHAPING_HW, ARB_RR)

ALL = {c.name: c for c in (HOST_NO_TS, HOST_TS_FIRECRACKER, HOST_TS_REFLEX,
                           BYPASSED_NO_TS_PANIC, ARCUS)}


def make_sim_config(sys_cfg: SystemConfig, n_ticks: int, **overrides
                    ) -> SimConfig:
    return SimConfig(
        n_ticks=n_ticks,
        shaping=sys_cfg.shaping,
        arbiter=sys_cfg.arbiter,
        sw_host_delay_cycles=sys_cfg.sw_host_delay_cycles or 500,
        sw_jitter_cycles=sys_cfg.sw_jitter_cycles or 2500,
        **overrides,
    )


def make_stall_mask(sys_cfg: SystemConfig, cfg: SimConfig, *, seed: int = 1,
                    total_ticks: int | None = None) -> np.ndarray | None:
    if sys_cfg.shaping != SHAPING_SW or sys_cfg.stall_rate_hz <= 0:
        return None
    n = total_ticks or cfg.n_ticks
    base = dataclasses.replace(cfg, n_ticks=n)
    return gen_stall_mask(base, seed=seed, stall_rate_hz=sys_cfg.stall_rate_hz,
                          stall_us=sys_cfg.stall_us)


def run_system_batch(systems, flows, accels, link, n_ticks: int, *,
                     tb_states, arr, stall_seed: int = 1,
                     cfg_overrides: dict | None = None):
    """Run several baseline *systems* over the same scenario as ONE
    vmap-batched compiled engine call.

    Shaping mode, arbiter and the software-delay model are traced engine
    inputs, so Arcus and its Host/Bypassed baselines (Sec. 5.1) — which
    differ only in those knobs — batch into a single executable instead of
    one compile-bound serial ``simulate`` per system.

    * ``systems``: sequence of SystemConfig (or names into ``ALL``);
    * ``tb_states``: per-system TBState registers;
    * ``arr``: one shared (times, sizes) trace, or a per-system sequence;
    * SW systems get their stall process generated here ([B, T] mask).

    Returns ``list[SimResult]``, one per system, each bitwise-identical to
    a serial run of that system."""
    systems = [ALL[s] if isinstance(s, str) else s for s in systems]
    cfgs = [make_sim_config(s, n_ticks, **(cfg_overrides or {}))
            for s in systems]
    arrs = list(arr) if isinstance(arr, (list, tuple)) \
        and isinstance(arr[0], (list, tuple)) else [arr] * len(systems)
    stall = None
    masks = [make_stall_mask(s, c, seed=stall_seed)
             for s, c in zip(systems, cfgs)]
    if any(m is not None for m in masks):
        stall = np.stack([m if m is not None else np.zeros(n_ticks, bool)
                          for m in masks])
    return simulate_batch(flows, accels, link, cfgs, list(tb_states),
                          *stack_arrivals(arrs), stall_mask=stall)


def make_tb_state(sys_cfg: SystemConfig, plans: list[tb.TBParams],
                  *, clock_hz: float = 250e6) -> tb.TBState:
    """Token-bucket registers for a system.  Non-shaping systems get
    effectively-infinite buckets (transparent gate).  Software shapers get
    enlarged buckets (~5 ms of tokens): timestamp-based catch-up after a
    missed timer releases the deferred tokens in a burst — the
    over-provisioning pathology of Table 3."""
    n = len(plans)
    big = 2**30
    if sys_cfg.shaping == SHAPING_NONE:
        return tb.init(np.full(n, big, np.int32), np.full(n, big, np.int32),
                       np.ones(n, np.int32), np.zeros(n, np.int32))
    if sys_cfg.shaping == SHAPING_SW:
        plans = [
            dataclasses.replace(
                p, bkt_size=max(p.bkt_size,
                                int(tb.achieved_rate(p, clock_hz) * 2e-3)))
            for p in plans
        ]
        # software buckets start empty: tokens exist only once the timer
        # thread has run (and its catch-up bursts are the pathology)
        return tb.pack(plans, start_full=False)
    return tb.pack(plans)
