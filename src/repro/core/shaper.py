"""Traffic-shaping decisions (Arcus §4.1-4.2).

The shaper's two levers (Sec. 2.2 "Basics of traffic shaping"):
  1. rate limiting   — token-bucket registers, planned by the control plane;
  2. message re-sizing — "Messages can be re-sized by splitting the payloads
     and duplicating another message header."

`ReshapeDecision` combines both: given a flow's SLO and the accelerator's
heterogeneity profile, pick (a) the token-bucket parameters for the target
rate (with ingress-rate inflation when the accelerator's egress/ingress
ratio R != 1) and (b) an optimal message size for the accelerator curve.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import token_bucket as tb
from repro.core.accelerator import AcceleratorSpec, size_grid
from repro.core.flow import SLO, SLOKind


@dataclasses.dataclass(frozen=True)
class ShapeDecision:
    params: tb.TBParams
    resize_to: int | None = None   # split messages larger than this
    note: str = ""


def optimal_msg_bytes(accel: AcceleratorSpec, lo: int = 256,
                      hi: int = 65536) -> int:
    """Smallest message size achieving >=95% of the accelerator's peak —
    large enough to be efficient, small enough to keep shaping granular."""
    grid = size_grid()
    grid = grid[(grid >= lo) & (grid <= hi)]
    tput = accel.throughput_gbps(grid)
    good = grid[tput >= 0.95 * tput.max()]
    return int(good.min()) if len(good) else int(grid[-1])


def ingress_rate_for_slo(accel: AcceleratorSpec, slo: SLO,
                         msg_bytes: int) -> float:
    """Gbps of *ingress* needed so the SLO is met at the accelerator.

    Heterogeneity-aware (Sec. 5.3.1): a compression SLO of X Gbps needs
    ingress X (input-defined); but if the SLO is on the *egress* side of a
    decompressor, ingress is X / R.  We follow the paper's convention that
    throughput SLOs are defined on the accelerator's input stream, except
    for R_EXPAND where the deliverable is the expanded output."""
    if slo.kind == SLOKind.IOPS:
        return slo.target * msg_bytes * 8 / 1e9
    if slo.kind == SLOKind.GBPS:
        if accel.r_kind == "expand":
            return slo.target / max(accel.r_value, 1e-6)
        return slo.target
    raise ValueError("latency SLOs are enforced by admission, not pacing")


def reshape_decision(accel: AcceleratorSpec, slo: SLO, msg_bytes: int,
                     *, clock_hz: float = 250e6,
                     headroom: float = 1.0) -> ShapeDecision:
    """The ReshapeDecision() of Algorithm 1 (line 20)."""
    if slo.kind == SLOKind.LATENCY:
        # a latency SLO is enforced by shaping *others* (Sec. 4.3): the
        # flow's own bucket is a generous device-speed allowance, not a
        # pacing rate — it must never be the thing queueing messages
        params = tb.params_for_gbps(accel.peak_gbps * max(headroom, 1.0),
                                    clock_hz)
        return ShapeDecision(params, None,
                             "latency SLO: device-speed allowance")
    note = []
    resize = None
    eff_msg = msg_bytes
    opt = 2 * optimal_msg_bytes(accel)  # comfortably on the flat part
    if msg_bytes > 4 * opt:
        # huge messages monopolize PCIe + accel queues (use case 1) — split
        resize = opt
        eff_msg = opt
        note.append(f"split {msg_bytes}B -> {opt}B")
    if slo.kind == SLOKind.IOPS:
        params = tb.params_for_iops(slo.target * headroom, clock_hz)
    else:
        gbps = ingress_rate_for_slo(accel, slo, eff_msg) * headroom
        params = tb.params_for_gbps(gbps, clock_hz)
        note.append(f"ingress {gbps:.2f} Gbps for SLO {slo.target}")
    if resize is not None:
        # split streams must also be paced smoothly: a few chunks of burst,
        # not a whole original message's worth
        import dataclasses as _dc
        params = _dc.replace(
            params, bkt_size=max(params.refill_rate, 4 * resize))
    return ShapeDecision(params, resize, "; ".join(note))


def reshape_trace(times: np.ndarray, sizes: np.ndarray, max_bytes: int
                  ) -> tuple[np.ndarray, np.ndarray]:
    """Split oversized messages in an arrival trace (payload split +
    duplicated header).  Host-side helper mirroring what the hardware does
    on the fly."""
    out_t, out_s = [], []
    for t, s in zip(times.ravel(), sizes.ravel()):
        if s <= 0:
            continue
        if s <= max_bytes:
            out_t.append(t)
            out_s.append(s)
        else:
            k = int(np.ceil(s / max_bytes))
            for j in range(k):
                out_t.append(t)
                out_s.append(min(max_bytes, s - j * max_bytes))
    order = np.argsort(np.asarray(out_t), kind="stable")
    return (np.asarray(out_t)[order].astype(np.int32),
            np.asarray(out_s)[order].astype(np.int32))
