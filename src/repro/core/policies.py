"""User-facing SLO policies (Arcus §6 "Enabling accelerator SLO policies").

Each policy maps to token-bucket register plans + admission attributes:

* Reserved      — exact pacing at the committed rate, admission-guaranteed
                  (capacity is debited for the full term).
* OnDemand      — exact pacing while admitted; admission may be rejected when
                  capacity is short (99% availability, short commitments).
* ManagedBurst  — base rate X with bursting to ``burst_x``*X for up to
                  ``burst_s`` seconds per day: a token bucket whose Bkt_Size
                  holds the entire burst budget while Refill_Rate sustains X.
* Opportunistic — no guarantee; unshaped but lowest arbiter weight, harvests
                  leftover capacity (the paper's LM / background example).
"""
from __future__ import annotations

import dataclasses

from repro.core import token_bucket as tb
from repro.core.flow import SLO, SLOKind


@dataclasses.dataclass(frozen=True)
class PolicyPlan:
    params: tb.TBParams
    admission_guaranteed: bool
    capacity_debit_gbps: float
    weight: float = 1.0
    priority: int = 0


def plan_reserved(slo: SLO, msg_bytes: int = 1024,
                  clock_hz: float = 250e6) -> PolicyPlan:
    params = _pace(slo, clock_hz)
    return PolicyPlan(params, True, _gbps_of(slo, msg_bytes), weight=1.0,
                      priority=2)


def plan_on_demand(slo: SLO, msg_bytes: int = 1024,
                   clock_hz: float = 250e6) -> PolicyPlan:
    params = _pace(slo, clock_hz)
    return PolicyPlan(params, False, _gbps_of(slo, msg_bytes), weight=1.0,
                      priority=1)


def plan_managed_burst(slo: SLO, *, burst_x: float = 10.0,
                       burst_s: float = 0.001, msg_bytes: int = 1024,
                       clock_hz: float = 250e6) -> PolicyPlan:
    base = _pace(slo, clock_hz)
    if slo.kind == SLOKind.GBPS:
        burst_tokens = int(slo.target * (burst_x - 1) * 1e9 / 8 * burst_s)
    else:
        burst_tokens = int(slo.target * (burst_x - 1) * burst_s)
    params = tb.TBParams(base.refill_rate,
                         max(base.bkt_size, burst_tokens),
                         base.interval, base.mode)
    # capacity planning must budget the burst, not the base (Sec. 4.3)
    return PolicyPlan(params, True, _gbps_of(slo, msg_bytes) * burst_x,
                      weight=1.0, priority=1)


def plan_opportunistic(clock_hz: float = 250e6) -> PolicyPlan:
    big = 2**30
    params = tb.TBParams(big, big, 1, tb.MODE_GBPS)
    return PolicyPlan(params, False, 0.0, weight=0.05, priority=0)


def _pace(slo: SLO, clock_hz: float) -> tb.TBParams:
    if slo.kind == SLOKind.IOPS:
        return tb.params_for_iops(slo.target, clock_hz)
    return tb.params_for_gbps(slo.target, clock_hz)


def _gbps_of(slo: SLO, msg_bytes: int) -> float:
    if slo.kind == SLOKind.GBPS:
        return slo.target
    if slo.kind == SLOKind.IOPS:
        return slo.target * msg_bytes * 8 / 1e9
    return 0.0
