"""Communication-resource model (Arcus §2.2, §3.1 communication-related
contention).

Models the insufficiently-isolated components the paper identifies:
  * a full-duplex host<->device interconnect (PCIe Gen 3.0 x8 in the paper's
    prototype) with independent per-direction capacity,
  * a root-complex / shared-buffer credit pool drained by in-flight messages,
  * the arbiter that multiplexes flows onto the interconnect (round-robin /
    weighted RR / weighted-fair / strict priority) — the PANIC-style
    interface of the baselines.

Capacities are expressed as bytes-per-cycle so the jitted dataplane can work
in integer cycle time.
"""
from __future__ import annotations

import dataclasses

import numpy as np

ARB_RR = 0
ARB_WRR = 1
ARB_PRIORITY = 2
ARB_WFQ = 3

#: well-known resource-axis names (axis 0 is always the link itself)
RES_LINK = "link"
RES_MEM_BW = "mem_bw"
RES_HOST_DMA = "host_dma"


@dataclasses.dataclass(frozen=True)
class ResourceSpec:
    """One contended resource axis *beyond* the link itself (HW-QoS survey
    dimensions: device memory bandwidth, host/PCIe DMA engines, ...).

    The link stays axis 0 of the resource vector with its own full-duplex
    budget machinery (``LinkSpec``); each ``ResourceSpec`` adds a pooled
    axis the dataplane charges per granted/egressed byte.  The shaping
    knob is a token bucket on the axis itself: ``capacity_gbps`` is the
    refill rate, ``burst_bytes`` the bucket depth (unused budget carried
    forward, 0 = lose idle capacity exactly like the link does).

    ``fabric_only`` axes (host DMA engines) charge only bytes that
    actually cross the host fabric — an off-fabric direction (wire-side
    ingress/egress of the inline paths) is free.
    """

    name: str
    capacity_gbps: float
    burst_bytes: int = 0
    fabric_only: bool = False

    def bytes_per_cycle(self, clock_hz: float) -> float:
        return self.capacity_gbps * 1e9 / 8.0 / clock_hz


def mem_bw(capacity_gbps: float, burst_bytes: int = 0) -> ResourceSpec:
    """Device-memory-bandwidth axis (every byte an accelerator reads or
    writes crosses it)."""
    return ResourceSpec(RES_MEM_BW, capacity_gbps, burst_bytes)


def host_dma(capacity_gbps: float, burst_bytes: int = 0) -> ResourceSpec:
    """Host/PCIe DMA-engine axis — pooled across both directions, charged
    only for bytes that cross the host fabric."""
    return ResourceSpec(RES_HOST_DMA, capacity_gbps, burst_bytes,
                        fabric_only=True)


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """Full-duplex interconnect + credit pool — axis 0 of the host's
    contended-resource vector, optionally extended with more axes.

    Defaults model PCIe Gen 3.0 x8: 7.88 GB/s raw per direction; effective
    payload bandwidth ~85% after TLP overheads (the paper's CaseP_multi_path
    reaches 85% of ideal).

    ``resources`` lists the additional shaped axes (``ResourceSpec``): an
    empty tuple (the default) is the scalar R=1 degenerate case and is
    bitwise-identical to the pre-vector engine.
    """

    h2d_gbps: float = 63.0       # Gbit/s per direction (Gen3 x8)
    d2h_gbps: float = 63.0
    efficiency: float = 0.85
    clock_hz: float = 250e6
    credits: int = 64            # root-complex buffer credits (in-flight msgs)
    mtu_bytes: int = 4096        # max TLP burst granted per flow per round
    # per-message fabric overhead (descriptor fetch + doorbell + TLP headers
    # + completion): the reason 64B messages see a fraction of line rate
    # (Sec. 3.1 communication-related inaccuracy).
    msg_overhead_bytes: int = 100
    # additional contended axes beyond the link (R-1 of them; R=1 when empty)
    resources: tuple = ()

    def __post_init__(self):
        # lists are a natural way to hand resources in; keep the spec
        # hashable (profiling groups / compile keys) by storing a tuple
        if not isinstance(self.resources, tuple):
            object.__setattr__(self, "resources", tuple(self.resources))

    def bytes_per_cycle(self) -> tuple[float, float]:
        h2d = self.h2d_gbps * self.efficiency * 1e9 / 8.0 / self.clock_hz
        d2h = self.d2h_gbps * self.efficiency * 1e9 / 8.0 / self.clock_hz
        return h2d, d2h

    @property
    def n_resources(self) -> int:
        """R: the link itself plus every extra axis."""
        return 1 + len(self.resources)

    def resource_caps_per_cycle(self) -> np.ndarray:
        """[R-1] bytes-per-cycle capacities of the extra axes."""
        return np.asarray([r.bytes_per_cycle(self.clock_hz)
                           for r in self.resources], np.float32)

    def resource_burst_bytes(self) -> np.ndarray:
        """[R-1] token-bucket depths (bytes of unused budget carried)."""
        return np.asarray([r.burst_bytes for r in self.resources],
                          np.float32)


def arbiter_weights(kind: int, n: int, weight: np.ndarray,
                    priority: np.ndarray) -> np.ndarray:
    """Static per-flow service quanta for the arbiters used by baselines.

    Returns [N] float32 'quantum' multipliers: the relative share of link
    budget a flow may claim per round. RR = equal; WRR/WFQ = by weight;
    PRIORITY = lexicographic (modeled as exponential weighting, which is how
    strict priority behaves under saturation).
    """
    if kind == ARB_RR:
        w = np.ones(n)
    elif kind in (ARB_WRR, ARB_WFQ):
        w = np.asarray(weight, np.float64).copy()
    elif kind == ARB_PRIORITY:
        p = np.asarray(priority, np.float64)
        w = 16.0 ** (p - p.min())
    else:
        raise ValueError(kind)
    w = w / w.sum()
    return w.astype(np.float32)
