"""Communication-resource model (Arcus §2.2, §3.1 communication-related
contention).

Models the insufficiently-isolated components the paper identifies:
  * a full-duplex host<->device interconnect (PCIe Gen 3.0 x8 in the paper's
    prototype) with independent per-direction capacity,
  * a root-complex / shared-buffer credit pool drained by in-flight messages,
  * the arbiter that multiplexes flows onto the interconnect (round-robin /
    weighted RR / weighted-fair / strict priority) — the PANIC-style
    interface of the baselines.

Capacities are expressed as bytes-per-cycle so the jitted dataplane can work
in integer cycle time.
"""
from __future__ import annotations

import dataclasses

import numpy as np

ARB_RR = 0
ARB_WRR = 1
ARB_PRIORITY = 2
ARB_WFQ = 3


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """Full-duplex interconnect + credit pool.

    Defaults model PCIe Gen 3.0 x8: 7.88 GB/s raw per direction; effective
    payload bandwidth ~85% after TLP overheads (the paper's CaseP_multi_path
    reaches 85% of ideal).
    """

    h2d_gbps: float = 63.0       # Gbit/s per direction (Gen3 x8)
    d2h_gbps: float = 63.0
    efficiency: float = 0.85
    clock_hz: float = 250e6
    credits: int = 64            # root-complex buffer credits (in-flight msgs)
    mtu_bytes: int = 4096        # max TLP burst granted per flow per round
    # per-message fabric overhead (descriptor fetch + doorbell + TLP headers
    # + completion): the reason 64B messages see a fraction of line rate
    # (Sec. 3.1 communication-related inaccuracy).
    msg_overhead_bytes: int = 100

    def bytes_per_cycle(self) -> tuple[float, float]:
        h2d = self.h2d_gbps * self.efficiency * 1e9 / 8.0 / self.clock_hz
        d2h = self.d2h_gbps * self.efficiency * 1e9 / 8.0 / self.clock_hz
        return h2d, d2h


def arbiter_weights(kind: int, n: int, weight: np.ndarray,
                    priority: np.ndarray) -> np.ndarray:
    """Static per-flow service quanta for the arbiters used by baselines.

    Returns [N] float32 'quantum' multipliers: the relative share of link
    budget a flow may claim per round. RR = equal; WRR/WFQ = by weight;
    PRIORITY = lexicographic (modeled as exponential weighting, which is how
    strict priority behaves under saturation).
    """
    if kind == ARB_RR:
        w = np.ones(n)
    elif kind in (ARB_WRR, ARB_WFQ):
        w = np.asarray(weight, np.float64).copy()
    elif kind == ARB_PRIORITY:
        p = np.asarray(priority, np.float64)
        w = 16.0 ** (p - p.min())
    else:
        raise ValueError(kind)
    w = w / w.sum()
    return w.astype(np.float32)
