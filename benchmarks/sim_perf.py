"""Simulation-engine performance: compile cache, donated carries, batching.

Tracks the harness-speed trajectory of the compiled dataplane engine
(repro.core.engine) — the numbers that decide whether the paper-scale
experiments (100-window managed runs, multi-seed CDF sweeps) run in seconds
or in minutes:

  sim_perf/cold_compile   — first simulate() call: trace + XLA compile + run
  sim_perf/cached_rerun   — same-signature re-invocation (pure execution);
                            speedup_x = cold / cached is the headline
  sim_perf/managed_10w    — ArcusRuntime.run_managed over 10 windows with a
                            register write every window; `traces` proves the
                            tick scan compiled exactly once
  sim_perf/batch8         — simulate_batch over 8 seeds in one vmap call vs
                            8 serial simulate() calls
  sim_perf/grant_vec      — vectorized RR grant fast path vs the sequential
                            argmin loop (16 flows, 8-wide grants)
  sim_perf/stage_vec      — vectorized accelerator-service + egress stages
                            (prefix-sum slot assignment) vs the sequential
                            per-iteration loops
  sim_perf/profile_batch8 — ProfileTable.profile_contexts over 8
                            heterogeneous contexts (ragged flow counts,
                            mixed accelerators) as ONE compiled engine
                            call vs 8 serial profile_context() runs; the
                            engine cache stats assert exactly one
                            compiled call was issued
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import Row, Timer, save_json, us_per_tick
from repro.core import engine, token_bucket as tb
from repro.core.accelerator import CATALOG, AccelTable
from repro.core.flow import SLO, FlowSet, FlowSpec, Path, TrafficPattern
from repro.core.interconnect import LinkSpec
from repro.core.profiler import ProfileTable
from repro.core.runtime import ArcusRuntime
from repro.core.sim import (SHAPING_HW, SHAPING_NONE, SimConfig,
                            gen_arrivals, simulate, simulate_batch,
                            stack_arrivals)


def _scenario(n_flows: int, n_ticks: int, *, shaping=SHAPING_HW,
              k_grant: int = 4, grant_fast: bool = True, seed: int = 0):
    slo = 40.0 / n_flows
    specs = [FlowSpec(i, i, Path.FUNCTION_CALL, 0,
                      TrafficPattern(1024, load=0.9 / n_flows,
                                     process="poisson"), SLO.gbps(slo))
             for i in range(n_flows)]
    flows = FlowSet.build(specs)
    cfg = SimConfig(n_ticks=n_ticks, shaping=shaping, k_grant=k_grant,
                    grant_fast=grant_fast)
    arr = gen_arrivals(flows, cfg, seed=seed,
                       load_ref_gbps={i: 55.0 for i in range(n_flows)})
    if shaping == SHAPING_HW:
        tbs = tb.pack([tb.params_for_gbps(slo)] * n_flows)
    else:
        big = np.full(n_flows, 2**30, np.int32)
        tbs = tb.init(big, big, np.ones(n_flows, np.int32),
                      np.zeros(n_flows, np.int32))
    accels = AccelTable.build([CATALOG["synthetic50"]])
    return flows, accels, LinkSpec(), cfg, tbs, arr


def run(quick: bool = False) -> list[Row]:
    # the run_managed per-window regime this engine optimizes
    window = 2_000 if quick else 5_000
    rows, payload = [], {}

    # -- cold vs cached -------------------------------------------------
    engine.cache_clear()
    flows, accels, link, cfg, tbs, arr = _scenario(4, window)
    with Timer() as t_cold:
        simulate(flows, accels, link, cfg, tbs, *arr)
    with Timer() as t_warm:
        simulate(flows, accels, link, cfg, tbs, *arr)
    speedup = t_cold.s / max(t_warm.s, 1e-9)
    rows.append(Row("sim_perf/cold_compile", us_per_tick(t_cold.s, window),
                    dict(wall_s=t_cold.s)))
    rows.append(Row("sim_perf/cached_rerun", us_per_tick(t_warm.s, window),
                    dict(wall_s=t_warm.s, speedup_x=speedup,
                         traces=engine.cache_info()["traces"])))

    # -- managed 10-window loop ----------------------------------------
    rt = ArcusRuntime([CATALOG["synthetic50"]])
    for i, slo in enumerate((10.0, 20.0)):
        rt.register(FlowSpec(i, i, Path.FUNCTION_CALL, 0,
                             TrafficPattern(1024, load=0.45), SLO.gbps(slo)))
    engine.cache_clear()
    with Timer() as t_mng:
        rt.run_managed(total_ticks=10 * window, window_ticks=window,
                       load_ref_gbps={0: 32.0, 1: 32.0})
    info = engine.cache_info()
    rows.append(Row("sim_perf/managed_10w",
                    us_per_tick(t_mng.s, 10 * window),
                    dict(wall_s=t_mng.s, windows=10,
                         entries=info["entries"], traces=info["traces"])))

    # -- batch over 8 seeds ---------------------------------------------
    # fairness: serial calls get the same padded traces the batch uses, so
    # all eight share one compiled engine (without padding every seed's
    # trace length differs and each serial call would recompile — exactly
    # the pathology simulate_batch removes wholesale)
    seeds = list(range(8))
    arrs = []
    for s in seeds:
        _, _, _, _, _, a = _scenario(4, window, seed=s)
        arrs.append(a)
    arr_b = stack_arrivals(arrs)
    per_seed = [(arr_b[0][b], arr_b[1][b]) for b in range(len(seeds))]
    with Timer() as t_ser_cold:       # includes the one serial compile
        serial = [simulate(flows, accels, link, cfg, tbs, *a)
                  for a in per_seed]
    with Timer() as t_bat_cold:       # includes the one batch compile
        batch = simulate_batch(flows, accels, link, cfg,
                               [tbs] * len(seeds), *arr_b)
    with Timer() as t_ser:            # warm
        serial = [simulate(flows, accels, link, cfg, tbs, *a)
                  for a in per_seed]
    with Timer() as t_bat:            # warm
        batch = simulate_batch(flows, accels, link, cfg,
                               [tbs] * len(seeds), *arr_b)
    match = all(
        np.array_equal(np.asarray(s.counters[k]), np.asarray(b.counters[k]))
        for s, b in zip(serial, batch)
        for k in ("c_adm_msgs", "c_done_msgs", "c_drops"))
    rows.append(Row("sim_perf/batch8",
                    us_per_tick(t_bat.s, 8 * window),
                    dict(wall_s=t_bat.s, serial_wall_s=t_ser.s,
                         speedup_vs_serial_x=t_ser.s / max(t_bat.s, 1e-9),
                         cold_wall_s=t_bat_cold.s,
                         serial_cold_wall_s=t_ser_cold.s,
                         counters_match_serial=bool(match))))

    # -- vectorized grant fast path vs sequential ------------------------
    n_ticks_g = 4 * window
    fl, ac, lk, cf, tg, ag = _scenario(16, n_ticks_g, shaping=SHAPING_NONE,
                                       k_grant=8, grant_fast=True)
    cf_seq = dataclasses.replace(cf, grant_fast=False)
    simulate(fl, ac, lk, cf, tg, *ag)          # compile both variants
    simulate(fl, ac, lk, cf_seq, tg, *ag)
    with Timer() as t_fast:
        r_fast = simulate(fl, ac, lk, cf, tg, *ag)
    with Timer() as t_seq:
        r_seq = simulate(fl, ac, lk, cf_seq, tg, *ag)
    g_match = all(
        np.array_equal(np.asarray(r_fast.counters[k]),
                       np.asarray(r_seq.counters[k]))
        for k in ("c_adm_msgs", "c_done_msgs", "c_drops"))
    rows.append(Row("sim_perf/grant_vec",
                    us_per_tick(t_fast.s, n_ticks_g),
                    dict(seq_us_per_tick=us_per_tick(t_seq.s, n_ticks_g),
                         speedup_x=t_seq.s / max(t_fast.s, 1e-9),
                         counters_match_seq=bool(g_match))))

    # -- vectorized service + egress stages vs sequential loops ----------
    # k_srv=8 crosses the service-stage width threshold (A * k_srv >= 8)
    cf_sv = dataclasses.replace(cf, stage_fast=True, k_srv=8, k_eg=8)
    cf_ss = dataclasses.replace(cf_sv, stage_fast=False)
    simulate(fl, ac, lk, cf_sv, tg, *ag)       # compile both variants
    simulate(fl, ac, lk, cf_ss, tg, *ag)
    with Timer() as t_sv:
        r_sv = simulate(fl, ac, lk, cf_sv, tg, *ag)
    with Timer() as t_ss:
        r_ss = simulate(fl, ac, lk, cf_ss, tg, *ag)
    s_match = all(
        np.array_equal(np.asarray(r_sv.counters[k]),
                       np.asarray(r_ss.counters[k]))
        for k in ("c_adm_msgs", "c_done_msgs", "c_drops"))
    rows.append(Row("sim_perf/stage_vec",
                    us_per_tick(t_sv.s, n_ticks_g),
                    dict(seq_us_per_tick=us_per_tick(t_ss.s, n_ticks_g),
                         speedup_x=t_ss.s / max(t_sv.s, 1e-9),
                         counters_match_seq=bool(s_match))))

    # -- batched profiler sweep: 8 heterogeneous contexts, 1 engine call --
    ctxs = [
        (CATALOG["ipsec32"], [(Path.FUNCTION_CALL, 64, 0.9)]),
        (CATALOG["ipsec32"], [(Path.FUNCTION_CALL, 1500, 0.9)] * 2),
        (CATALOG["ipsec32"], [(Path.FUNCTION_CALL, 64, 0.9),
                              (Path.FUNCTION_CALL, 1500, 0.9)]),
        (CATALOG["synthetic50"], [(Path.FUNCTION_CALL, 512, 0.9)] * 3),
        (CATALOG["synthetic50"], [(Path.FUNCTION_CALL, 4096, 0.9)]),
        (CATALOG["aes256"], [(Path.FUNCTION_CALL, 1024, 0.9)] * 2),
        (CATALOG["sha3_512"], [(Path.INLINE_NIC_RX, 256, 0.9)] * 2),
        (CATALOG["compress"], [(Path.FUNCTION_CALL, 4096, 0.9),
                               (Path.FUNCTION_CALL, 64, 0.9),
                               (Path.FUNCTION_CALL, 1024, 0.9)]),
    ]
    prof_ticks = 6_000 if quick else 30_000
    pt_serial = ProfileTable(n_ticks=prof_ticks)
    engine.cache_clear()
    with Timer() as t_pser:                   # 8 serial compile-bound runs
        serial_entries = [pt_serial.profile_context(a, f) for a, f in ctxs]
    pt_batch = ProfileTable(n_ticks=prof_ticks)
    engine.cache_clear()
    with Timer() as t_pbat:                   # one ragged batched call
        batch_entries = pt_batch.profile_contexts(ctxs)
    info = engine.cache_info()
    # acceptance criterion: the whole heterogeneous Capacity(t, X, N)
    # sweep issues exactly ONE compiled engine call
    assert info == {"entries": 1, "traces": 1}, info
    p_match = all(s.capacity_gbps == b.capacity_gbps
                  and s.per_flow_gbps == b.per_flow_gbps
                  for s, b in zip(serial_entries, batch_entries))
    assert p_match, "batched profiler sweep diverged from serial entries"
    rows.append(Row("sim_perf/profile_batch8",
                    us_per_tick(t_pbat.s, len(ctxs) * prof_ticks),
                    dict(wall_s=t_pbat.s, serial_wall_s=t_pser.s,
                         speedup_vs_serial_x=t_pser.s / max(t_pbat.s, 1e-9),
                         contexts=len(ctxs), engine_calls=info["entries"],
                         entries_match_serial=bool(p_match))))

    payload = {r.name.split("/", 1)[1]: dict(us_per_call=r.us_per_call,
                                             **r.derived) for r in rows}
    save_json("sim_perf", payload)
    return rows
