"""Fleet-scale SLO management — the paper's scalability claim at fleet
granularity.

Arcus argues one shaping architecture can serve many client servers with
<1% throughput variance ("SLO Management for Accelerators in the Cloud");
this benchmark drives B managed client servers — heterogeneous flow counts
AND accelerator complements — as ONE compiled control plane
(`runtime.run_managed_batch`) and checks both halves of the claim:

  fleet_slo/B{N}        — batched managed fleet of N servers: wall clock,
                          us per (server x tick), cross-server throughput
                          deviation of the common reference flow vs the
                          paper's <1% target, worst per-server p99 latency,
                          and the engine-cache proof that the whole
                          heterogeneous fleet is ONE compiled entry
  fleet_slo/batch_vs_serial8 — the same 8-server fleet run as 8 serial
                          `run_managed` loops (each a compile-bound
                          distinct signature) vs the single batched
                          program; asserts counters bitwise-equal and
                          >= 3x wall-clock on CPU
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, Timer, save_json, us_per_tick
from repro.core import engine
from repro.core.accelerator import CATALOG
from repro.core.flow import SLO, FlowSpec, Path, TrafficPattern
from repro.core.profiler import ProfileTable
from repro.core.runtime import ArcusRuntime, register_fleet, run_managed_batch

#: every server carries this reference flow on its first accelerator; its
#: achieved rate is what the cross-server variance check compares
REF_SLO_GBPS = 8.0
REF_MSG = 1024

#: heterogeneous accelerator complements, cycled across the fleet (the
#: first accel is shared so the reference flow is comparable server-to-
#: server; the rest make the accel tables ragged)
_COMPLEMENTS = (
    ["synthetic50"],
    ["synthetic50", "aes256"],
    ["synthetic50", "aes256", "ipsec32"],
)


def _fleet_specs(b: int) -> list[FlowSpec]:
    """Server b's flows: the shared reference flow plus 0-2 extra flows on
    the server's extra accelerators (ragged flow counts)."""
    names = _COMPLEMENTS[b % len(_COMPLEMENTS)]
    specs = [FlowSpec(0, 0, Path.FUNCTION_CALL, 0,
                      TrafficPattern(REF_MSG, load=0.4, process="poisson"),
                      SLO.gbps(REF_SLO_GBPS))]
    for i, _name in enumerate(names[1:], start=1):
        specs.append(FlowSpec(i, i, Path.FUNCTION_CALL, i,
                              TrafficPattern(512 << (i % 2), load=0.3,
                                             process="poisson"),
                              SLO.gbps(3.0 + i)))
    return specs


def _build_fleet(n_servers: int, profile: ProfileTable
                 ) -> list[ArcusRuntime]:
    rts = [ArcusRuntime([CATALOG[n]
                         for n in _COMPLEMENTS[b % len(_COMPLEMENTS)]],
                        profile_table=profile)
           for b in range(n_servers)]
    specs = [_fleet_specs(b) for b in range(n_servers)]
    accepted = register_fleet(rts, specs)
    assert all(all(a) for a in accepted), "fleet admission rejected a flow"
    return rts


def _refs(rts) -> list[dict[int, float]]:
    return [{i: 32.0 for i in range(len(rt.table))} for rt in rts]


def _ref_flow_gbps(res) -> float:
    return float(res.counters["c_done_bytes"][0] * 8 / res.seconds / 1e9)


def _p99_lat_us(res) -> float:
    lat = res.comp_lat_s[res.comp_flow == 0]
    if len(lat) == 0:
        return float("nan")
    return float(np.percentile(lat, 99) * 1e6)


def run(quick: bool = False) -> list[Row]:
    sweep = (1, 8, 32) if quick else (1, 8, 32, 128)
    window = 1_500 if quick else 3_000
    n_windows = 4 if quick else 5
    total = window * n_windows          # divisible: one engine entry
    rows, payload = [], {}

    profile = ProfileTable(n_ticks=6_000 if quick else 20_000)
    for B in sweep:
        rts = _build_fleet(B, profile)
        seeds = list(range(B))
        engine.cache_clear()
        with Timer() as t:
            results, reports = run_managed_batch(
                rts, total_ticks=total, window_ticks=window,
                seeds=seeds, load_ref_gbps=_refs(rts))
        info = engine.cache_info()
        # the whole heterogeneous fleet (mixed flow counts, mixed accel
        # counts, per-server registers) is ONE compiled engine entry
        assert info == {"entries": 1, "traces": 1}, info
        ref = np.array([_ref_flow_gbps(r) for r in results])
        dev_pct = (np.max(np.abs(ref - ref.mean()) / ref.mean()) * 100
                   if B > 1 else 0.0)
        viol = sum(len(w.violated) for rep in reports for w in rep)
        d = dict(wall_s=t.s, servers=B, windows=len(reports[0]),
                 ref_gbps_mean=float(ref.mean()),
                 ref_dev_max_pct=float(dev_pct),
                 var_under_1pct=bool(dev_pct < 1.0),
                 p99_lat_us_worst=max(_p99_lat_us(r) for r in results),
                 slo_violations=viol,
                 entries=info["entries"], traces=info["traces"])
        rows.append(Row(f"fleet_slo/B{B}", us_per_tick(t.s, B * total), d))
        payload[f"B{B}"] = d

    # -- batched fleet vs B serial run_managed loops at B=8 --------------
    # serial pays one compile per server (every server's trace shape and
    # flow/accel signature differs); the batch compiles once.  Fresh
    # runtimes per side: run_managed mutates control state.
    B = 8
    seeds = list(range(B))
    rts_serial = _build_fleet(B, profile)
    engine.cache_clear()
    with Timer() as t_ser:
        serial = [rt.run_managed(total_ticks=total, window_ticks=window,
                                 seed=seeds[b],
                                 load_ref_gbps=_refs(rts_serial)[b])
                  for b, rt in enumerate(rts_serial)]
    rts_batch = _build_fleet(B, profile)
    engine.cache_clear()
    with Timer() as t_bat:
        results, reports = run_managed_batch(
            rts_batch, total_ticks=total, window_ticks=window,
            seeds=seeds, load_ref_gbps=_refs(rts_batch))
    match = all(
        np.array_equal(np.asarray(s.counters[k]), np.asarray(r.counters[k]))
        for (s, _), r in zip(serial, results)
        for k in ("c_adm_msgs", "c_done_msgs", "c_drops", "c_adm_bytes",
                  "c_done_bytes"))
    reports_match = all(
        ws.measured == wb.measured and ws.violated == wb.violated
        for (_, rep_s), rep_b in zip(serial, reports)
        for ws, wb in zip(rep_s, rep_b))
    speedup = t_ser.s / max(t_bat.s, 1e-9)
    assert match and reports_match, \
        "batched fleet diverged from serial run_managed"
    assert speedup >= 3.0, f"fleet batching speedup {speedup:.2f}x < 3x"
    d = dict(wall_s=t_bat.s, serial_wall_s=t_ser.s,
             speedup_vs_serial_x=speedup,
             counters_match_serial=bool(match),
             reports_match_serial=bool(reports_match))
    rows.append(Row("fleet_slo/batch_vs_serial8",
                    us_per_tick(t_bat.s, B * total), d))
    payload["batch_vs_serial8"] = d
    save_json("fleet_slo", payload)
    return rows
