"""Closed-loop adaptive shaping vs static registers — the control PR.

Arcus's registers come from offline profiled capacities and change only
on admit/rebalance; the closed loop (``repro.core.control``) re-decides
them every window from measured SLO slack.  Two workloads, each run
twice through the SAME ``FleetController`` harness — once with
``StaticHold`` (bitwise the pre-control-loop behaviour) and once with
the bi-level adaptive policy (``GlobalRetarget`` wrapping
``SlackAIMD``):

* **churn** — a B-server fleet where every server co-locates a
  latency-critical tenant with a throughput reference, and bursty
  on/off tenants arrive and depart at window boundaries
  (``TenantEvent`` churn).  Static registers give the bursty arrivals
  their planner-default deep buckets, so each burst piles into the
  shared accelerator queue ahead of the latency tenant; the adaptive
  loop sees the latency violations in ``WindowMetrics`` and
  multiplicatively shrinks the bursty tenants' bucket depth.  Metric:
  fleet-wide latency-SLO violation windows (and mean measured latency).
* **fig9** — the Fig. 9 use-case-2 co-location (64B latency-critical
  VM1 + a bursty 1500B VM2 shaped at 32 Gbps, averaging below it, on
  one inline-NIC accelerator), driven through the managed window loop
  instead of the one-shot baseline batch.  Static keeps VM2's
  planner-default bucket, admitting its line-rate bursts wholesale;
  adaptive shrinks the bucket window by window, pacing the bursts at
  the refill rate.  Metric: VM1 p99 latency, with VM2's long-run
  throughput held within 5% of the static arm's.

Both adaptive runs ride ONE compiled engine entry (asserted) — the
whole point of actuating through the existing register-rewrite path —
and the benchmark asserts the adaptive arm strictly improves the
workload's headline metric, which is the acceptance bar for the PR.
``check_regression.py --pr-adaptive`` gates the committed JSON.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (Row, Timer, save_json, tail_latency_us,
                               us_per_tick)
from repro.core import control, engine
from repro.core.accelerator import CATALOG, AcceleratorSpec, CURVE_LINEAR
from repro.core.controller import FleetController, TenantEvent
from repro.core.flow import SLO, FlowSpec, Path, SLOKind, TrafficPattern
from repro.core.interconnect import LinkSpec
from repro.core.profiler import ProfileTable
from repro.core.runtime import ArcusRuntime

#: profiling horizon is mode-independent so quick/full admission
#: decisions (and the committed baseline) stay identical
_PROFILE_TICKS = 8_000

_CHURN_B = 2
_CHURN_WINDOW = 1_500
_CHURN_WINDOWS = 6


def _adaptive_policy() -> control.ControlPolicy:
    return control.GlobalRetarget(control.SlackAIMD(), period=3)


def _lat_violations(reports) -> int:
    """Latency-SLO violation windows across the fleet, from the
    WindowMetrics schema (one consumer-side derivation, shared with the
    controller's policies)."""
    return sum(m.violated for rep in reports for w in rep
               for m in w.metrics.values()
               if m.kind == int(SLOKind.LATENCY))


def _violations(reports) -> int:
    """All SLO-violation windows (rate and latency) across the fleet."""
    return sum(m.violated for rep in reports for w in rep
               for m in w.metrics.values())


def _lat_mean_us(reports) -> float:
    lats = [m.lat_avg_s for rep in reports for w in rep
            for m in w.metrics.values()
            if m.kind == int(SLOKind.LATENCY) and np.isfinite(m.lat_avg_s)]
    return float(np.mean(lats) * 1e6) if lats else float("nan")


# ---------------------------------------------------------------------------
# Churn arm: latency tenants vs bursty churners
# ---------------------------------------------------------------------------


def _churn_fleet(profile: ProfileTable,
                 policy: control.ControlPolicy) -> FleetController:
    rts = [ArcusRuntime([CATALOG["synthetic50"]], profile_table=profile)
           for _ in range(_CHURN_B)]
    ctrl = FleetController(rts, control=policy)
    specs = []
    for b in range(_CHURN_B):
        specs.append([
            # latency-critical tenant: small messages, tight bound
            FlowSpec(2000 + b, 2000 + b, Path.FUNCTION_CALL, 0,
                     TrafficPattern(128, rate_mps=1.0e6, process="poisson"),
                     SLO.latency(4e-6)),
            # throughput reference
            FlowSpec(1000 + b, 1000 + b, Path.FUNCTION_CALL, 0,
                     TrafficPattern(1024, load=0.3, process="poisson"),
                     SLO.gbps(8.0)),
        ])
    acc = ctrl.admit_fleet(specs)
    assert all(all(a) for a in acc), "churn-arm admission rejected"
    return ctrl


def _burster(i: int) -> FlowSpec:
    return FlowSpec(i, i, Path.FUNCTION_CALL, 0,
                    TrafficPattern(1500, load=0.5, process="onoff",
                                   burst_len=64, duty=0.3),
                    SLO.gbps(6.0))


def _churn_events() -> list[TenantEvent]:
    """One bursty tenant arrives per server at window 1, departs at
    window 4; a second wave arrives at window 2 — violation pressure
    through most of the timeline."""
    ev = []
    for i in range(_CHURN_B):
        ev.append(TenantEvent.arrive(1, _burster(i), server=i))
        ev.append(TenantEvent.depart(4, tenant_id=i))
        ev.append(TenantEvent.arrive(2, _burster(100 + i), server=i))
    return ev


def _run_churn(profile: ProfileTable, policy: control.ControlPolicy,
               *, timed: bool = False) -> dict:
    ctrl = _churn_fleet(profile, policy)
    kwargs = dict(total_ticks=_CHURN_WINDOW * _CHURN_WINDOWS,
                  window_ticks=_CHURN_WINDOW,
                  seeds=list(range(_CHURN_B)),
                  load_ref_gbps=[{1: 32.0}] * _CHURN_B,
                  events=_churn_events())
    if timed:
        engine.cache_clear()
    with Timer() as t:
        _res, reports = ctrl.run(**kwargs)
    out = dict(
        wall_s=t.s, policy=policy.name,
        violations=_violations(reports),
        lat_violations=_lat_violations(reports),
        lat_mean_us=_lat_mean_us(reports),
        reconfigs=sum(rt.table[f].reconfigs for rt in ctrl.runtimes
                      for f in rt.table))
    if timed:
        info = engine.cache_info()
        assert info == {"entries": 1, "traces": 1}, info
        out["engine_entries"] = info["entries"]
    return out


# ---------------------------------------------------------------------------
# Fig. 9 arm: bursty MTU stream vs latency-critical tiny messages
# ---------------------------------------------------------------------------

_NIC = AcceleratorSpec("nic_acc", peak_gbps=60.0, curve=CURVE_LINEAR,
                       overhead_ns=120.0, parallelism=2)
_FIG9_KW = dict(k_grant=8, k_srv=8, k_eg=8, comp_cap=1 << 17)


def _fig9_fleet(profile: ProfileTable,
                policy: control.ControlPolicy) -> FleetController:
    rt = ArcusRuntime([_NIC],
                      link=LinkSpec(d2h_gbps=80.0, h2d_gbps=80.0,
                                    credits=256),
                      profile_table=profile)
    ctrl = FleetController([rt], control=policy)
    # window telemetry measures MEAN completion latency; a mean bound of
    # 0.6us is the control-loop proxy for the paper's 1us TAIL bound —
    # VM2's burst collisions push VM1's p99 to ~6us while the window
    # mean only rises to ~0.7us, so the mean target must sit below the
    # collision-free operating point for the loop to see tail pressure
    acc = ctrl.admit_fleet([[
        FlowSpec(0, 0, Path.INLINE_NIC_RX, 0,
                 TrafficPattern(64, rate_mps=2.0e6, process="poisson"),
                 SLO.latency(0.6e-6), priority=2),
        # VM2's AVERAGE offered load (0.5 * 60 = 30 Gbps) sits below its
        # 32 Gbps shaped rate — the Fig. 9 regime where bucket DEPTH is
        # the lever: a deep bucket admits the line-rate bursts wholesale
        # (VM1 collisions), a shallow one paces them at the refill rate
        # without costing VM2 long-run throughput.  (A backlogged flow —
        # average offered above the shaped rate — keeps its bucket
        # pinned empty, and depth stops mattering at all.)
        FlowSpec(1, 1, Path.INLINE_NIC_RX, 0,
                 TrafficPattern(1500, load=0.5, process="onoff",
                                burst_len=64, duty=0.3),
                 SLO.gbps(32.0), priority=0),
    ]])
    assert all(all(a) for a in acc), "fig9-arm admission rejected"
    return ctrl


def _run_fig9(profile: ProfileTable, policy: control.ControlPolicy,
              n_ticks: int, *, timed: bool = False) -> dict:
    ctrl = _fig9_fleet(profile, policy)
    kwargs = dict(total_ticks=n_ticks, window_ticks=n_ticks // 10,
                  tick_cycles=4, seeds=[0], load_ref_gbps=[{1: 60.0}],
                  sim_kwargs=dict(_FIG9_KW))
    if timed:
        engine.cache_clear()
    with Timer() as t:
        results, reports = ctrl.run(**kwargs)
    res = results[0]
    # time-based warmup cut: the admission transient (buckets start
    # full, so window 0 admits a line-rate burst) is identical in both
    # arms and would otherwise dominate the tail of both — the
    # comparison is about the steady state the policy converges to
    sel = (res.comp_flow == 0) & (res.comp_t_s >= 0.4 * res.seconds)
    tails = tail_latency_us(res.comp_lat_s[sel], qs=(99,))
    out = dict(
        wall_s=t.s, policy=policy.name,
        vm1_avg_us=tails["mean_us"],
        vm1_p99_us=tails["p99_us"],
        vm2_gbps=float(np.mean([w.metrics[1].measured
                                for w in reports[0][1:]])),
        lat_violations=_lat_violations(reports))
    if timed:
        info = engine.cache_info()
        assert info == {"entries": 1, "traces": 1}, info
        out["engine_entries"] = info["entries"]
    return out


# ---------------------------------------------------------------------------


def run(quick: bool = False) -> list[Row]:
    rows, payload = [], {}
    profile = ProfileTable(n_ticks=_PROFILE_TICKS)

    # -- churn arm -----------------------------------------------------
    # warm every admission + envelope context on throwaway controllers
    # sharing the ProfileTable, so the timed adaptive run profiles
    # nothing and stays on ONE compiled engine entry
    _run_churn(profile, control.StaticHold())
    _run_churn(profile, _adaptive_policy())
    churn_static = _run_churn(profile, control.StaticHold(), timed=True)
    churn_adapt = _run_churn(profile, _adaptive_policy(), timed=True)
    assert churn_static["violations"] >= 1, \
        "churn arm lost its static violation pressure"
    assert churn_adapt["violations"] < churn_static["violations"], \
        "adaptive shaping did not reduce churn-arm SLO violations"
    payload["churn"] = dict(
        static=churn_static, adaptive=churn_adapt, improved=True)
    rows.append(Row("adaptive/churn/static",
                    us_per_tick(churn_static["wall_s"],
                                _CHURN_B * _CHURN_WINDOW * _CHURN_WINDOWS),
                    churn_static))
    rows.append(Row("adaptive/churn/adaptive",
                    us_per_tick(churn_adapt["wall_s"],
                                _CHURN_B * _CHURN_WINDOW * _CHURN_WINDOWS),
                    churn_adapt))

    # -- fig9 arm ------------------------------------------------------
    n_ticks = 60_000 if quick else 250_000
    _run_fig9(profile, control.StaticHold(), n_ticks)
    _run_fig9(profile, _adaptive_policy(), n_ticks)
    fig9_static = _run_fig9(profile, control.StaticHold(), n_ticks,
                            timed=True)
    fig9_adapt = _run_fig9(profile, _adaptive_policy(), n_ticks,
                           timed=True)
    assert fig9_adapt["vm1_p99_us"] < fig9_static["vm1_p99_us"], \
        "adaptive shaping did not reduce fig9 VM1 tail latency"
    # both arms admit all of VM2's (sub-rate) traffic; pacing must not
    # cost it long-run throughput
    assert fig9_adapt["vm2_gbps"] >= 0.95 * fig9_static["vm2_gbps"], \
        "adaptive shaping starved VM2 vs the static arm"
    payload["fig9"] = dict(
        static=fig9_static, adaptive=fig9_adapt,
        improved=True,
        p99_improvement_x=fig9_static["vm1_p99_us"]
        / max(fig9_adapt["vm1_p99_us"], 1e-9))
    rows.append(Row("adaptive/fig9/static",
                    us_per_tick(fig9_static["wall_s"], n_ticks),
                    fig9_static))
    rows.append(Row("adaptive/fig9/adaptive",
                    us_per_tick(fig9_adapt["wall_s"], n_ticks), fig9_adapt))

    save_json("adaptive", payload)
    return rows
