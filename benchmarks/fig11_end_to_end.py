"""Fig. 11 + Table 4 — end-to-end applications.

(a) Inline-NIC mode: two MICA users (64B / 256B values, 50/50 GET/SET)
    share SHA1-HMAC + AES-128-CBC accelerators while a live-migration (LM)
    job streams MTU-sized messages through AES.  Arcus pins both MICA
    users at their SLOs and lets LM harvest the remainder; the PANIC
    baseline over-provisions user1 and starves user2 (paper: +48% / -61%).

(b) Inline-P2P mode: FIO reads (1KB random, SLO 2M IOPS) vs writes
    (4KB sequential, SLO 25K IOPS) on an NVMe RAID-0.  Without shaping the
    write stream over-provisions ~2x while reads fall to ~44% of SLO.

(c) Function-call mode: RocksDB offloading checksum (CRC32C) + compression
    onto accelerators.  Model-based accounting (constants documented
    inline) reproducing Table 4: 1.43x throughput and ~59% CPU savings on
    an 8-core VM.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import Row, Timer, save_json, us_per_tick
from repro.core import baselines, token_bucket as tb
from repro.core.accelerator import CATALOG, AccelTable
from repro.core.flow import SLO, FlowSet, FlowSpec, Path, TrafficPattern
from repro.core.interconnect import LinkSpec
from repro.core.sim import gen_arrivals


# ---------------------------------------------------------------------------
# (a) MICA + live migration — both systems in one batched engine call
# ---------------------------------------------------------------------------

def _mica(sys_names, n_ticks: int):
    sha, aes = CATALOG["sha1_hmac"], CATALOG["aes128_cbc"]
    # SLOs: user1 (64B, latency-critical KV) 2 Gbps-equiv of accel I/O;
    # user2 (256B) 4 Gbps; LM opportunistic large stream on AES.
    specs = [
        FlowSpec(0, 0, Path.INLINE_NIC_RX, 0,
                 TrafficPattern(64, load=0.30, process="poisson"),
                 SLO.gbps(2.0), priority=2),
        FlowSpec(1, 1, Path.INLINE_NIC_RX, 1,
                 TrafficPattern(256, load=0.30, process="poisson"),
                 SLO.gbps(4.0), priority=2),
        FlowSpec(2, 2, Path.INLINE_NIC_TX, 1,
                 TrafficPattern(1500, load=0.9, process="onoff",
                                burst_len=128, duty=0.5),
                 SLO.gbps(0.0), priority=0, weight=0.05),
    ]
    flows = FlowSet.build(specs)
    overrides = dict(tick_cycles=8, k_grant=8, k_srv=8, k_eg=8)
    cfg0 = baselines.make_sim_config(baselines.ALL[sys_names[0]], n_ticks,
                                     **overrides)
    arr = gen_arrivals(flows, cfg0, seed=7,
                       load_ref_gbps={0: 12.0, 1: 20.0, 2: 36.0})

    def tb_for(sys_name):
        sys_cfg = baselines.ALL[sys_name]
        if sys_cfg.shaping == baselines.SHAPING_HW:
            plans = [tb.params_for_gbps(2.0, max_interval=128),
                     tb.params_for_gbps(4.0, max_interval=128),
                     # LM harvests what AES has left after user2
                     # (heterogeneity-aware: aes effective at 1500B minus
                     # user2's share)
                     tb.params_for_gbps(
                         max(1.0, 0.9 * aes.effective_gbps(1500) - 4.0))]
            return tb.pack(plans)
        return baselines.make_tb_state(sys_cfg, [tb.TBParams(1, 1, 1)] * 3)

    batch = baselines.run_system_batch(
        sys_names, flows, AccelTable.build([sha, aes]), LinkSpec(),
        n_ticks, tb_states=[tb_for(s) for s in sys_names], arr=arr,
        cfg_overrides=overrides)
    out = {}
    for sys_name, res in zip(sys_names, batch):
        lat1 = res.latency_percentiles(0, (50, 99))
        out[sys_name] = dict(
            user1_gbps=res.mean_ingress_gbps(0, flows),
            user2_gbps=res.mean_ingress_gbps(1, flows),
            lm_gbps=res.mean_ingress_gbps(2, flows),
            user1_p99_over_p50=(lat1[99] / max(lat1[50], 1e-12)),
        )
    return out


# ---------------------------------------------------------------------------
# (b) storage reads vs writes — both systems in one batched engine call
# ---------------------------------------------------------------------------

def _storage(sys_names, n_ticks: int):
    # NVMe RAID-0: service is operation-dominated — 1KB random reads
    # ~20 us, 4KB writes ~500 us (program + GC amortization); 64-deep
    # queue parallelism across 4 SSDs.
    nvme = dataclasses.replace(
        CATALOG["nvme_raid0"], name="nvme_rw", parallelism=64,
        service_us_at=((1024, 20.0), (4096, 300.0)))
    SLO_R, SLO_W = 2.0e6, 25.0e3
    specs = [
        FlowSpec(0, 0, Path.INLINE_P2P, 0,
                 TrafficPattern(1024, rate_mps=SLO_R * 1.4,
                                process="poisson"), SLO.iops(SLO_R)),
        FlowSpec(1, 1, Path.INLINE_P2P, 0,
                 TrafficPattern(4096, rate_mps=SLO_W * 2.5,
                                process="onoff", burst_len=256, duty=0.4),
                 SLO.iops(SLO_W)),
    ]
    flows = FlowSet.build(specs)
    overrides = dict(tick_cycles=64, k_grant=16, k_srv=16, k_eg=16,
                     lmax=64, qlen=1024, comp_cap=1 << 17,
                     aq_len=2048, aq_byte_cap=4 << 20)
    cfg0 = baselines.make_sim_config(baselines.ALL[sys_names[0]], n_ticks,
                                     **overrides)
    arr = gen_arrivals(flows, cfg0, seed=11)

    def tb_for(sys_name):
        sys_cfg = baselines.ALL[sys_name]
        if sys_cfg.shaping == baselines.SHAPING_HW:
            # writes arrive in 256-deep bursts; a tight bucket keeps them
            # from flooding the shared device buffer ahead of reads (the
            # shaping decision the profiler's SLO-Violating tag encodes)
            return tb.pack([tb.params_for_iops(SLO_R * 1.05),
                            tb.params_for_iops(SLO_W * 1.05)])
        return baselines.make_tb_state(sys_cfg, [tb.TBParams(1, 1, 1)] * 2)

    batch = baselines.run_system_batch(
        sys_names, flows, AccelTable.build([nvme]), LinkSpec(credits=4096),
        n_ticks, tb_states=[tb_for(s) for s in sys_names], arr=arr,
        cfg_overrides=overrides)
    out = {}
    for sys_name, res in zip(sys_names, batch):
        warm = 0.15 * res.seconds
        out[sys_name] = dict(
            read_miops=res.mean_rate(0, "iops", warmup_s=warm) / 1e6,
            write_kiops=res.mean_rate(1, "iops", warmup_s=warm) / 1e3,
            read_frac_of_slo=res.mean_rate(0, "iops", warmup_s=warm) / SLO_R,
            write_over_slo_x=res.mean_rate(1, "iops", warmup_s=warm) / SLO_W,
        )
    return out


# ---------------------------------------------------------------------------
# (c) RocksDB offload accounting (Table 4)
# ---------------------------------------------------------------------------

def _rocksdb():
    """Model-based reproduction of Table 4 (constants documented).

    An 8-core VM runs RocksDB.  Measured baseline (ext4): 161.7 MB/s using
    5.23 cores.  Per *amplified* byte (write-amplification ~2.2x across
    memtable flush + compaction), software compression costs ~22 cyc/B and
    crc32c ~2.9 cyc/B on a 2.3 GHz core — together ~74% of the per-byte
    CPU cost.  Offloading both removes that CPU time; throughput then
    rises until the storage write path saturates (~230 MB/s user-bytes on
    this testbed's SSD after amplification).  The accelerators themselves
    (compress @20 Gbps effective, crc32c @48 Gbps) have ample headroom."""
    clock = 2.3e9
    base_mbs = 161.7
    cores_used = 5.23
    amp = 2.2
    comp_cyc_per_b, crc_cyc_per_b = 22.0, 2.9          # per amplified byte
    io_limit_mbs = 231.0   # SSD write-path bound (user-bytes) on the testbed
    total_cyc_per_ab = cores_used * clock / (base_mbs * 1e6 * amp)
    offload_cyc_per_ab = comp_cyc_per_b + crc_cyc_per_b
    remain_cyc_per_ab = total_cyc_per_ab - offload_cyc_per_ab
    arcus_runtime_cores = 0.175          # paper: 17.5% of a core
    # post-offload: storage-bound throughput; CPU need at that rate
    arcus_mbs = min(io_limit_mbs, base_mbs * total_cyc_per_ab
                    / max(remain_cyc_per_ab, 1e-9))
    cores_new = arcus_mbs * 1e6 * amp * remain_cyc_per_ab / clock \
        + arcus_runtime_cores
    comp_demand_gbps = arcus_mbs * 1e6 * amp * 8 / 1e9
    accel_ok = comp_demand_gbps < CATALOG["compress"].effective_gbps(16384)
    return dict(
        baseline_mbs=base_mbs,
        arcus_mbs=arcus_mbs,
        speedup_x=arcus_mbs / base_mbs,
        cores_baseline=cores_used,
        cores_arcus=cores_new,
        cores_saved_pct=100 * (1 - cores_new / cores_used),
        accel_headroom_ok=bool(accel_ok),
    )


def run(quick: bool = False) -> list[Row]:
    rows, payload = [], {}
    n_ticks = 40_000 if quick else 150_000
    mica_systems = ("Arcus", "Bypassed_noTS_panic")
    with Timer() as t:
        mica = _mica(mica_systems, n_ticks)
    for sys_name in mica_systems:
        payload[f"mica_{sys_name}"] = mica[sys_name]
        rows.append(Row(f"fig11a_mica/{sys_name}",
                        us_per_tick(t.s / len(mica_systems), n_ticks),
                        mica[sys_name]))
    n2 = n_ticks * 2
    storage_systems = ("Arcus", "Host_noTS")
    with Timer() as t:
        storage = _storage(storage_systems, n2)
    for sys_name in storage_systems:
        payload[f"storage_{sys_name}"] = storage[sys_name]
        rows.append(Row(f"fig11b_storage/{sys_name}",
                        us_per_tick(t.s / len(storage_systems), n2),
                        storage[sys_name]))
    payload["rocksdb"] = _rocksdb()
    rows.append(Row("table4_rocksdb", 0.0, payload["rocksdb"]))
    save_json("fig11_end_to_end", payload)
    return rows
