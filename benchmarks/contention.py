"""Multi-resource contention — vector-aware vs resource-blind admission.

The engine now charges every granted message a per-resource demand vector
(link, device memory bandwidth, host DMA), and the profiler/placement
stack scores candidates on the min margin over all axes.  This benchmark
measures what that buys on a mixed fleet: B=8 servers whose links carry a
memory-bandwidth axis tight enough that three bandwidth-bound tenants
saturate it, fed an interleaved stream of bandwidth-bound tenants
(default 1.0/1.0 demand per byte) and compute-bound tenants (0.05/0.05
``res_demand`` hint — a systolic engine barely touching memory).

Three admission control planes place the SAME 24-tenant stream, then
every resulting fleet runs on the SAME resource-limited dataplane:

  vector    — SLOAware() on the resource-aware fleet: scores the min
              margin over every axis, steers bandwidth-bound tenants
              away from memory-crowded servers
  axis0     — SLOAware(axis=0) on the resource-aware fleet: scores link
              margin only, but feasibility stays vector-checked (the
              admission floor the refactor guarantees)
  mem_blind — the pre-vector control plane: an R=1 fleet that profiles
              and scores the link alone, then its placement runs on the
              real memory-limited hardware

Reported per arm: admitted count, SLO-friendly tenants (measured ingress
>= 95% of SLO on the contended dataplane), and the cross-resource
utilization variance of the placement.  Asserted:

  * vector admits strictly more SLO-friendly tenants than mem_blind
    (the memory-blind plane stacks three bandwidth-bound tenants per
    server; they each sustain ~cap/(w_in+w_eg)/3 < SLO);
  * all three B=8 mixed-resource fleets run as ONE compiled engine
    entry (resource axes ride traced shapes, not compile keys);
  * the R=1 degenerate gate: huge-capacity axes reproduce the default
    engine bitwise, counter for counter.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, Timer, save_json, us_per_tick
from repro.core import engine, placement, token_bucket as tb
from repro.core.accelerator import CATALOG, AccelTable
from repro.core.controller import FleetController
from repro.core.flow import SLO, FlowSet, FlowSpec, Path, TrafficPattern
from repro.core.interconnect import RES_MEM_BW, LinkSpec, host_dma, mem_bw
from repro.core.profiler import ProfileTable
from repro.core.runtime import ArcusRuntime
from repro.core.sim import (SHAPING_HW, SimConfig, gen_arrivals, simulate,
                            simulate_batch, stack_arrivals)

_B = 8
_SLO = 5.0                  # Gbps per tenant
_MEM_GBPS = 24.0            # two bandwidth-bound tenants fit, three don't
_DMA_GBPS = 48.0            # live but never binding
_PROFILE_TICKS = 6_000      # mode-independent: decisions match the baseline
_SHAPE_HEADROOM = 1.05
_FRIENDLY_FRAC = 0.95

#: compute-bound tenants barely touch memory bandwidth
_COMPUTE_HINT = ((RES_MEM_BW, 0.05, 0.05),)


def _vector_link() -> LinkSpec:
    return LinkSpec(resources=(mem_bw(_MEM_GBPS), host_dma(_DMA_GBPS)))


def _tenants():
    """Interleaved stream: bandwidth-bound on even ids (no hint — default
    1.0/1.0 demand), compute-bound on odd ids (the 0.05 hint)."""
    specs = []
    for i in range(3 * _B):
        hint = () if i % 2 == 0 else _COMPUTE_HINT
        specs.append(FlowSpec(i, i, Path.FUNCTION_CALL, 0,
                              TrafficPattern(1024, load=0.5,
                                             process="poisson"),
                              SLO.gbps(_SLO), res_demand=hint))
    return specs


def _mk_fleet(link: LinkSpec) -> list[ArcusRuntime]:
    profile = ProfileTable(link, n_ticks=_PROFILE_TICKS)
    return [ArcusRuntime([CATALOG["synthetic50"]], link=link,
                         profile_table=profile)
            for _ in range(_B)]


def _place(arm: str):
    """Run one control plane over a fresh fleet; returns (placements,
    per-server spec lists in lane order)."""
    if arm == "mem_blind":
        rts, pol = _mk_fleet(LinkSpec()), placement.SLOAware()
    elif arm == "axis0":
        rts, pol = _mk_fleet(_vector_link()), placement.SLOAware(axis=0)
    else:
        rts, pol = _mk_fleet(_vector_link()), placement.SLOAware()
    placed = FleetController(rts).place(_tenants(), policy=pol)
    per_server = [[rt.table[fid].spec for fid in sorted(rt.table)]
                  for rt in rts]
    return placed, per_server


def _mem_demand(spec: FlowSpec) -> float:
    """Gbps of memory-bandwidth demand a tenant's SLO implies (the same
    ic + egress_ratio*ec algebra CapacityEntry uses; synthetic50 is
    R_EQUAL so the ratio is 1)."""
    ic, ec = 1.0, 1.0
    for nm, i, e in spec.res_demand:
        if nm == RES_MEM_BW:
            ic, ec = i, e
    return _SLO * (ic + ec)


def _run_dataplane(per_server, link: LinkSpec, cfg: SimConfig):
    """One B=8 batched engine call over the placed fleet; returns the
    per-server measured ingress Gbps keyed by flow id."""
    accels = AccelTable.build([CATALOG["synthetic50"]])
    flows_l, tbs_l, arrs = [], [], []
    for b, specs in enumerate(per_server):
        assert specs, f"server {b} ended up empty — scenario drifted"
        flows = FlowSet.build(specs)
        flows_l.append(flows)
        tbs_l.append(tb.pack([tb.params_for_gbps(_SLO * _SHAPE_HEADROOM)
                              for _ in specs]))
        arrs.append(gen_arrivals(flows, cfg, seed=b + 1,
                                 load_ref_gbps={i: 32.0
                                                for i in range(flows.n)}))
    res = simulate_batch(flows_l, accels, link, cfg, tbs_l,
                         *stack_arrivals(arrs))
    measured = {}
    for b, specs in enumerate(per_server):
        for i, s in enumerate(specs):
            measured[s.flow_id] = float(res[b].mean_ingress_gbps(
                i, flows_l[b]))
    return measured


def _degenerate_gate(per_server, cfg: SimConfig) -> bool:
    """Huge-capacity axes must reproduce the default R=1 engine bitwise —
    the non-negotiable contract of the vector refactor."""
    flows = FlowSet.build(per_server[0])
    accels = AccelTable.build([CATALOG["synthetic50"]])
    tbs = tb.pack([tb.params_for_gbps(_SLO * _SHAPE_HEADROOM)
                   for _ in per_server[0]])
    arr = gen_arrivals(flows, cfg, seed=1,
                       load_ref_gbps={i: 32.0 for i in range(flows.n)})
    inert = LinkSpec(resources=(mem_bw(1e6), host_dma(1e6)))
    r0 = simulate(flows, accels, LinkSpec(), cfg, tbs, *arr)
    r1 = simulate(flows, accels, inert, cfg, tbs, *arr)
    for k in ("c_adm_msgs", "c_done_msgs", "c_drops", "c_adm_bytes",
              "c_done_bytes"):
        assert np.array_equal(r0.counters[k], r1.counters[k]), \
            f"degenerate R=1 contract broken on {k}"
    np.testing.assert_array_equal(r0.comp_flow, r1.comp_flow)
    return True


def run(quick: bool = False) -> list[Row]:
    n_ticks = 10_000 if quick else 25_000
    cfg = SimConfig(n_ticks=n_ticks, shaping=SHAPING_HW)
    link = _vector_link()
    rows, payload = [], {}
    arms = ("vector", "axis0", "mem_blind")
    b_payload = {"tenants": 3 * _B, "servers": _B, "slo_gbps": _SLO,
                 "mem_gbps": _MEM_GBPS, "dma_gbps": _DMA_GBPS}

    # place first (admission profiling compiles its own ragged batch
    # shapes), then run every arm's dataplane on a cleared cache so the
    # one-compiled-entry contract is measured on the fleet runs alone
    placements = {}
    for arm in arms:
        with Timer() as t_place:
            placements[arm] = _place(arm) + (t_place,)

    friendly_by, admitted_by = {}, {}
    vector_servers = placements["vector"][1]
    for arm in arms:
        placed, per_server, t_place = placements[arm]
        engine.cache_clear()
        with Timer() as t:
            measured = _run_dataplane(per_server, link, cfg)
        # the B=8 mixed-resource fleet runs as ONE compiled engine
        # entry: resource axes ride traced shapes, not compile keys
        assert engine.cache_info() == {"entries": 1, "traces": 1}, \
            (arm, engine.cache_info())
        admitted = sum(p.accepted for p in placed)
        friendly = sum(m >= _FRIENDLY_FRAC * _SLO
                       for m in measured.values())
        # per-server memory-axis utilization of the placement — the
        # cross-resource balance the vector score buys
        mem_util = [sum(_mem_demand(s) for s in specs) / _MEM_GBPS
                    for specs in per_server]
        d = dict(admitted=admitted, rejected=3 * _B - admitted,
                 slo_friendly=friendly,
                 decisions=[p.server if p.accepted else -1
                            for p in placed],
                 mem_util_per_server=[round(u, 4) for u in mem_util],
                 mem_util_var=float(np.var(mem_util)),
                 min_measured_gbps=min(measured.values()),
                 placement_wall_s=t_place.s, dataplane_wall_s=t.s)
        admitted_by[arm], friendly_by[arm] = admitted, friendly
        b_payload[arm] = d
        rows.append(Row(f"contention/B{_B}/{arm}",
                        us_per_tick(t.s, n_ticks), d))

    b_payload["engine_cache"] = engine.cache_info()

    # the headline: resource-aware scoring admits strictly more tenants
    # that actually meet their SLO on the contended hardware than the
    # memory-blind (pre-vector) control plane
    gain = friendly_by["vector"] - friendly_by["mem_blind"]
    assert gain > 0, friendly_by
    # vector feasibility alone (axis-0 scoring) already prevents the
    # overload; the vector score additionally balances the memory axis
    assert friendly_by["axis0"] >= friendly_by["mem_blind"], friendly_by
    assert (b_payload["vector"]["mem_util_var"]
            <= b_payload["mem_blind"]["mem_util_var"]), b_payload
    b_payload["gain_slo_friendly_vector_vs_mem_blind"] = gain
    b_payload["degenerate_bitwise"] = _degenerate_gate(vector_servers, cfg)

    payload[f"B{_B}"] = b_payload
    save_json("contention", payload)
    return rows
