"""Fig. 8 — use case 1: SLO guarantee for large-message streams.

VM1 streams 4KB accelerator I/Os; VM2's message size sweeps 1KB..512KB;
both bidirectional function-call flows on one accelerator, each entitled
to half the throughput.

Arcus: the control plane paces both flows at half capacity and re-sizes
VM2's oversized messages (ReshapeDecision's payload split).  Baseline
Host_noTS: VM2's large messages congest PCIe and the accelerator queue,
stealing 36-67% of VM1's share (and vice versa at 1KB).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, Timer, save_json, us_per_tick
from repro.core import baselines, token_bucket as tb
from repro.core.accelerator import CATALOG, AccelTable
from repro.core.flow import SLO, FlowSet, FlowSpec, Path, TrafficPattern
from repro.core.interconnect import LinkSpec
from repro.core.shaper import reshape_decision, reshape_trace
from repro.core.sim import gen_arrivals, simulate

MSGS = (1024, 4096, 16384, 65536, 262144, 524288)
ACCEL = CATALOG["aes256"]  # 40 Gbps, R=1


def _fair_share(m2) -> float:
    from repro.core.shaper import optimal_msg_bytes
    m2 = int(m2)
    split = 2 * optimal_msg_bytes(ACCEL)
    m2_eff = split if m2 > 4 * split else m2
    t_per_byte = (float(ACCEL.service_time_s(4096)) / 4096
                  + float(ACCEL.service_time_s(m2_eff)) / m2_eff)
    # serving one byte of EACH flow costs t_per_byte seconds ->
    # each flow's fair rate is 1/t_per_byte bytes/s
    return 0.94 / t_per_byte * 8 / 1e9 * ACCEL.parallelism


def _run(sys_name: str, m2: int, n_ticks: int):
    sys_cfg = baselines.ALL[sys_name]
    # heterogeneity-aware fair share: the *mixed* capacity when the
    # accelerator alternates equal bytes of both flows' (shaped) message
    # sizes — Capacity(t, X, N) for this pattern combination (Sec. 4.3)
    half = _fair_share(m2)
    # untrusted tenants inject near line rate; only Arcus re-paces them
    specs = [
        FlowSpec(0, 0, Path.FUNCTION_CALL, 0,
                 TrafficPattern(4096, load=0.9, process="poisson"),
                 SLO.gbps(half)),
        FlowSpec(1, 1, Path.FUNCTION_CALL, 0,
                 TrafficPattern(m2, load=0.9, process="poisson"),
                 SLO.gbps(half)),
    ]
    flows = FlowSet.build(specs)
    cfg = baselines.make_sim_config(sys_cfg, n_ticks, tick_cycles=16,
                                    k_grant=8, k_srv=4, k_eg=8,
                                    qlen=512)
    arr_t, arr_sz = gen_arrivals(flows, cfg,
                                 load_ref_gbps={0: 44.0, 1: 44.0})
    if sys_cfg.shaping == baselines.SHAPING_HW:
        # ReshapeDecision: pace each flow at half capacity; split VM2's
        # oversized messages to the accelerator-optimal size
        d0 = reshape_decision(ACCEL, SLO.gbps(half), 4096)
        d1 = reshape_decision(ACCEL, SLO.gbps(half), m2)
        if d1.resize_to:
            t1, s1 = reshape_trace(arr_t[1], arr_sz[1], d1.resize_to)
            m = max(arr_t.shape[1], len(t1))
            pad = lambda a, fill: np.pad(a, (0, m - len(a)),
                                         constant_values=fill)
            arr_t = np.stack([pad(arr_t[0], 2**31 - 1), pad(t1, 2**31 - 1)])
            arr_sz = np.stack([pad(arr_sz[0], 0), pad(s1, 0)])
        tbs = tb.pack([d0.params, d1.params])
    else:
        tbs = baselines.make_tb_state(sys_cfg, [tb.TBParams(1, 1, 1)] * 2)
    res = simulate(flows, AccelTable.build([ACCEL]), LinkSpec(), cfg, tbs,
                   arr_t, arr_sz)
    return (res.mean_ingress_gbps(0, flows), res.mean_ingress_gbps(1, flows))


def run(quick: bool = False) -> list[Row]:
    rows, payload = [], {}
    n_ticks = 25_000 if quick else 80_000
    msgs = MSGS[:4] if quick else MSGS
    for sys_name in ("Arcus", "Host_noTS"):
        per = {}
        with Timer() as t:
            for m2 in msgs:
                per[m2] = _run(sys_name, m2, n_ticks)
        v1 = np.array([p[0] for p in per.values()])
        v2 = np.array([p[1] for p in per.values()])
        # loss is measured against the per-case fair share (equal-byte
        # mixed capacity), matching Fig. 8's "what VM1 should have been
        # allocated"
        fair = np.array([_fair_share(m2) for m2 in per])
        loss1 = 100 * (1 - v1 / fair)
        loss2 = 100 * (1 - v2 / fair)
        rows.append(Row(
            f"fig8/{sys_name}", us_per_tick(t.s, len(msgs) * n_ticks),
            dict(vm1_worst_loss_pct=float(loss1.max()),
                 vm2_worst_loss_pct=float(loss2.max()),
                 vm1_min_gbps=float(v1.min()),
                 vm1_max_gbps=float(v1.max()))))
        payload[sys_name] = {str(k): v for k, v in per.items()}
    save_json("fig8_large_messages", payload)
    return rows
