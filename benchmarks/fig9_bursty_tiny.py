"""Fig. 9 — use case 2: bursty tiny messages vs MTU streams.

VM1: latency-critical 64B flow, SLO = 99th% latency within ~1 us.
VM2: 1500B stream, SLO = 32 Gbps throughput, bursty (on/off).
Both on the inline-NIC-RX path, sharing one accelerator.

Arcus shapes VM2's injection so it cannot overload the shared accelerator
queue; the Bypassed(PANIC) baseline prioritizes VM1 at the arbiter but has
no shaping, so VM2's bursts (>32 Gbps momentarily) still pile into the
shared queue ahead of VM1's packets.  Paper claims: VM1 avg ~0.5 us /
99th% <= 0.74 us under Arcus, >= 1.9x better 99th% than the baseline, and
VM2 throughput pinned at 32 Gbps.

Both systems differ only in the engine's traced mode words (shaping +
arbiter), so the whole figure is ONE vmap-batched compiled call via
``baselines.run_system_batch`` — no serial per-system ``simulate``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import Row, Timer, save_json, us_per_tick
from repro.core import baselines, token_bucket as tb
from repro.core.accelerator import AcceleratorSpec, AccelTable, CURVE_LINEAR
from repro.core.flow import SLO, FlowSet, FlowSpec, Path, TrafficPattern
from repro.core.interconnect import LinkSpec
from repro.core.sim import gen_arrivals

# fast wire-speed accelerator: tiny fixed pipeline latency
ACCEL = AcceleratorSpec("nic_acc", peak_gbps=60.0, curve=CURVE_LINEAR,
                        overhead_ns=120.0, parallelism=2)

SYSTEMS = ("Arcus", "Bypassed_noTS_panic")


def _tb_for(sys_name: str):
    sys_cfg = baselines.ALL[sys_name]
    if sys_cfg.shaping == baselines.SHAPING_HW:
        # fine-grained pacing (64-cycle refill interval): latency-critical
        # co-location needs smooth sub-us shaping, not 4 us refill chunks.
        # VM1 is latency-critical: its SLO is enforced by shaping *others*
        # (paper Sec. 4.3); its own bucket gets generous headroom.
        plans = [tb.params_for_gbps(4.0, max_interval=64),
                 tb.params_for_gbps(32.0, max_interval=64)]
        # tight bucket for VM2: bursts must not overload the shared queue
        plans[1] = dataclasses.replace(
            plans[1], bkt_size=max(4 * 1500, plans[1].refill_rate))
        return tb.pack(plans)
    return baselines.make_tb_state(sys_cfg, [tb.TBParams(1, 1, 1)] * 2)


def _metrics(res):
    lat = res.flow_latencies(0)
    lat = lat[len(lat) // 5:]  # warmup trim (sorted; trim is approximate)
    return dict(
        vm1_avg_us=float(np.mean(lat) * 1e6) if len(lat) else float("nan"),
        vm1_p99_us=float(np.percentile(lat, 99) * 1e6) if len(lat) else
        float("nan"),
        vm2_gbps=res.mean_ingress_gbps(1, None),
    )


def run_systems(sys_names, n_ticks: int) -> dict[str, dict]:
    """Fig. 9 metrics for several systems from ONE batched engine call."""
    specs = [
        FlowSpec(0, 0, Path.INLINE_NIC_RX, 0,
                 TrafficPattern(64, rate_mps=2.0e6, process="poisson"),
                 SLO.latency(1e-6), priority=2),
        FlowSpec(1, 1, Path.INLINE_NIC_RX, 0,
                 TrafficPattern(1500, load=0.75, process="onoff",
                                burst_len=64, duty=0.3),
                 SLO.gbps(32.0), priority=0),
    ]
    flows = FlowSet.build(specs)
    overrides = dict(tick_cycles=4, k_grant=8, k_srv=8, k_eg=8,
                     comp_cap=1 << 17)
    cfg0 = baselines.make_sim_config(baselines.ALL[sys_names[0]], n_ticks,
                                     **overrides)
    arr = gen_arrivals(flows, cfg0, load_ref_gbps={1: 60.0})
    batch = baselines.run_system_batch(
        sys_names, flows, AccelTable.build([ACCEL]),
        LinkSpec(d2h_gbps=80.0, h2d_gbps=80.0, credits=256),
        n_ticks, tb_states=[_tb_for(s) for s in sys_names], arr=arr,
        cfg_overrides=overrides)
    return {name: _metrics(res) for name, res in zip(sys_names, batch)}


def run(quick: bool = False) -> list[Row]:
    rows, payload = [], {}
    n_ticks = 60_000 if quick else 250_000
    with Timer() as t:
        results = run_systems(SYSTEMS, n_ticks)
    for sys_name in SYSTEMS:
        rows.append(Row(f"fig9/{sys_name}",
                        us_per_tick(t.s / len(SYSTEMS), n_ticks),
                        results[sys_name]))
    arc, byp = results["Arcus"], results["Bypassed_noTS_panic"]
    rows.append(Row("fig9/claims", 0.0, dict(
        p99_improvement_x=byp["vm1_p99_us"] / max(arc["vm1_p99_us"], 1e-9),
        vm1_p99_under_1us=bool(arc["vm1_p99_us"] <= 1.0),
        vm2_shaped_at_32g=bool(abs(arc["vm2_gbps"] - 32.0) < 1.5))))
    payload.update(results)
    save_json("fig9_bursty_tiny", payload)
    return rows
