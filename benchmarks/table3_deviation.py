"""Table 3 — throughput deviation from the rate-limit target.

Thin view over the Fig. 6 experiment (same run, Table 3 is its VM1
percentile table)."""
from __future__ import annotations

from benchmarks import fig6_throughput_cdf as fig6
from benchmarks.common import Row, save_json


def run(quick: bool = False) -> list[Row]:
    out = fig6._experiment(quick)
    rows, payload = [], {}
    for sys_name, (var, _lat) in out.items():
        res = var[0]
        d = fig6.deviation_percentiles(res, 0, fig6.SLO1)
        rows.append(Row(f"table3/{sys_name}", 0.0, d))
        payload[sys_name] = d
    save_json("table3_deviation", payload)
    return rows
