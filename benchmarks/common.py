"""Shared helpers for the benchmark suite.

Every benchmark module exposes ``run(quick: bool) -> list[Row]``; run.py
prints them as ``name,us_per_call,derived`` CSV (us_per_call = wall
microseconds per simulated dataplane tick or per engine step — the
"how fast does the harness itself run" number; `derived` = the paper
metric being reproduced).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any

import numpy as np

#: JSON artifacts land here; CI points REPRO_BENCH_RESULTS somewhere else so
#: a smoke run never overwrites the committed baselines it is compared to
RESULTS_DIR = os.environ.get(
    "REPRO_BENCH_RESULTS",
    os.path.join(os.path.dirname(__file__), "results"))


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: dict[str, Any]

    def csv(self) -> str:
        d = ";".join(f"{k}={_fmt(v)}" for k, v in self.derived.items())
        return f"{self.name},{self.us_per_call:.3f},{d}"


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def save_json(name: str, payload) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, name + ".json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.s = time.time() - self.t0


def us_per_tick(wall_s: float, n_ticks: int) -> float:
    return wall_s / max(n_ticks, 1) * 1e6


def tail_latency_us(lat_s, qs: tuple[float, ...] = (50, 99, 99.9)
                    ) -> dict[str, float]:
    """Tail-latency summary of a completion-latency sample, in us.

    Returns ``{"p50_us": ..., "p99_us": ..., "p999_us": ...}`` (keys
    derived from ``qs``: the percentile with dots stripped) plus
    ``mean_us`` and ``n`` — NaN when the sample is empty.  One shared
    derivation so every benchmark's percentile math (interpolation mode
    included) is the same."""
    lat = np.asarray(lat_s, dtype=float)
    out: dict[str, float] = {"n": int(lat.size)}
    keys = ["p" + f"{q:g}".replace(".", "") + "_us" for q in qs]
    if lat.size == 0:
        out["mean_us"] = float("nan")
        out.update({k: float("nan") for k in keys})
        return out
    out["mean_us"] = float(np.mean(lat) * 1e6)
    for q, key in zip(qs, keys):
        out[key] = float(np.percentile(lat, q) * 1e6)
    return out
