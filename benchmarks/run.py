"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = wall
microseconds per simulated dataplane tick / engine step; derived = the
paper metric being reproduced).  JSON artifacts land in
benchmarks/results/.

Usage:
    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time
import traceback

MODULES = [
    "sim_perf",                  # engine compile-cache / batching speed
    "fleet_slo",                 # fleet-scale batched control plane
    "placement",                 # fleet admission placement policies
    "churn",                     # tenant-lifecycle churn timelines
    "contention",                # multi-resource vector admission
    "adaptive",                  # closed-loop shaping vs static registers
    "scenarios",                 # production-shaped workload scenarios
    "table2_shaping_accuracy",   # Table 2
    "fig3_provisioning",         # Fig. 3 / Table 1
    "fig6_throughput_cdf",       # Fig. 6 + Sec 5.2 latency
    "table3_deviation",          # Table 3
    "fig7_heterogeneity",        # Fig. 7
    "fig8_large_messages",       # Fig. 8 (use case 1)
    "fig9_bursty_tiny",          # Fig. 9 (use case 2)
    "fig11_end_to_end",          # Fig. 11 + Table 4
    "serving_slo",               # TPU-serving adaptation
    "roofline",                  # §Roofline (reads dry-run artifacts)
    "perf_variants",             # §Perf baseline-vs-optimized comparison
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="shorter sims (CI-scale)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failures = 0
    for name in MODULES:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            for row in mod.run(quick=args.quick):
                print(row.csv(), flush=True)
            print(f"# {name} done in {time.time()-t0:.0f}s", flush=True)
        except Exception as e:
            failures += 1
            print(f"# {name} FAILED: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
