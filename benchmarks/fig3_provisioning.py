"""Fig. 3 / Table 1 — inaccurate accelerator provisioning in current systems.

CaseT_pattern1-4: two VMs share a 32 Gbps IPSec accelerator via a
PANIC-style hypervisor-bypassed interface (no shaping); VM2's load sweeps
0.1-0.9.  Expected pathologies (paper Sec. 3.1):
  * tiny-message mixtures collapse overall throughput to 18-32% of peak,
  * SLOs (10/20 Gbps) violated everywhere, no fair 50/50 split,
  * one VM's load growth changes its neighbor's throughput.

CaseP_same_path / CaseP_multi_path: each VM owns its own synthetic 50 Gbps
accelerator (no interface contention) — contention is purely PCIe.
same_path (both inline-NIC-RX, both egress d2h) loses ~45% of aggregate
vs multi_path (function-call + NIC-RX exploits full duplex) and splits
bandwidth up to ~4x unfairly.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import Row, Timer, save_json, us_per_tick
from repro.core import baselines, token_bucket as tb
from repro.core.accelerator import AccelTable, CATALOG, R_FIXED
from repro.core.flow import SLO, FlowSet, FlowSpec, Path, TrafficPattern
from repro.core.interconnect import LinkSpec
from repro.core.sim import gen_arrivals, simulate

CASES_T = {
    "pattern1": ((256, 0.1), (64, None)),
    "pattern2": ((256, 0.1), (512, None)),
    "pattern3": ((128, 0.1), (512, None)),
    "pattern4": ((1500, 0.1), (512, None)),
}


def _run_two_flows(accels, specs, sys_cfg, n_ticks, load_ref,
                   tick_cycles=8, **cfg_kw):
    flows = FlowSet.build(specs)
    cfg = baselines.make_sim_config(sys_cfg, n_ticks,
                                    tick_cycles=tick_cycles, **cfg_kw)
    arr = gen_arrivals(flows, cfg, load_ref_gbps=load_ref)
    tbs = baselines.make_tb_state(sys_cfg, [tb.TBParams(1, 1, 1)] * len(specs))
    stall = baselines.make_stall_mask(sys_cfg, cfg)
    res = simulate(flows, AccelTable.build(accels), LinkSpec(), cfg, tbs,
                   *arr, stall_mask=stall)
    return [res.mean_ingress_gbps(i, flows) for i in range(len(specs))], res


def run(quick: bool = False) -> list[Row]:
    rows, payload = [], {}
    n_ticks = 30_000 if quick else 100_000
    loads = (0.1, 0.5, 0.9) if quick else (0.1, 0.3, 0.5, 0.7, 0.9)
    ipsec = CATALOG["ipsec32"]

    # ---- CaseT: accelerator-interface contention ----------------------
    for case, ((m1, l1), (m2, _)) in CASES_T.items():
        per_load = {}
        with Timer() as t:
            for l2 in loads:
                specs = [
                    FlowSpec(0, 0, Path.FUNCTION_CALL, 0,
                             TrafficPattern(m1, load=l1, process="poisson"),
                             SLO.gbps(10)),
                    FlowSpec(1, 1, Path.FUNCTION_CALL, 0,
                             TrafficPattern(m2, load=l2, process="poisson"),
                             SLO.gbps(20)),
                ]
                tput, _ = _run_two_flows(
                    [ipsec], specs, baselines.BYPASSED_NO_TS_PANIC, n_ticks,
                    {0: 32.0, 1: 32.0})
                per_load[l2] = tput
        v1 = np.array([v[0] for v in per_load.values()])
        v2 = np.array([v[1] for v in per_load.values()])
        total = v1 + v2
        slo_viol = bool(np.any(v1 < 10 * 0.98) or np.any(v2 < 20 * 0.98))
        rows.append(Row(
            f"fig3/CaseT_{case}", us_per_tick(t.s, n_ticks * len(loads)),
            dict(total_min_frac=float(total.min() / 32),
                 total_max_frac=float(total.max() / 32),
                 vm1_range=f"{v1.min():.1f}-{v1.max():.1f}",
                 slo_violated=slo_viol)))
        payload[f"CaseT_{case}"] = {str(k): v for k, v in per_load.items()}

    # ---- CaseP: pure communication contention --------------------------
    # Each VM owns a separate synthetic 50 Gbps accelerator (duplicated
    # interface, queue, DMA engine — paper Table 1) so SLO violations can
    # only come from PCIe.  The synthetic accel is a sink (tiny completion
    # in function-call mode); inline-NIC-RX always delivers full payloads
    # host-ward (path semantics, see sim.py).
    syn = dataclasses.replace(CATALOG["synthetic50"], name="syn50",
                              r_kind=R_FIXED, fixed_egress_bytes=64,
                              overhead_ns=0.0, parallelism=4)
    # paper patterns: VM1 {4KB, load=0.4}, VM2 {64B, load=0.1-0.9}
    results = {}
    with Timer() as t:
        for name, paths in (("same_path", (Path.INLINE_NIC_RX,
                                           Path.INLINE_NIC_RX)),
                            ("multi_path", (Path.FUNCTION_CALL,
                                            Path.INLINE_NIC_RX))):
            per_load = {}
            for l2 in loads:
                specs = [
                    FlowSpec(0, 0, paths[0], 0,
                             TrafficPattern(4096, load=0.4,
                                            process="poisson"),
                             SLO.gbps(50)),
                    FlowSpec(1, 1, paths[1], 1,
                             TrafficPattern(64, load=l2, process="poisson"),
                             SLO.gbps(50)),
                ]
                tput, _ = _run_two_flows([syn, syn], specs,
                                         baselines.HOST_NO_TS, n_ticks,
                                         {0: 60.0, 1: 60.0},
                                         k_grant=8, k_srv=4, k_eg=8)
                per_load[l2] = tput
            results[name] = per_load
    hi = max(loads)
    same, multi = results["same_path"][hi], results["multi_path"][hi]
    rows.append(Row(
        "fig3/CaseP", us_per_tick(t.s, 2 * len(loads) * n_ticks),
        dict(same_total=sum(same), multi_total=sum(multi),
             same_vs_multi=sum(same) / max(sum(multi), 1e-9),
             same_imbalance=max(same) / max(min(same), 1e-9))))
    payload["CaseP"] = {k: {str(l): v for l, v in d.items()}
                        for k, d in results.items()}
    save_json("fig3_provisioning", payload)
    return rows
