"""Roofline analysis over the dry-run artifacts (§Roofline deliverable).

For each (arch x shape) on the single-pod mesh, three terms in seconds:

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HBM_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / (links x link_bw)

Sources & caveats (documented measurements, see EXPERIMENTS.md §Roofline):
  * FLOPs / collective bytes come from the *unrolled* lowering
    (rec["unrolled"]): XLA's HLO cost analysis counts a while-loop body
    once, not x trip-count, so the scanned module under-counts by ~reps.
    cost_analysis of the SPMD-partitioned module is per device — the
    "/ chips" of the assignment formulas is already applied.
  * The memory term is ANALYTIC (weights + KV/state streams + activation
    I/O per device).  The CPU backend's bytes_accessed is fusion-blind
    (it counts every HLO op's operands; a TPU pass fuses most of them) and
    overestimates ~10x; the analytic stream model is the honest
    approximation of post-fusion HBM traffic.
  * MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (inference);
    usefulness = MODEL_FLOPS / (HLO_FLOPs x chips).
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import RESULTS_DIR, Row, save_json
from repro.configs.registry import SHAPES, get_config
from repro.models import transformer as T
from repro.serving.costmodel import (TPU_V5E, kv_bytes_per_token,
                                     param_bytes)

DRYRUN_DIR = os.path.join(RESULTS_DIR, "dryrun")
ICI_BW = TPU_V5E["ici"]
N_LINKS = 4  # ICI links per chip on the v5e 2D torus


def model_flops(arch: str, shape: str) -> float:
    cfg = get_config(arch)
    sh = SHAPES[shape]
    B, S = sh["global_batch"], sh["seq_len"]
    n_active = param_bytes(cfg) / 2.0          # bf16 bytes -> active params
    if sh["mode"] == "train":
        return 6.0 * n_active * B * S
    if sh["mode"] == "prefill":
        return 2.0 * n_active * B * S
    return 2.0 * n_active * B                  # decode: one token/seq


def total_param_bytes(cfg) -> float:
    """All weights (bf16), incl. every expert — what streams from HBM."""
    import jax
    import numpy as np
    shapes = jax.eval_shape(lambda: T.init_model_params_only(0, cfg))
    return sum(2.0 * float(np.prod(x.shape))
               for x in jax.tree.leaves(shapes))


def memory_bytes_per_device(arch: str, shape: str, chips: int = 256) -> float:
    """Analytic HBM traffic per device per step (post-fusion model)."""
    cfg = get_config(arch)
    sh = SHAPES[shape]
    B, S = sh["global_batch"], sh["seq_len"]
    E, L = cfg.d_model, cfg.n_layers
    pb = total_param_bytes(cfg)
    if sh["mode"] == "train":
        # fwd read + bwd read (remat re-reads) + grad write + opt update
        # (m, v, p fp32 read+write = 24 B/param) — all sharded over chips
        w = pb * 3 + (pb / 2) * 24
        acts = 3 * 2.0 * B * S * E * L * 2  # layer I/O x fwd+remat+bwd, bf16
        return (w + acts) / chips
    if sh["mode"] == "prefill":
        acts = 2 * 2.0 * B * S * E * L   # layer I/O (KV writes subsumed)
        return (pb + acts) / chips
    # decode: weights + per-token KV/state stream per sequence
    kv = kv_bytes_per_token(cfg, S) * B
    return (pb + kv) / chips


def analyze_record(rec: dict, prefill_unrolled: dict | None = None
                   ) -> dict | None:
    if rec.get("status") != "ok":
        return None
    arch, shape = rec["arch"], rec["shape"]
    chips = rec.get("n_devices", 256)
    u = rec.get("unrolled") or {}
    if u.get("derive") == "4x_prefill" and prefill_unrolled:
        # train_4k and prefill_32k carry the same 1.048M tokens:
        # train = fwd + bwd(2x) + remat fwd = 4x prefill compute
        flops = 4.0 * prefill_unrolled.get("flops", 0.0)
        flops_src = "4x_prefill_unrolled"
        # per-layer collectives also scale ~4x (gathers re-run in bwd/remat,
        # grad reduce ~= activation gather volume)
        coll = {k: 4.0 * v for k, v in
                (prefill_unrolled.get("collectives") or {}).items()}
    else:
        flops = u.get("flops") or rec.get("flops", 0.0)
        flops_src = ("unrolled" if u.get("flops") and not u.get("approx")
                     else "scan_x_reps" if u.get("approx") else "scanned")
        coll = (u.get("collectives")
                if isinstance(u.get("collectives"), dict)
                else rec.get("collectives", {})) or {}
    coll_bytes = sum(v for k, v in coll.items() if not k.startswith("n_"))
    t_c = flops / TPU_V5E["flops"]
    t_m = memory_bytes_per_device(arch, shape, chips) / TPU_V5E["hbm"]
    t_x = coll_bytes / (ICI_BW * N_LINKS)
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dominant = max(terms, key=terms.get)
    mf = model_flops(arch, shape)
    useful = mf / max(flops * chips, 1.0)
    return dict(arch=arch, shape=shape, mesh=rec["mesh"],
                compute_s=t_c, memory_s=t_m, collective_s=t_x,
                dominant=dominant, model_flops=mf,
                useful_ratio=useful, bound_step_s=max(terms.values()),
                collective_bytes=coll_bytes, flops_src=flops_src,
                hlo_flops_per_dev=flops)


def load_all(mesh: str = "pod") -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    prefill_u = {r["arch"]: r.get("unrolled") for r in recs
                 if r.get("shape") == "prefill_32k"
                 and isinstance(r.get("unrolled"), dict)
                 and not r["unrolled"].get("approx")}
    out = []
    for rec in recs:
        a = analyze_record(rec, prefill_unrolled=prefill_u.get(rec.get("arch")))
        if a:
            out.append(a)
    return out


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| useful | flops src |\n|---|---|---|---|---|---|---|---|\n")
    body = "".join(
        f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} "
        f"| {r['memory_s']:.3e} | {r['collective_s']:.3e} "
        f"| **{r['dominant']}** | {r['useful_ratio']:.2f} "
        f"| {r['flops_src']} |\n"
        for r in rows)
    return hdr + body


def run(quick: bool = False) -> list[Row]:
    del quick
    rows = []
    all_rows = load_all("pod")
    for r in all_rows:
        rows.append(Row(f"roofline/{r['arch']}/{r['shape']}", 0.0, dict(
            compute_s=r["compute_s"], memory_s=r["memory_s"],
            collective_s=r["collective_s"], dominant=r["dominant"],
            useful=r["useful_ratio"])))
    save_json("roofline", all_rows)
    with open(os.path.join(RESULTS_DIR, "roofline.md"), "w") as f:
        f.write(markdown_table(all_rows))
    return rows
