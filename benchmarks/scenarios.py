"""Production-shaped workload scenarios: one driver, many named
scenarios, comparable outputs.

Every scenario registered in ``repro.workloads`` (MMPP surges,
heavy-tailed sizes, anti-phase diurnal + correlated cross-server
bursts, a flash crowd with mid-storm arrivals, and an adversarial
token-bucket prober) runs twice through the SAME ``FleetController``
harness — once under ``StaticHold`` (registers fixed at admission) and
once under the bi-level adaptive policy (``GlobalRetarget`` wrapping
``SlackAIMD``) — and reports, per arm:

  * per-tenant throughput variance: cross-server deviation of the
    compliant reference tenants' timeline-mean throughput (the paper's
    <1% target) and their worst per-window coefficient of variation;
  * tail latency (p50 / p99 / p999) of the small-message latency
    probes, warmup-cut so the identical-in-both-arms start-full bucket
    transient doesn't dominate;
  * SLO-violation window counts and the lifecycle decisions of any
    mid-run churn — the deterministic vectors ``check_regression
    --pr-scenarios`` diffs against the committed baseline;
  * the one-compiled-engine-entry contract per timed run (asserted).

The adversarial scenario additionally documents its probe: the burst
depth / period actually used, and either that the compliant tenants'
variance held under the paper's 1% target or the measured breaking
point (the JSON records both arms' numbers either way).

Scenario timelines are fixed and mode-independent (quick == full), so
the committed ``scenarios.json`` gates CI smoke runs exactly.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (Row, Timer, save_json, tail_latency_us,
                               us_per_tick)
from repro.core import control, engine
from repro.core.flow import SLOKind
from repro.core.profiler import ProfileTable
from repro import workloads as wl

#: profiling horizon is mode-independent so quick/full admission
#: decisions (and the committed baseline) stay identical
_PROFILE_TICKS = 8_000

_SCENARIOS = ("mmpp_surge", "heavy_tail", "diurnal_corr", "flash_crowd",
              "adversarial_probe")

#: completions before this fraction of the horizon are excluded from
#: the latency tails: buckets start full, so the first windows admit an
#: identical-in-both-arms burst transient
_WARMUP_FRAC = 0.25


def _adaptive_policy() -> control.ControlPolicy:
    return control.GlobalRetarget(control.SlackAIMD(), period=3)


def _violations(reports) -> tuple[int, int]:
    """(all, latency-only) SLO-violation windows across the fleet."""
    alltot = sum(m.violated for rep in reports for w in rep
                 for m in w.metrics.values())
    lat = sum(m.violated for rep in reports for w in rep
              for m in w.metrics.values()
              if m.kind == int(SLOKind.LATENCY))
    return alltot, lat


def _ref_stats(spec, reports) -> dict:
    """The compliant reference tenants' (ids 1000+b) throughput
    variance: cross-server deviation of the timeline mean, plus the
    worst per-server cross-window CV (window 0 excluded — the
    start-full bucket transient is not steady-state variance)."""
    per = [np.array([w.measured[1000 + b] for w in reports[b]])
           for b in range(spec.servers)]
    mean_b = np.array([p.mean() for p in per])
    dev_pct = float(np.max(np.abs(mean_b - mean_b.mean())
                           / mean_b.mean()) * 100)
    cv_pct = float(max(np.std(p[1:]) / max(np.mean(p[1:]), 1e-12) * 100
                       for p in per))
    return dict(ref_gbps_mean=float(mean_b.mean()),
                ref_dev_max_pct=dev_pct,
                ref_window_cv_max_pct=cv_pct)


def _tenant_gbps(reports) -> dict[str, float]:
    """Timeline-mean measured rate per rate-SLO tenant (fleet-unique
    ids; the per-tenant throughput table of the JSON output)."""
    acc: dict[int, list[float]] = {}
    for rep in reports:
        for w in rep:
            for m in w.metrics.values():
                if m.kind != int(SLOKind.LATENCY):
                    acc.setdefault(m.flow_id, []).append(m.measured)
    return {str(fid): float(np.mean(v)) for fid, v in sorted(acc.items())}


def _lat_tails(spec, results) -> dict:
    """p50/p99/p999 of the latency probes' completions (lane 1 on every
    server), fleet-pooled, past the warmup cut."""
    lat = []
    for b in range(spec.servers):
        res = results[b]
        sel = ((res.comp_flow == 1)
               & (res.comp_t_s >= _WARMUP_FRAC * res.seconds))
        lat.append(res.comp_lat_s[sel])
    return tail_latency_us(np.concatenate(lat), qs=(50, 99, 99.9))


def _run_arm(spec, built, policy_name: str) -> dict:
    with Timer() as t:
        results, reports = built.run()
    viol, lat_viol = _violations(reports)
    out = dict(wall_s=t.s, policy=policy_name,
               violations=viol, lat_violations=lat_viol,
               decisions=[[e["kind"], e["tenant"],
                           -1 if e["server"] is None else e["server"]]
                          for e in built.controller.last_events],
               tenant_gbps=_tenant_gbps(reports),
               **_ref_stats(spec, reports),
               **_lat_tails(spec, results))
    if spec.events is not None:
        arrivals = [e for e in built.controller.last_events
                    if e["kind"] == "arrive"]
        assert arrivals and all(e["server"] is not None
                                for e in arrivals), \
            f"scenario {spec.name}: mid-run arrival rejected"
    return out


def _adversarial_doc(spec, static: dict, adaptive: dict) -> dict:
    """The probe's documentation: burst sizing actually used, and
    either 'the compliant tenants held <1% cross-server variance' or
    the measured breaking point — both arms' numbers recorded."""
    adv = spec.tenants(spec)[0][2].pattern     # [ref, lat, adversarial]
    holds = static["ref_dev_max_pct"] < 1.0
    return dict(
        bucket_bytes=int(adv.param("bucket_bytes")),
        period_s=float(adv.param("period_s")),
        period_windows=int(round(adv.param("period_s") / spec.window_s())),
        avg_gbps=float(adv.param("bucket_bytes") * 8e-9
                       / adv.param("period_s")),
        holds_under_1pct_static=bool(holds),
        holds_under_1pct_adaptive=bool(
            adaptive["ref_dev_max_pct"] < 1.0),
        breaking_point=None if holds else dict(
            ref_dev_max_pct=static["ref_dev_max_pct"],
            ref_window_cv_max_pct=static["ref_window_cv_max_pct"],
            note="static registers: bucket-depth bursts at window edges "
                 "push the compliant reference tenants past 1% "
                 "cross-server deviation"))


def run(quick: bool = False) -> list[Row]:
    rows, scen_payload = [], {}
    profile = ProfileTable(n_ticks=_PROFILE_TICKS)
    adversarial = None

    for name in _SCENARIOS:
        spec = wl.get_scenario(name)
        # warm every admission + envelope context on a throwaway
        # controller sharing the ProfileTable: the timed builds below
        # are then pure ProfileTable cache hits (no profiling engine
        # entries), so clearing the jit cache right before the timed
        # runs proves BOTH arms — every window, any mid-run churn —
        # rode one single compiled engine entry
        spec.build(control=_adaptive_policy(), profile=profile).run()
        b_static = spec.build(control=control.StaticHold(),
                              profile=profile)
        b_adapt = spec.build(control=_adaptive_policy(), profile=profile)
        engine.cache_clear()
        static = _run_arm(spec, b_static, b_static.controller.control.name)
        adapt = _run_arm(spec, b_adapt, b_adapt.controller.control.name)
        info = engine.cache_info()
        assert info == {"entries": 1, "traces": 1}, info
        static["engine_entries"] = adapt["engine_entries"] = \
            info["entries"]
        d = dict(static=static, adaptive=adapt,
                 engine_entries=info["entries"],
                 engine_traces=info["traces"],
                 servers=spec.servers, windows=spec.n_windows,
                 total_ticks=spec.total_ticks,
                 p99_ratio_static_over_adaptive=static["p99_us"]
                 / max(adapt["p99_us"], 1e-9))
        if name == "adversarial_probe":
            adversarial = _adversarial_doc(spec, static, adapt)
            d["probe"] = adversarial
        scen_payload[name] = d
        for arm, res in (("static", static), ("adaptive", adapt)):
            rows.append(Row(
                f"scenarios/{name}/{arm}",
                us_per_tick(res["wall_s"],
                            spec.servers * spec.total_ticks),
                dict(violations=res["violations"],
                     ref_dev_max_pct=res["ref_dev_max_pct"],
                     p99_us=res["p99_us"], p999_us=res["p999_us"])))

    save_json("scenarios", {"scenarios": scen_payload,
                            "adversarial": adversarial})
    return rows
