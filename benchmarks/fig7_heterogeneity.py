"""Fig. 7 — learned characteristics: heterogeneity, scalability, decisions.

(a) Non-linear throughput-vs-message-size curves for three accelerator
    families (logarithmic: SHA; exponential: AES; uniquely ad-hoc:
    compression) and their egress/ingress ratios R.
(b) Scalability 1 -> 16 flows: near-full aggregate throughput (the paper's
    per-flow overhead is 0.97% ALMs / 0.05 cores; here we show the
    dataplane itself is not the bottleneck as flows scale).
(c) Control-plane classification: VM1 with 16 x 1KB flows + VM2 with
    4 x 4KB flows on one accelerator -> profiled split ~50/50 -> the
    combination is tagged SLO-Friendly for half-capacity SLOs, and
    SLO-Violating when the requested SLOs exceed profiled capacity.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, Timer, save_json, us_per_tick
from repro.core import token_bucket as tb
from repro.core.accelerator import CATALOG, AccelTable
from repro.core.flow import SLO, FlowSet, FlowSpec, Path, TrafficPattern
from repro.core.interconnect import LinkSpec
from repro.core.profiler import ProfileTable
from repro.core.sim import SimConfig, gen_arrivals, simulate


def run(quick: bool = False) -> list[Row]:
    rows, payload = [], {}

    # (a) heterogeneity curves -----------------------------------------
    grid = [64, 256, 1024, 4096, 16384, 65536]
    curves = {}
    for name in ("sha3_512", "aes256", "compress"):
        acc = CATALOG[name]
        tput = acc.throughput_gbps(np.asarray(grid, float))
        curves[name] = {str(m): float(t) for m, t in zip(grid, tput)}
        egress = acc.egress_bytes(np.asarray(grid, float))
        r = egress / np.asarray(grid, float)
        rows.append(Row(f"fig7a/{name}", 0.0,
                        dict(curve=acc.curve,
                             frac_at_64B=float(tput[0] / acc.peak_gbps),
                             frac_at_64KB=float(tput[-1] / acc.peak_gbps),
                             R_at_4KB=float(r[3]))))
    payload["curves"] = curves

    # (b) scalability 1..16 flows ---------------------------------------
    n_ticks = 20_000 if quick else 60_000
    agg = {}
    with Timer() as t:
        for n in (1, 2, 4, 8, 16):
            specs = [
                FlowSpec(i, i, Path.FUNCTION_CALL, 0,
                         TrafficPattern(4096, load=1.0 / n,
                                        process="poisson"),
                         SLO.gbps(50.0 / n))
                for i in range(n)
            ]
            flows = FlowSet.build(specs)
            cfg = SimConfig(n_ticks=n_ticks, k_grant=8, k_srv=4, k_eg=8)
            arr = gen_arrivals(flows, cfg,
                               load_ref_gbps={i: 55.0 for i in range(n)})
            plans = [tb.params_for_gbps(52.0 / n) for _ in range(n)]
            res = simulate(flows, AccelTable.build([CATALOG["synthetic50"]]),
                           LinkSpec(), cfg, tb.pack(plans), *arr)
            agg[n] = sum(res.mean_ingress_gbps(i, flows) for i in range(n))
    rows.append(Row("fig7b/scalability", us_per_tick(t.s, 5 * n_ticks),
                    {f"flows{n}_gbps": v for n, v in agg.items()}
                    | {"frac_16_vs_1": agg[16] / max(agg[1], 1e-9)}))
    payload["scalability"] = agg

    # (c) control-plane classification -----------------------------------
    pt = ProfileTable(n_ticks=20_000 if quick else 40_000)
    ctx = [(Path.INLINE_NIC_RX, 1024, 0.9)] * 16 + \
          [(Path.INLINE_NIC_RX, 4096, 0.9)] * 4
    with Timer() as t:
        entry = pt.profile_context(CATALOG["synthetic50"], ctx)
    vm1 = sum(entry.per_flow_gbps[:16])
    vm2 = sum(entry.per_flow_gbps[16:])
    half = entry.capacity_gbps / 2
    # "half each" must leave the admission margin (2%) — request 0.97x
    friendly = entry.slo_tag([0.97 * half, 0.97 * half])
    violating = not entry.slo_tag([half * 1.4, half * 1.4])
    rows.append(Row("fig7c/classification", us_per_tick(t.s, pt.n_ticks),
                    dict(vm1_gbps=vm1, vm2_gbps=vm2,
                         fair_ratio=vm1 / max(vm2, 1e-9),
                         tag_half_friendly=friendly,
                         tag_overbooked_violating=violating)))
    payload["classification"] = rows[-1].derived
    save_json("fig7_heterogeneity", payload)
    return rows
