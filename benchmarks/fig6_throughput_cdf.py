"""Fig. 6 + Table 3 + §5.2 tail latency — SLO accuracy & variance.

Two users issue 4KB random reads against an NVMe RAID-0 backend;
SLO_user1 = 300K IOPS, SLO_user2 = 200K IOPS (99th%).  Compared systems:
Arcus (hardware token buckets) vs Host_TS_reflex / Host_TS_firecracker
(software shaping with timer jitter + host interference).

Paper claims reproduced here:
  * CDF of per-window throughput is near-vertical for Arcus (Fig. 6);
  * Table 3: Arcus 25/50/75/99th-percentile throughput deviation within
    +-1% of target vs -11.7%..+24.3% for software shaping;
  * tail latency: Arcus cuts 95/99/99.9th% by ~19/31/46% vs ReFlex-style
    software shaping (their numbers: 128/193/299us -> 104/133/162us).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, Timer, save_json, us_per_tick
from repro.core import baselines, token_bucket as tb
from repro.core.accelerator import CATALOG, AccelTable
from repro.core.flow import SLO, FlowSet, FlowSpec, Path, TrafficPattern
from repro.core.interconnect import LinkSpec
from repro.core.sim import gen_arrivals

SLO1, SLO2 = 300_000.0, 200_000.0
MSG = 4096

_cache: dict = {}


def _flows(load_x: float) -> FlowSet:
    return FlowSet.build([
        FlowSpec(0, 0, Path.FUNCTION_CALL, 0,
                 TrafficPattern(MSG, rate_mps=SLO1 * load_x,
                                process="poisson"), SLO.iops(SLO1)),
        FlowSpec(1, 1, Path.FUNCTION_CALL, 0,
                 TrafficPattern(MSG, rate_mps=SLO2 * load_x,
                                process="poisson"), SLO.iops(SLO2)),
    ])


_SYSTEMS = ("Arcus", "Host_TS_reflex", "Host_TS_firecracker")
_OVERRIDES = dict(tick_cycles=64, comp_cap=1 << 17, k_grant=8, k_srv=8,
                  k_eg=8, qlen=512, lmax=64)


def _experiment(quick: bool, *, seed=3):
    """All three systems x both load points — the oversubscribed 1.5x
    variance run and the 0.9x latency run — as ONE vmap-batched engine
    call.  Shaping mode, arbiter and the software-delay model are traced,
    and stall masks batch per element ([B, T]), so the firecracker/reflex
    software baselines ride the same compiled executable as Arcus instead
    of one serial-batched call per system."""
    key = ("fig6", quick)
    if key in _cache:
        return _cache[key]
    n_ticks = 60_000 if quick else 400_000
    load_points = (1.5, 0.9)
    cfg0 = baselines.make_sim_config(baselines.ALL[_SYSTEMS[0]], n_ticks,
                                     **_OVERRIDES)
    # arrival traces depend only on the structural config — one trace per
    # load point, shared by every system lane
    arrs_lp = [gen_arrivals(_flows(x), cfg0, seed=seed) for x in load_points]
    plans = [tb.params_for_iops(SLO1), tb.params_for_iops(SLO2)]
    systems, arrs, tbss = [], [], []
    for sys_name in _SYSTEMS:
        sys_cfg = baselines.ALL[sys_name]
        for a in arrs_lp:
            systems.append(sys_cfg)
            arrs.append(a)
            tbss.append(baselines.make_tb_state(sys_cfg, plans))
    nvme = CATALOG["nvme_raid0"]
    with Timer() as t:
        res = baselines.run_system_batch(
            systems, _flows(1.0), AccelTable.build([nvme]),
            LinkSpec(credits=256), n_ticks, tb_states=tbss, arr=arrs,
            cfg_overrides=_OVERRIDES)
    per = t.s / len(res)
    out = {}
    for si, sys_name in enumerate(_SYSTEMS):
        # variance run: oversubscribed 1.5x (shaping fully engaged);
        # latency run: 0.9x SLO (queues shallow; jitter visible)
        var, lat = res[2 * si], res[2 * si + 1]
        out[sys_name] = ((var, per, cfg0), (lat, per, cfg0))
    _cache[key] = out
    return out


def deviation_percentiles(res, flow_id: int, target: float,
                          window: int = 500):
    samp = res.throughput_samples(flow_id, window_msgs=window, kind="iops",
                                  warmup_s=0.15 * res.seconds)
    if len(samp) == 0:
        return {}
    qs = {q: float(np.percentile(samp, q)) for q in (25, 50, 75, 99)}
    return {f"p{q}_dev_pct": 100 * (v - target) / target
            for q, v in qs.items()}


def _lat_pcts(res, flow_id=0):
    lat = np.sort(res.comp_lat_s[(res.comp_flow == flow_id)
                                 & (res.comp_t_s > 0.15 * res.seconds)])
    if len(lat) == 0:
        return {95: float("nan"), 99: float("nan"), 99.9: float("nan")}
    return {q: float(np.percentile(lat, q)) for q in (95, 99, 99.9)}


def run(quick: bool = False) -> list[Row]:
    out = _experiment(quick)
    rows, payload = [], {}
    base_lat = _lat_pcts(out["Host_TS_reflex"][1][0])
    for sys_name, (var, latrun) in out.items():
        res, wall, cfg = var
        d: dict = {}
        for fid, slo in ((0, SLO1), (1, SLO2)):
            meas = res.mean_rate(fid, "iops", warmup_s=0.15 * res.seconds)
            d[f"user{fid+1}_kiops"] = meas / 1e3
            d.update({f"u{fid+1}_{k}": v for k, v in
                      deviation_percentiles(res, fid, slo).items()})
        lat = _lat_pcts(latrun[0])
        d.update({f"lat_p{q}_us": v * 1e6 for q, v in lat.items()})
        if sys_name == "Arcus":
            d.update({f"lat_red_p{q}_pct":
                      100 * (1 - lat[q] / base_lat[q])
                      for q in lat if base_lat[q] > 0})
        rows.append(Row(f"fig6/{sys_name}",
                        us_per_tick(wall + latrun[1], 2 * cfg.n_ticks), d))
        payload[sys_name] = d
    save_json("fig6_throughput_cdf", payload)
    return rows
