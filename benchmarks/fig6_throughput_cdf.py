"""Fig. 6 + Table 3 + §5.2 tail latency — SLO accuracy & variance.

Two users issue 4KB random reads against an NVMe RAID-0 backend;
SLO_user1 = 300K IOPS, SLO_user2 = 200K IOPS (99th%).  Compared systems:
Arcus (hardware token buckets) vs Host_TS_reflex / Host_TS_firecracker
(software shaping with timer jitter + host interference).

Paper claims reproduced here:
  * CDF of per-window throughput is near-vertical for Arcus (Fig. 6);
  * Table 3: Arcus 25/50/75/99th-percentile throughput deviation within
    +-1% of target vs -11.7%..+24.3% for software shaping;
  * tail latency: Arcus cuts 95/99/99.9th% by ~19/31/46% vs ReFlex-style
    software shaping (their numbers: 128/193/299us -> 104/133/162us).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import Row, Timer, save_json, us_per_tick
from repro.core import baselines, token_bucket as tb
from repro.core.accelerator import CATALOG, AccelTable
from repro.core.flow import SLO, FlowSet, FlowSpec, Path, TrafficPattern
from repro.core.interconnect import LinkSpec
from repro.core.sim import gen_arrivals, simulate_batch, stack_arrivals

SLO1, SLO2 = 300_000.0, 200_000.0
MSG = 4096

_cache: dict = {}


def _flows(load_x: float) -> FlowSet:
    return FlowSet.build([
        FlowSpec(0, 0, Path.FUNCTION_CALL, 0,
                 TrafficPattern(MSG, rate_mps=SLO1 * load_x,
                                process="poisson"), SLO.iops(SLO1)),
        FlowSpec(1, 1, Path.FUNCTION_CALL, 0,
                 TrafficPattern(MSG, rate_mps=SLO2 * load_x,
                                process="poisson"), SLO.iops(SLO2)),
    ])


def _system_runs(sys_name: str, n_ticks: int, *, seed=3):
    """Both load points of one system — the oversubscribed 1.5x variance
    run and the 0.9x latency run — in a single vmap-batched engine call
    (the traces differ; flow routing, registers and stall mask are
    shared)."""
    sys_cfg = baselines.ALL[sys_name]
    nvme = CATALOG["nvme_raid0"]
    cfg = baselines.make_sim_config(
        sys_cfg, n_ticks, tick_cycles=64, comp_cap=1 << 17,
        k_grant=8, k_srv=8, k_eg=8, qlen=512, lmax=64)
    load_points = (1.5, 0.9)
    arrs = [gen_arrivals(_flows(x), cfg, seed=seed) for x in load_points]
    plans = [tb.params_for_iops(SLO1), tb.params_for_iops(SLO2)]
    tbs = baselines.make_tb_state(sys_cfg, plans)
    stall = baselines.make_stall_mask(sys_cfg, cfg)
    with Timer() as t:
        res = simulate_batch(_flows(1.0), AccelTable.build([nvme]),
                             LinkSpec(credits=256), cfg,
                             [tbs] * len(load_points),
                             *stack_arrivals(arrs), stall_mask=stall)
    per = t.s / len(load_points)
    return (res[0], per, cfg), (res[1], per, cfg)


def _experiment(quick: bool):
    key = ("fig6", quick)
    if key in _cache:
        return _cache[key]
    n_ticks = 60_000 if quick else 400_000
    out = {}
    for sys_name in ("Arcus", "Host_TS_reflex", "Host_TS_firecracker"):
        # variance run: oversubscribed 1.5x (shaping fully engaged);
        # latency run: 0.9x SLO (queues shallow; jitter visible)
        var, lat = _system_runs(sys_name, n_ticks)
        out[sys_name] = (var, lat)
    _cache[key] = out
    return out


def deviation_percentiles(res, flow_id: int, target: float,
                          window: int = 500):
    samp = res.throughput_samples(flow_id, window_msgs=window, kind="iops",
                                  warmup_s=0.15 * res.seconds)
    if len(samp) == 0:
        return {}
    qs = {q: float(np.percentile(samp, q)) for q in (25, 50, 75, 99)}
    return {f"p{q}_dev_pct": 100 * (v - target) / target
            for q, v in qs.items()}


def _lat_pcts(res, flow_id=0):
    lat = np.sort(res.comp_lat_s[(res.comp_flow == flow_id)
                                 & (res.comp_t_s > 0.15 * res.seconds)])
    if len(lat) == 0:
        return {95: float("nan"), 99: float("nan"), 99.9: float("nan")}
    return {q: float(np.percentile(lat, q)) for q in (95, 99, 99.9)}


def run(quick: bool = False) -> list[Row]:
    out = _experiment(quick)
    rows, payload = [], {}
    base_lat = _lat_pcts(out["Host_TS_reflex"][1][0])
    for sys_name, (var, latrun) in out.items():
        res, wall, cfg = var
        d: dict = {}
        for fid, slo in ((0, SLO1), (1, SLO2)):
            meas = res.mean_rate(fid, "iops", warmup_s=0.15 * res.seconds)
            d[f"user{fid+1}_kiops"] = meas / 1e3
            d.update({f"u{fid+1}_{k}": v for k, v in
                      deviation_percentiles(res, fid, slo).items()})
        lat = _lat_pcts(latrun[0])
        d.update({f"lat_p{q}_us": v * 1e6 for q, v in lat.items()})
        if sys_name == "Arcus":
            d.update({f"lat_red_p{q}_pct":
                      100 * (1 - lat[q] / base_lat[q])
                      for q in lat if base_lat[q] > 0})
        rows.append(Row(f"fig6/{sys_name}",
                        us_per_tick(wall + latrun[1], 2 * cfg.n_ticks), d))
        payload[sys_name] = d
    save_json("fig6_throughput_cdf", payload)
    return rows
