"""§Perf hillclimb comparison: paper-faithful baseline vs optimized
variants, recomputed from the saved dry-run artifacts.

Pairs (EXPERIMENTS.md §Perf):
  gemma3-12b x decode_32k      — seq-sharded KV (S over "model") + shard_map
                                 partial-softmax + owned-shard cache writes
  gemma3-12b x long_500k       — + head_dim over "model" (2-level combine)
  llama4-maverick x prefill_32k — (32, 8) mesh refactor + gathered-weight
                                 constraints
"""
from __future__ import annotations

import json
import os

from benchmarks.common import RESULTS_DIR, Row
from benchmarks.roofline import ICI_BW, N_LINKS

DRYRUN_DIR = os.path.join(RESULTS_DIR, "dryrun")

PAIRS = [
    ("gemma3-12b", "decode_32k", "seqattn", "unrolled"),
    ("gemma3-12b", "long_500k", "seqattn2", "unrolled"),
    ("llama4-maverick-400b-a17b", "prefill_32k", "mesh32x8_acts", "scanned"),
    # generality check: the (32, 8) mesh refactor applied to the other
    # head-indivisible archs (baselines are unrolled; variants scanned ->
    # compare via the per-rep ratio, reported as-is)
    ("qwen2.5-14b", "prefill_32k", "mesh32x8", "scanned"),
    ("starcoder2-3b", "prefill_32k", "mesh32x8", "scanned"),
]


def _load(tag: str) -> dict | None:
    p = os.path.join(DRYRUN_DIR, tag + ".json")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


def _coll_bytes(rec: dict, level: str) -> float:
    src = rec.get("unrolled", {}) if level == "unrolled" else rec
    c = src.get("collectives")
    if not isinstance(c, dict):
        c = rec.get("collectives", {})
    return sum(v for k, v in (c or {}).items() if not k.startswith("n_"))


def _flops(rec: dict, level: str) -> float:
    if level == "unrolled":
        u = rec.get("unrolled", {})
        if u.get("flops") and not u.get("approx"):
            return u["flops"]
    return rec.get("flops", 0.0)


def run(quick: bool = False) -> list[Row]:
    del quick
    rows = []
    for arch, shape, variant, level in PAIRS:
        base = _load(f"{arch}__{shape}__pod")
        opt = _load(f"{arch}__{shape}__pod__{variant}")
        if not base or not opt or base.get("status") != "ok" \
                or opt.get("status") != "ok":
            continue
        cb, co = _coll_bytes(base, level), _coll_bytes(opt, level)
        fb, fo = _flops(base, level), _flops(opt, level)
        rows.append(Row(f"perf/{arch}/{shape}", 0.0, dict(
            variant=variant, level=level,
            coll_gib_base=cb / 2**30, coll_gib_opt=co / 2**30,
            coll_reduction_x=cb / max(co, 1.0),
            coll_term_base_s=cb / (ICI_BW * N_LINKS),
            coll_term_opt_s=co / (ICI_BW * N_LINKS),
            flops_base=fb, flops_opt=fo)))
    return rows
