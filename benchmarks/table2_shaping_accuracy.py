"""Table 2 — accurate traffic shaping from 1 Gbps to 1000 Gbps.

For each SLO we (a) verify the paper's published register values give a
shaped rate >= the SLO (their table carries headroom at 1 Gbps), and
(b) derive our own (Refill_Rate, Bkt_Size, Interval) with the control
plane's planner and measure the achieved rate end-to-end in the
cycle-accurate dataplane.  Claim: cycle-level hardware shaping holds the
achieved rate within ~1% of the target (vs >10 us software timers).
"""
from __future__ import annotations


from benchmarks.common import Row, Timer, save_json, us_per_tick
from repro.core import token_bucket as tb
from repro.core.accelerator import AcceleratorSpec, AccelTable, CURVE_LINEAR
from repro.core.flow import SLO, FlowSet, FlowSpec, Path, TrafficPattern
from repro.core.interconnect import LinkSpec
from repro.core.sim import (SHAPING_HW, SimConfig, gen_arrivals,
                            simulate_batch, stack_arrivals)

SLOS_GBPS = (1, 10, 100, 1000)


def run(quick: bool = False) -> list[Row]:
    rows, payload = [], {}
    n_ticks = 40_000 if quick else 150_000
    # comp_cap must cover every completion in the measured window
    # (1000 Gbps / 8KB -> ~73K completions over 4.8 ms)
    cfg = SimConfig(n_ticks=n_ticks, shaping=SHAPING_HW,
                    k_grant=8, k_srv=8, k_eg=8, comp_cap=1 << 17)
    # all four rate points share the engine signature (same shapes/config,
    # per-element accel table + link + registers + trace), so the whole
    # sweep is one vmap-batched compiled call
    plans, accels, links, arrs = [], [], [], []
    # the engine consumes only routing/priority/weight from the FlowSet
    # (identical across the four rate points — msg size and SLO only shape
    # the per-point arrival traces and registers), so one canonical flow
    # set serves the whole batch
    shared_flows = FlowSet.build([
        FlowSpec(0, 0, Path.FUNCTION_CALL, 0,
                 TrafficPattern(1024, load=0.9), SLO.gbps(1.0))])
    for slo in SLOS_GBPS:
        ours = tb.params_for_gbps(float(slo))
        plans.append(ours)
        # measured end-to-end (headroom on every other resource)
        msg = 1024 if slo <= 100 else 8192
        accels.append(AccelTable.build([
            AcceleratorSpec("wire", peak_gbps=4 * slo, curve=CURVE_LINEAR,
                            overhead_ns=5.0)]))
        links.append(LinkSpec(h2d_gbps=4 * slo, d2h_gbps=4 * slo,
                              efficiency=1.0, credits=4096))
        spec = FlowSpec(0, 0, Path.FUNCTION_CALL, 0,
                        TrafficPattern(msg, load=0.9), SLO.gbps(slo))
        arrs.append(gen_arrivals(FlowSet.build([spec]), cfg,
                                 load_ref_gbps={0: 2.0 * slo}))
    with Timer() as t:
        results = simulate_batch(shared_flows, accels, links, cfg,
                                 [tb.pack([p]) for p in plans],
                                 *stack_arrivals(arrs))
    for slo, ours, res in zip(SLOS_GBPS, plans, results):
        # paper's parameters: analytic shaped rate
        pp = tb.PAPER_TABLE2[slo]
        paper_rate = tb.achieved_rate(pp) * 8 / 1e9
        plan_rate = tb.achieved_rate(ours) * 8 / 1e9
        warm = 0.25 * res.seconds
        sel = res.comp_t_s >= warm
        meas = res.comp_sz[sel].sum() * 8 / (res.seconds - warm) / 1e9
        err = (meas - slo) / slo
        rows.append(Row(
            f"table2/slo_{slo}gbps",
            us_per_tick(t.s / len(SLOS_GBPS), n_ticks),
            dict(paper_params_gbps=paper_rate, planned_gbps=plan_rate,
                 measured_gbps=meas, err_pct=100 * err,
                 refill=ours.refill_rate, bkt=ours.bkt_size,
                 interval=ours.interval)))
        payload[slo] = rows[-1].derived
    save_json("table2_shaping_accuracy", payload)
    return rows
