"""Fleet admission placement — global CapacityPlanning vs per-server.

Arcus's admission control is SLO-Friendly-or-reject against the profiled
Capacity(t, X, N) context of ONE server; "SLO beyond the Hardware
Isolation Limits" is exactly what a tenant hits when its nominal server
is loaded while a sibling idles.  This benchmark drives the same skewed
tenant stream (everyone's static assignment lands on the first half of
the fleet) through four admission schemes:

  per_server — today's ``register_fleet``: the caller's static pin
               decides, rejections are final
  first_fit  — ``place_fleet``: first server with profiled headroom
  best_fit   — tightest post-admission residual capacity
  slo_aware  — maximum post-admission ``slo_tag`` margin

and reports, per policy and fleet size B ∈ {8, 32} (quick: {8}):

  * admitted / rejected tenant counts (slo_aware must admit strictly
    more than per_server on the skewed stream — the coordination gap,
    closed);
  * aggregate SLO-violation rate of a short managed run over the
    resulting fleet (violated flow-windows / flow-windows);
  * profiling cost: every admission round profiles its whole
    cross-server candidate set through ONE batched
    ``profile_contexts_multi`` engine call (asserted via
    ``profiler.profiling_stats`` + engine cache deltas);
  * the parity contract: pinned first-fit reproduces ``register_fleet``
    accept/reject decisions exactly.
"""
from __future__ import annotations

from benchmarks.common import Row, Timer, save_json, us_per_tick
from repro.core import engine
from repro.core.accelerator import CATALOG
from repro.core.flow import SLO, FlowSpec, Path, TrafficPattern
from repro.core.placement import POLICIES
from repro.core.profiler import ProfileTable, profiling_stats
from repro.core.runtime import (ArcusRuntime, place_fleet, register_fleet,
                                run_managed_batch)

#: heterogeneous accelerator complements, cycled across the fleet; every
#: server leads with synthetic50 so the reference tenants can land
#: anywhere, the extras make flow AND accel counts ragged
_COMPLEMENTS = (
    ["synthetic50"],
    ["synthetic50", "aes256"],
    ["synthetic50", "aes256", "ipsec32"],
)

#: profiling horizon is mode-independent so quick/full admission
#: decisions (and the committed baseline) stay identical
_PROFILE_TICKS = 8_000

_REF_SLO = 9.0          # Gbps per tenant; ~4 tenants fit one synthetic50


def _build_fleet(n_servers: int, profile: ProfileTable
                 ) -> list[ArcusRuntime]:
    return [ArcusRuntime([CATALOG[n]
                          for n in _COMPLEMENTS[b % len(_COMPLEMENTS)]],
                         profile_table=profile)
            for b in range(n_servers)]


def _tenants(b_servers: int):
    """The skewed stream: 3B reference tenants whose static assignment
    round-robins over only the first half of the fleet."""
    hot = max(b_servers // 2, 1)
    specs, names, pins = [], [], []
    for i in range(3 * b_servers):
        specs.append(FlowSpec(i, i, Path.FUNCTION_CALL, 0,
                              TrafficPattern(1024, load=0.5,
                                             process="poisson"),
                              SLO.gbps(_REF_SLO)))
        names.append("synthetic50")
        pins.append(i % hot)
    return specs, names, pins


def _violation_rate(rts, *, window: int, n_windows: int) -> float:
    """Aggregate SLO-violation rate of a short managed run over every
    server that hosts at least one tenant."""
    active = [rt for rt in rts if rt.table]
    if not active:
        return float("nan")
    refs = [{i: 32.0 for i in range(len(rt.table))} for rt in active]
    _, reports = run_managed_batch(
        active, total_ticks=window * n_windows, window_ticks=window,
        seeds=list(range(len(active))), load_ref_gbps=refs)
    flows = sum(len(rt.table) for rt in active)
    viol = sum(len(w.violated) for rep in reports for w in rep)
    return viol / max(flows * n_windows, 1)


def _admit(policy_name: str, rts, specs, names, pins):
    """Run one admission scheme over a fresh fleet; returns
    (admitted_count, per-server accept lists for per_server parity)."""
    if policy_name == "per_server":
        fleet_specs: list[list[FlowSpec]] = [[] for _ in rts]
        for s, p in zip(specs, pins):
            fleet_specs[p].append(s)
        acc = register_fleet(rts, fleet_specs)
        return sum(map(sum, acc)), acc
    placed = place_fleet(rts, specs, policy=POLICIES[policy_name](),
                         accel_names=names)
    return sum(p.accepted for p in placed), placed


def _decisions(policy_name: str, detail, pins) -> list[int]:
    """Per-tenant landing decision in stream order (server index, -1 =
    rejected) — the committed vector ``check_regression`` diffs, so even
    a count-preserving reshuffle of admissions trips the CI gate."""
    if policy_name == "per_server":
        queues = [list(a) for a in detail]
        return [p if queues[p].pop(0) else -1 for p in pins]
    return [p.server if p.accepted else -1 for p in detail]


def run(quick: bool = False) -> list[Row]:
    sweep = (8,) if quick else (8, 32)
    window = 1_500 if quick else 3_000
    n_windows = 3 if quick else 5
    policies = ("per_server", "first_fit", "best_fit", "slo_aware")
    rows, payload = [], {}
    profile = ProfileTable(n_ticks=_PROFILE_TICKS)

    for B in sweep:
        specs, names, pins = _tenants(B)
        rounds = len(specs)
        b_payload = {"tenants": rounds, "hot_servers": max(B // 2, 1)}
        # warm the shared ProfileTable once (contexts are keyed by
        # accel + flows, not server, so one per-server pass covers every
        # policy's contexts) — the timed walls below then all measure
        # admission work, not first-touch profiling
        with Timer() as t_warm:
            _admit("per_server", _build_fleet(B, profile),
                   specs, names, pins)
        b_payload["warmup_profiling_wall_s"] = t_warm.s
        admitted_by = {}
        per_server_acc = None
        for pol in policies:
            rts = _build_fleet(B, profile)
            p0, e0 = profiling_stats(), engine.cache_info()
            with Timer() as t:
                admitted, detail = _admit(pol, rts, specs, names, pins)
            p1, e1 = profiling_stats(), engine.cache_info()
            calls = p1["calls"] - p0["calls"]
            batches = p1["sim_batches"] - p0["sim_batches"]
            entries = e1["entries"] - e0["entries"]
            if pol != "per_server":
                # ONE batched profiling call per admission round; the
                # engine compiles at most one signature per launched batch
                assert calls == rounds, (pol, calls, rounds)
                assert batches <= rounds, (pol, batches, rounds)
                assert entries <= max(batches, 1), (pol, entries, batches)
            admitted_by[pol] = admitted
            if pol == "per_server":
                per_server_acc = detail
            d = dict(admitted=admitted, rejected=rounds - admitted,
                     decisions=_decisions(pol, detail, pins),
                     placement_wall_s=t.s,
                     profile_calls=calls, profile_sim_batches=batches,
                     profile_contexts=p1["contexts"] - p0["contexts"],
                     engine_entries_delta=entries,
                     engine_traces_delta=e1["traces"] - e0["traces"],
                     slo_violation_rate=_violation_rate(
                         rts, window=window, n_windows=n_windows))
            b_payload[pol] = d
            rows.append(Row(f"placement/B{B}/{pol}",
                            us_per_tick(t.s, rounds), d))

        # the coordination gap, closed: fleet-wide placement admits
        # strictly more of the skewed stream than per-server admission
        gain = admitted_by["slo_aware"] - admitted_by["per_server"]
        assert gain > 0, admitted_by
        b_payload["gain_slo_aware_vs_per_server"] = gain

        # parity contract: pinned first-fit IS register_fleet (compared
        # against the per_server accept lists computed above)
        placed = place_fleet(_build_fleet(B, profile), specs,
                             policy=POLICIES["first_fit"](), pinned=pins)
        parity = all(
            [p.accepted for p, pin in zip(placed, pins) if pin == b]
            == per_server_acc[b]
            for b in range(B))
        assert parity, "pinned first-fit diverged from register_fleet"
        b_payload["parity_first_fit_pinned"] = parity
        payload[f"B{B}"] = b_payload

    save_json("placement", payload)
    return rows
