"""TPU-serving adaptation: per-tenant SLOs on a real model engine.

Two SLO tenants + one opportunistic background tenant share a serving
engine running a (reduced) gemma3-family model; the clock is the roofline
StepCostModel for the v5e target.  Arcus-shaped scheduling vs unshaped
FCFS: the background tenant's long prompts must not break the SLO tenants'
TTFT tail or token-rate variance — the serving analogue of Fig. 8/9.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, Timer, save_json
from repro.configs.registry import get_reduced_config
from repro.core.flow import SLO
from repro.models import transformer as T
from repro.serving.costmodel import HardwareSpec, StepCostModel
from repro.serving.engine import ServingEngine
from repro.serving.request import Request, Tenant
from repro.serving.scheduler import ArcusScheduler, FCFSScheduler

_params_cache = {}


def _setup(quick: bool):
    cfg = get_reduced_config("gemma3-12b")
    if "p" not in _params_cache:
        _params_cache["p"] = T.init_model(0, cfg)[0]
    params = _params_cache["p"]
    return cfg, params


def _workload(cfg, sched, rng, duration_s: float, n_reqs: int):
    rid = 0
    # tenant 2 is greedy: dumps a pile of long prompts at t=0 (the serving
    # analogue of the LM / large-message tenants in Fig. 8/11)
    for _ in range(n_reqs):
        sched.submit(Request(rid, 2, list(rng.integers(0, cfg.vocab, 96)),
                             24, arrive_s=0.0))
        rid += 1
    # tenants 0/1 trickle short SLO-bound requests over the run
    t = 0.0
    for _ in range(n_reqs):
        for tid, plen, mnew in ((0, 16, 8), (1, 24, 8)):
            sched.submit(Request(rid, tid, list(rng.integers(0, cfg.vocab,
                                                             plen)),
                                 mnew, arrive_s=t))
            rid += 1
        t += duration_s / max(n_reqs, 1) * 0.5


def _run(shaped: bool, quick: bool):
    cfg, params = _setup(quick)
    engine = ServingEngine(cfg, params, max_batch=8, max_len=256)
    # virtual clock: the FULL-size family's roofline costs on 8 v5e chips
    # (the reduced model only supplies real tokens for correctness)
    from repro.configs.registry import get_config
    cost = StepCostModel(get_config("gemma3-12b"), HardwareSpec(chips=8))
    tenants = [
        Tenant(0, SLO.iops(1200.0), "reserved"),
        Tenant(1, SLO.iops(800.0), "reserved"),
        Tenant(2, SLO.iops(1e9), "opportunistic"),
    ]
    cls = ArcusScheduler if shaped else FCFSScheduler
    sched = cls(engine, tenants, cost)
    if shaped:
        # opportunistic tenant: tiny refill, empty bucket — pure harvesting
        plans = sched.buckets
        sched.buckets = plans._replace(
            refill_rate=plans.refill_rate.at[2].set(
                max(1, int(0.1 * plans.refill_rate[0]))),
            bkt_size=plans.bkt_size.at[2].set(256),
            tokens=plans.tokens.at[2].set(0))
    rng = np.random.default_rng(5)
    dur = 1.0 if quick else 4.0
    _workload(cfg, sched, rng, dur, 16 if quick else 32)
    stats = sched.run(dur, max_rounds=600 if quick else 2500)
    out = {}
    for tid in (0, 1, 2):
        st = stats[tid]
        ttft = np.asarray(st.ttft) if st.ttft else np.asarray([np.nan])
        tps = np.asarray(st.window_tps) if st.window_tps else np.asarray([0.0])
        out[f"t{tid}_tokens"] = st.served_tokens
        out[f"t{tid}_ttft_p99_ms"] = float(np.percentile(ttft, 99) * 1e3)
        if len(tps) > 1 and tps.mean() > 0:
            out[f"t{tid}_tps_cv"] = float(tps.std() / tps.mean())
    return out


def run(quick: bool = False) -> list[Row]:
    rows, payload = [], {}
    for name, shaped in (("Arcus", True), ("FCFS", False)):
        with Timer() as t:
            payload[name] = _run(shaped, quick)
        rows.append(Row(f"serving_slo/{name}", t.s * 1e6 / 300,
                        payload[name]))
    a, f = payload["Arcus"], payload["FCFS"]
    rows.append(Row("serving_slo/claims", 0.0, dict(
        ttft_p99_improvement_t0=f["t0_ttft_p99_ms"] /
        max(a["t0_ttft_p99_ms"], 1e-9),
        background_harvested=a["t2_tokens"] > 0)))
    save_json("serving_slo", payload)
    return rows
