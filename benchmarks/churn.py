"""Tenant churn at fleet scale — the lifecycle control plane vs the
paper's <1% throughput-variance target.

Arcus's Algorithm 1 manages SLOs *continuously*; a real cloud sees
tenants arrive and depart the whole time.  This benchmark drives a
B-server managed fleet (heterogeneous accelerator complements, one
long-lived reference flow per server) through a deterministic churn
timeline via ``FleetController.run``: every window, ``rate`` tenants
arrive (placed fleet-wide by SLO-aware scoring) and tenants admitted two
windows earlier depart — mixed arrivals and departures at every
boundary.  After the run, a pinned two-tenant burst piles onto server 0
(the operator's static choice) and ``rebalance()`` migrates it onto the
capacity churn freed elsewhere in the fleet.

Reported per fleet size B ∈ {8, 32} (quick: {8}; B=8 runs a fixed
timeline in both modes so the committed ``churn.json`` gates CI smoke
runs exactly) and per churn rate:

  * admitted / rejected / departed / migrated tenant counts and the
    per-event landing decisions (the vectors ``check_regression
    --pr-churn`` diffs against the committed baseline);
  * cross-server throughput deviation of the reference flows over the
    whole churn timeline, vs the paper's <1% variance target;
  * the one-compiled-engine-entry contract: the entire churn timeline —
    arrivals, departures, lane holes — runs on a single engine entry
    (admission contexts are pre-warmed, so boundary placements are pure
    ProfileTable cache hits);
  * score-cache reuse (``profiling_stats``: ``score_hits``) across the
    boundary placements and the rebalance sweep.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, Timer, save_json, us_per_tick
from repro.core import engine
from repro.core.accelerator import CATALOG
from repro.core.controller import FleetController, TenantEvent
from repro.core.flow import SLO, FlowSpec, Path, TrafficPattern
from repro.core.profiler import ProfileTable, profiling_stats
from repro.core.runtime import ArcusRuntime

_COMPLEMENTS = (
    ["synthetic50"],
    ["synthetic50", "aes256"],
    ["synthetic50", "aes256", "ipsec32"],
)

#: profiling horizon is mode-independent so quick/full admission
#: decisions (and the committed baseline) stay identical
_PROFILE_TICKS = 8_000

REF_SLO = 8.0

#: the B=8 timeline is fixed across quick/full so the committed baseline
#: gates smoke runs bit-for-bit
_B8_WINDOW = 1_500
_B8_WINDOWS = 6


def _ref_spec(b: int) -> FlowSpec:
    return FlowSpec(1000 + b, 1000 + b, Path.FUNCTION_CALL, 0,
                    TrafficPattern(1024, load=0.35, process="poisson"),
                    SLO.gbps(REF_SLO))


def _tenant(i: int) -> FlowSpec:
    return FlowSpec(i, i, Path.FUNCTION_CALL, 0,
                    TrafficPattern(1024, load=0.4, process="poisson"),
                    SLO.gbps(6.0))


def _timeline(rate: int, n_windows: int) -> list[TenantEvent]:
    """Deterministic churn: ``rate`` arrivals per window from window 1,
    each departing two windows after it arrived (mixed ARRIVE/DEPART at
    every interior boundary)."""
    events: list[TenantEvent] = []
    born: dict[int, int] = {}
    nid = 0
    for w in range(1, n_windows):
        for fid, bw in sorted(born.items()):
            if bw == w - 2:
                events.append(TenantEvent.depart(w, tenant_id=fid))
                del born[fid]
        if w < n_windows - 1:
            for _ in range(rate):
                events.append(TenantEvent.arrive(
                    w, _tenant(nid), accel_name="synthetic50"))
                born[nid] = w
                nid += 1
    return events


def _build(B: int, profile: ProfileTable) -> FleetController:
    rts = [ArcusRuntime([CATALOG[n]
                         for n in _COMPLEMENTS[b % len(_COMPLEMENTS)]],
                        profile_table=profile)
           for b in range(B)]
    ctrl = FleetController(rts)
    acc = ctrl.admit_fleet([[_ref_spec(b)] for b in range(B)])
    assert all(all(a) for a in acc), "reference-flow admission rejected"
    return ctrl


def _run_one(B: int, rate: int, window: int, n_windows: int,
             profile: ProfileTable) -> dict:
    events = _timeline(rate, n_windows)
    total = window * n_windows
    kwargs = dict(total_ticks=total, window_ticks=window,
                  seeds=list(range(B)),
                  load_ref_gbps=[{0: 32.0}] * B, events=events)

    # warm every admission context on a throwaway clone sharing the
    # ProfileTable — the timed run's boundary placements then profile
    # nothing (pure cache hits), keeping the dataplane ONE engine entry
    _build(B, profile).run(**kwargs)

    ctrl = _build(B, profile)
    p0 = profiling_stats()
    engine.cache_clear()
    with Timer() as t:
        _results, reports = ctrl.run(**kwargs)
    info = engine.cache_info()
    assert info == {"entries": 1, "traces": 1}, info
    p_run = profiling_stats()
    # every boundary placement was a pure ProfileTable cache hit
    assert p_run["contexts"] == p0["contexts"], p_run
    arrivals = [e for e in ctrl.last_events if e["kind"] == "arrive"]
    assert all(e["server"] is not None for e in arrivals), \
        "churn arrival rejected — retune the timeline load"
    # a pinned burst piles onto server 0 (an operator's static choice);
    # rebalance then migrates it onto the capacity churn freed elsewhere
    burst = ctrl.place([_tenant(900 + i) for i in range(2)],
                       pinned=[0, 0], accel_names=["synthetic50"] * 2)
    assert all(p.accepted for p in burst), "burst admission rejected"
    with Timer() as t_reb:
        moves = ctrl.rebalance()
    assert moves, "rebalance found no migration for the pinned burst"
    p1 = profiling_stats()

    # reference-flow throughput across servers, averaged over the whole
    # churn timeline (the <1% cross-server variance target under churn)
    ref = np.array([np.mean([w.measured[1000 + b] for w in reports[b]])
                    for b in range(B)])
    dev_pct = float(np.max(np.abs(ref - ref.mean()) / ref.mean()) * 100)
    viol = sum(len(w.violated) for rep in reports for w in rep)
    return dict(
        wall_s=t.s, rebalance_wall_s=t_reb.s, servers=B, rate=rate,
        windows=n_windows, events=len(events),
        admitted=ctrl.stats["admitted"], rejected=ctrl.stats["rejected"],
        departed=ctrl.stats["departed"], migrated=ctrl.stats["migrated"],
        decisions=[[e["kind"], e["tenant"],
                    -1 if e["server"] is None else e["server"]]
                   for e in ctrl.last_events],
        moves=[[m["tenant"], m["src"], m["dst"]] for m in moves],
        ref_gbps_mean=float(ref.mean()), ref_dev_max_pct=dev_pct,
        var_under_1pct=bool(dev_pct < 1.0),
        slo_violations=viol,
        engine_entries=info["entries"], engine_traces=info["traces"],
        score_hits=p1["score_hits"] - p0["score_hits"],
        profile_contexts=p1["contexts"] - p0["contexts"],
        total_ticks=window * n_windows)


def run(quick: bool = False) -> list[Row]:
    rates = (1, 2)
    rows, payload = [], {}
    profile = ProfileTable(n_ticks=_PROFILE_TICKS)

    b8 = {}
    for rate in rates:
        d = _run_one(8, rate, _B8_WINDOW, _B8_WINDOWS, profile)
        b8[f"rate{rate}"] = d
        rows.append(Row(f"churn/B8/rate{rate}",
                        us_per_tick(d["wall_s"], 8 * d["total_ticks"]), d))
    payload["B8"] = b8

    if not quick:
        d = _run_one(32, 2, 3_000, _B8_WINDOWS, profile)
        payload["B32"] = {"rate2": d}
        rows.append(Row("churn/B32/rate2",
                        us_per_tick(d["wall_s"], 32 * d["total_ticks"]), d))

    save_json("churn", payload)
    return rows
