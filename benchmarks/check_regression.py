"""CI bench-regression gate.

Compares a fresh ``sim_perf`` smoke run (written by ``benchmarks.run
--quick --only sim_perf`` with ``REPRO_BENCH_RESULTS`` pointed at a scratch
directory) against the committed baseline in
``benchmarks/results/sim_perf.json`` and emits ``BENCH_pr.json`` — the
perf trajectory artifact CI uploads for every PR:

  * cached-rerun us/tick (the steady-state engine speed) + ratio vs the
    committed baseline — the job FAILS if the PR is > ``--max-slowdown``
    (default 2x) slower.  The baseline is machine-dependent; the 2x
    allowance absorbs runner-vs-dev-box spread, and the
    machine-*relative* ratios below (batch-vs-serial, vectorized-stage
    speedups) are the signals to read when the absolute gate is noisy —
    re-baseline ``benchmarks/results/sim_perf.json`` if runners change
    class;
  * batch-vs-serial and profiler-sweep speedups;
  * engine compile-cache entry/trace counts (a growing count means a PR
    broke a cache key and reintroduced per-window recompiles);
  * (when ``--pr-placement``/``--baseline-placement`` are given) the
    fleet-placement decision gate: SLO-aware placement must still admit
    strictly more of the skewed B=8 stream than per-server admission,
    the pinned-first-fit parity contract must hold, and per-policy
    admitted counts must match the committed baseline exactly —
    placement decisions are deterministic, so ANY drift means a PR
    changed admission behavior (intentionally or not);
  * (when ``--pr-churn``/``--baseline-churn`` are given) the
    tenant-lifecycle churn gate over the fixed B=8 timelines:
    admitted/departed/migrated counts and per-event landing decisions
    must match the committed ``benchmarks/results/churn.json`` exactly,
    the whole timeline must still run as ONE compiled engine entry, and
    the cross-server reference-flow deviation must stay within 0.5
    percentage points of the baseline (and on the same side of the
    paper's 1% target);
  * (when ``--pr-contention``/``--baseline-contention`` are given) the
    multi-resource contention gate: per-arm admission decisions on the
    mixed B=8 fleet must match the committed baseline, resource-vector
    scoring must keep admitting strictly more SLO-friendly tenants than
    the memory-blind control plane, the vector placement's memory-axis
    utilization variance must stay at or below the memory-blind one,
    and the R=1 degenerate bitwise gate must have held;
  * (when ``--pr-adaptive``/``--baseline-adaptive`` are given) the
    closed-loop shaping gate: the adaptive policy must still beat
    StaticHold on both workloads (fewer SLO-violation windows on the
    churn arm — counts matching the committed baseline exactly, the
    arm's config is mode-independent — and a strictly better VM1 tail
    on the Fig. 9 arm with VM2 held at its SLO), with every timed run
    still ONE compiled engine entry;
  * (when ``--pr-scenarios``/``--baseline-scenarios`` are given) the
    production-shaped workload-scenario gate over the fixed named
    scenarios (MMPP / heavy-tail / diurnal+corrburst / flash crowd /
    adversarial prober): per-arm SLO-violation counts and lifecycle
    decisions must match the committed baseline exactly, reference
    variance must stay within 0.5 percentage points, the adversarial
    probe's holds-under-1% verdicts must not flip, and every scenario
    must ride ONE compiled engine entry across both control arms.

Usage:
    python -m benchmarks.check_regression \
        --pr bench_out/sim_perf.json \
        --baseline benchmarks/results/sim_perf.json \
        [--pr-placement bench_out/placement.json \
         --baseline-placement benchmarks/results/placement.json] \
        [--pr-churn bench_out/churn.json \
         --baseline-churn benchmarks/results/churn.json] \
        --out BENCH_pr.json [--max-slowdown 2.0]
"""
from __future__ import annotations

import argparse
import json
import sys


def summarize(pr: dict, baseline: dict, max_slowdown: float) -> dict:
    pr_us = pr["cached_rerun"]["us_per_call"]
    base_us = baseline["cached_rerun"]["us_per_call"]
    ratio = pr_us / max(base_us, 1e-12)
    return {
        "cached_rerun_us_per_tick": pr_us,
        "baseline_us_per_tick": base_us,
        "slowdown_vs_baseline_x": ratio,
        "max_slowdown_x": max_slowdown,
        "ok": ratio <= max_slowdown,
        "batch8_speedup_vs_serial_x":
            pr["batch8"]["speedup_vs_serial_x"],
        "profile_batch8_speedup_vs_serial_x":
            pr["profile_batch8"]["speedup_vs_serial_x"],
        "grant_vec_speedup_x": pr["grant_vec"]["speedup_x"],
        "stage_vec_speedup_x": pr["stage_vec"]["speedup_x"],
        "engine_cache": {
            "cached_rerun_traces": pr["cached_rerun"]["traces"],
            "managed_10w_entries": pr["managed_10w"]["entries"],
            "managed_10w_traces": pr["managed_10w"]["traces"],
        },
    }


_PLACEMENT_POLICIES = ("per_server", "first_fit", "best_fit", "slo_aware")


def summarize_placement(pr: dict, baseline: dict) -> dict:
    """Placement decision gate over the B=8 fleet (present in both quick
    and full runs): the per-tenant landing vectors (server index per
    tenant, -1 = rejected) per policy vs the committed baseline — so a
    count-preserving reshuffle of admissions still trips the gate — plus
    the slo_aware > per_server admission gain and first-fit parity."""
    b8, base8 = pr["B8"], baseline["B8"]
    admitted = {p: b8[p]["admitted"] for p in _PLACEMENT_POLICIES}
    drift = {}
    for p in _PLACEMENT_POLICIES:
        if admitted[p] != base8[p]["admitted"]:
            drift[p] = {"admitted": [admitted[p], base8[p]["admitted"]]}
        elif b8[p]["decisions"] != base8[p]["decisions"]:
            drift[p] = {"decisions": [b8[p]["decisions"],
                                      base8[p]["decisions"]]}
    gain = admitted["slo_aware"] - admitted["per_server"]
    return {
        "admitted_B8": admitted,
        "gain_slo_aware_vs_per_server": gain,
        "parity_first_fit_pinned": bool(b8["parity_first_fit_pinned"]),
        "decision_drift_vs_baseline": drift,
        "ok": (gain > 0 and not drift
               and bool(b8["parity_first_fit_pinned"])),
    }


_CHURN_COUNTS = ("admitted", "rejected", "departed", "migrated")


def summarize_churn(pr: dict, baseline: dict) -> dict:
    """Churn decision gate over the fixed B=8 timelines: lifecycle counts
    and per-event landing decisions are deterministic — any drift means a
    PR changed admission/placement/departure behavior; the variance and
    the one-engine-entry contract guard the dataplane side."""
    drift: dict = {}
    dev: dict = {}
    one_entry = True
    # iterate the UNION of timelines: a rate present on one side only is
    # itself drift (a PR must not silently shrink gate coverage)
    for rate in sorted(set(pr["B8"]) | set(baseline["B8"])):
        if rate not in pr["B8"] or rate not in baseline["B8"]:
            drift[rate] = {"missing_in": ("pr" if rate not in pr["B8"]
                                          else "baseline")}
            continue
        prr, base = pr["B8"][rate], baseline["B8"][rate]
        bad = {}
        for k in _CHURN_COUNTS:
            if prr[k] != base[k]:
                bad[k] = [prr[k], base[k]]
        if not bad and prr["decisions"] != base["decisions"]:
            bad["decisions"] = [prr["decisions"], base["decisions"]]
        if not bad and prr["moves"] != base["moves"]:
            bad["moves"] = [prr["moves"], base["moves"]]
        if bad:
            drift[rate] = bad
        dev[rate] = {
            "ref_dev_max_pct": prr["ref_dev_max_pct"],
            "baseline_pct": base["ref_dev_max_pct"],
            "ok": (abs(prr["ref_dev_max_pct"] - base["ref_dev_max_pct"])
                   <= 0.5
                   and prr["var_under_1pct"] == base["var_under_1pct"]),
        }
        one_entry &= prr["engine_entries"] == 1
    return {
        "counts_B8": {rate: {k: pr["B8"][rate][k] for k in _CHURN_COUNTS}
                      for rate in pr["B8"]},
        "decision_drift_vs_baseline": drift,
        "ref_deviation": dev,
        "one_engine_entry": one_entry,
        "ok": (not drift and one_entry
               and all(d["ok"] for d in dev.values())),
    }


_CONTENTION_ARMS = ("vector", "axis0", "mem_blind")


def summarize_contention(pr: dict, baseline: dict) -> dict:
    """Multi-resource contention gate over the fixed B=8 mixed fleet:
    per-arm admission counts and landing decisions are deterministic
    (profiling horizons are mode-independent) — any drift means a PR
    changed vector admission behavior; the SLO-friendly gain of vector
    scoring over the memory-blind control plane must stay strictly
    positive, the cross-resource (memory-axis) utilization variance of
    the vector placement must stay at or below the memory-blind one and
    within 0.05 of the committed baseline, and the R=1 degenerate
    bitwise gate must have held."""
    b8, base8 = pr["B8"], baseline["B8"]
    drift = {}
    for arm in _CONTENTION_ARMS:
        if b8[arm]["admitted"] != base8[arm]["admitted"]:
            drift[arm] = {"admitted": [b8[arm]["admitted"],
                                       base8[arm]["admitted"]]}
        elif b8[arm]["decisions"] != base8[arm]["decisions"]:
            drift[arm] = {"decisions": [b8[arm]["decisions"],
                                        base8[arm]["decisions"]]}
        elif b8[arm]["slo_friendly"] != base8[arm]["slo_friendly"]:
            drift[arm] = {"slo_friendly": [b8[arm]["slo_friendly"],
                                           base8[arm]["slo_friendly"]]}
    gain = (b8["vector"]["slo_friendly"]
            - b8["mem_blind"]["slo_friendly"])
    var = {arm: b8[arm]["mem_util_var"] for arm in _CONTENTION_ARMS}
    var_ok = (var["vector"] <= var["mem_blind"]
              and abs(var["vector"] - base8["vector"]["mem_util_var"])
              <= 0.05)
    return {
        "admitted_B8": {arm: b8[arm]["admitted"]
                        for arm in _CONTENTION_ARMS},
        "slo_friendly_B8": {arm: b8[arm]["slo_friendly"]
                            for arm in _CONTENTION_ARMS},
        "gain_slo_friendly_vector_vs_mem_blind": gain,
        "mem_util_var": var,
        "degenerate_bitwise": bool(b8["degenerate_bitwise"]),
        "decision_drift_vs_baseline": drift,
        "ok": (gain > 0 and var_ok and not drift
               and bool(b8["degenerate_bitwise"])),
    }


def summarize_adaptive(pr: dict, baseline: dict) -> dict:
    """Closed-loop shaping gate: the churn arm's violation-window counts
    are deterministic and mode-independent, so they must match the
    committed baseline exactly; the Fig. 9 arm's latencies scale with
    the quick/full horizon, so only its improvement facts are gated
    (adaptive strictly beats static p99 and keeps VM2's throughput
    within 5% of the static arm's)."""
    drift = {}
    for arm in ("static", "adaptive"):
        got = pr["churn"][arm]["violations"]
        want = baseline["churn"][arm]["violations"]
        if got != want:
            drift[arm] = {"violations": [got, want]}
    churn_gain = (pr["churn"]["static"]["violations"]
                  - pr["churn"]["adaptive"]["violations"])
    one_entry = all(
        pr[wl][arm].get("engine_entries") == 1
        for wl in ("churn", "fig9") for arm in ("static", "adaptive"))
    fig9 = pr["fig9"]
    p99x = fig9["p99_improvement_x"]
    vm2_ok = (fig9["adaptive"]["vm2_gbps"]
              >= 0.95 * fig9["static"]["vm2_gbps"])
    return {
        "churn_violations": {arm: pr["churn"][arm]["violations"]
                             for arm in ("static", "adaptive")},
        "churn_gain_static_minus_adaptive": churn_gain,
        "fig9_p99_improvement_x": p99x,
        "fig9_vm2_gbps_adaptive": fig9["adaptive"]["vm2_gbps"],
        "one_engine_entry": one_entry,
        "decision_drift_vs_baseline": drift,
        "ok": (not drift and one_entry and churn_gain > 0
               and p99x > 1.0 and vm2_ok),
    }


def summarize_scenarios(pr: dict, baseline: dict) -> dict:
    """Workload-scenario gate over the fixed named-scenario timelines
    (mode-independent, so the committed baseline gates smoke runs
    exactly): per-arm SLO-violation window counts and lifecycle
    decisions are deterministic — any drift means a PR changed a
    generator's rng stream, a scenario's tenant mix, or shaping
    behavior; every scenario must still ride ONE compiled engine entry
    across BOTH control arms, the reference tenants' cross-server
    deviation must stay within 0.5 percentage points of the baseline,
    and the adversarial probe's holds-under-1% verdicts must not flip
    silently."""
    drift: dict = {}
    dev: dict = {}
    one_entry = True
    prs, bases = pr["scenarios"], baseline["scenarios"]
    for name in sorted(set(prs) | set(bases)):
        if name not in prs or name not in bases:
            drift[name] = {"missing_in": ("pr" if name not in prs
                                          else "baseline")}
            continue
        p, b = prs[name], bases[name]
        bad = {}
        for arm in ("static", "adaptive"):
            if p[arm]["violations"] != b[arm]["violations"]:
                bad[f"{arm}_violations"] = [p[arm]["violations"],
                                            b[arm]["violations"]]
            if p[arm]["decisions"] != b[arm]["decisions"]:
                bad[f"{arm}_decisions"] = [p[arm]["decisions"],
                                           b[arm]["decisions"]]
        if bad:
            drift[name] = bad
        dev[name] = {
            "ref_dev_max_pct": p["static"]["ref_dev_max_pct"],
            "baseline_pct": b["static"]["ref_dev_max_pct"],
            "ok": abs(p["static"]["ref_dev_max_pct"]
                      - b["static"]["ref_dev_max_pct"]) <= 0.5,
        }
        one_entry &= p["engine_entries"] == 1
    probe_ok = True
    if pr.get("adversarial") and baseline.get("adversarial"):
        probe_ok = all(
            pr["adversarial"][k] == baseline["adversarial"][k]
            for k in ("holds_under_1pct_static",
                      "holds_under_1pct_adaptive"))
    return {
        "violations": {name: {arm: prs[name][arm]["violations"]
                              for arm in ("static", "adaptive")}
                       for name in prs},
        "decision_drift_vs_baseline": drift,
        "ref_deviation": dev,
        "adversarial_verdicts_stable": probe_ok,
        "one_engine_entry": one_entry,
        "ok": (not drift and one_entry and probe_ok
               and all(d["ok"] for d in dev.values())),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pr", required=True,
                    help="sim_perf.json from this PR's smoke run")
    ap.add_argument("--baseline", required=True,
                    help="committed benchmarks/results/sim_perf.json")
    ap.add_argument("--pr-placement", default=None,
                    help="placement.json from this PR's smoke run")
    ap.add_argument("--baseline-placement", default=None,
                    help="committed benchmarks/results/placement.json")
    ap.add_argument("--pr-churn", default=None,
                    help="churn.json from this PR's smoke run")
    ap.add_argument("--baseline-churn", default=None,
                    help="committed benchmarks/results/churn.json")
    ap.add_argument("--pr-contention", default=None,
                    help="contention.json from this PR's smoke run")
    ap.add_argument("--baseline-contention", default=None,
                    help="committed benchmarks/results/contention.json")
    ap.add_argument("--pr-adaptive", default=None,
                    help="adaptive.json from this PR's smoke run")
    ap.add_argument("--baseline-adaptive", default=None,
                    help="committed benchmarks/results/adaptive.json")
    ap.add_argument("--pr-scenarios", default=None,
                    help="scenarios.json from this PR's smoke run")
    ap.add_argument("--baseline-scenarios", default=None,
                    help="committed benchmarks/results/scenarios.json")
    ap.add_argument("--out", default="BENCH_pr.json")
    ap.add_argument("--max-slowdown", type=float, default=2.0)
    args = ap.parse_args()

    with open(args.pr) as f:
        pr = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    if bool(args.pr_placement) != bool(args.baseline_placement):
        ap.error("--pr-placement and --baseline-placement must be given "
                 "together (one alone would silently skip the placement "
                 "gate)")
    if bool(args.pr_churn) != bool(args.baseline_churn):
        ap.error("--pr-churn and --baseline-churn must be given together "
                 "(one alone would silently skip the churn gate)")
    if bool(args.pr_contention) != bool(args.baseline_contention):
        ap.error("--pr-contention and --baseline-contention must be given "
                 "together (one alone would silently skip the contention "
                 "gate)")
    if bool(args.pr_adaptive) != bool(args.baseline_adaptive):
        ap.error("--pr-adaptive and --baseline-adaptive must be given "
                 "together (one alone would silently skip the adaptive "
                 "gate)")
    if bool(args.pr_scenarios) != bool(args.baseline_scenarios):
        ap.error("--pr-scenarios and --baseline-scenarios must be given "
                 "together (one alone would silently skip the scenarios "
                 "gate)")
    out = summarize(pr, baseline, args.max_slowdown)
    if args.pr_placement and args.baseline_placement:
        with open(args.pr_placement) as f:
            pr_placement = json.load(f)
        with open(args.baseline_placement) as f:
            base_placement = json.load(f)
        out["placement"] = summarize_placement(pr_placement,
                                               base_placement)
    if args.pr_churn and args.baseline_churn:
        with open(args.pr_churn) as f:
            pr_churn = json.load(f)
        with open(args.baseline_churn) as f:
            base_churn = json.load(f)
        out["churn"] = summarize_churn(pr_churn, base_churn)
    if args.pr_contention and args.baseline_contention:
        with open(args.pr_contention) as f:
            pr_cont = json.load(f)
        with open(args.baseline_contention) as f:
            base_cont = json.load(f)
        out["contention"] = summarize_contention(pr_cont, base_cont)
    if args.pr_adaptive and args.baseline_adaptive:
        with open(args.pr_adaptive) as f:
            pr_adapt = json.load(f)
        with open(args.baseline_adaptive) as f:
            base_adapt = json.load(f)
        out["adaptive"] = summarize_adaptive(pr_adapt, base_adapt)
    if args.pr_scenarios and args.baseline_scenarios:
        with open(args.pr_scenarios) as f:
            pr_scen = json.load(f)
        with open(args.baseline_scenarios) as f:
            base_scen = json.load(f)
        out["scenarios"] = summarize_scenarios(pr_scen, base_scen)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(json.dumps(out, indent=1))
    ok = (out["ok"] and out.get("placement", {}).get("ok", True)
          and out.get("churn", {}).get("ok", True)
          and out.get("contention", {}).get("ok", True)
          and out.get("adaptive", {}).get("ok", True)
          and out.get("scenarios", {}).get("ok", True))
    if not out["ok"]:
        print(f"FAIL: cached rerun {out['cached_rerun_us_per_tick']:.1f} "
              f"us/tick is {out['slowdown_vs_baseline_x']:.2f}x the "
              f"committed baseline ({out['baseline_us_per_tick']:.1f}) — "
              f"limit {args.max_slowdown}x", file=sys.stderr)
    if not out.get("placement", {}).get("ok", True):
        print("FAIL: placement gate — admission gain lost, parity broken "
              f"or decisions drifted: {out['placement']}", file=sys.stderr)
    if not out.get("churn", {}).get("ok", True):
        print("FAIL: churn gate — lifecycle counts/decisions drifted, "
              "variance moved, or the timeline stopped being one "
              f"compiled engine entry: {out['churn']}", file=sys.stderr)
    if not out.get("contention", {}).get("ok", True):
        print("FAIL: contention gate — vector admission decisions "
              "drifted, the SLO-friendly gain over the memory-blind "
              "control plane was lost, or the cross-resource variance "
              f"moved: {out['contention']}", file=sys.stderr)
    if not out.get("adaptive", {}).get("ok", True):
        print("FAIL: adaptive gate — closed-loop shaping stopped beating "
              "StaticHold, churn violation counts drifted, or a timed "
              "run stopped being one compiled engine entry: "
              f"{out['adaptive']}", file=sys.stderr)
    if not out.get("scenarios", {}).get("ok", True):
        print("FAIL: scenarios gate — violation counts / lifecycle "
              "decisions drifted, reference variance moved, the "
              "adversarial verdicts flipped, or a scenario stopped "
              "being one compiled engine entry: "
              f"{out['scenarios']}", file=sys.stderr)
    if not ok:
        sys.exit(1)
    print(f"OK: cached rerun within {args.max_slowdown}x of baseline "
          f"({out['slowdown_vs_baseline_x']:.2f}x)"
          + ("" if "placement" not in out else
             "; placement decisions stable, slo_aware admission gain "
             f"+{out['placement']['gain_slo_aware_vs_per_server']}")
          + ("" if "churn" not in out else
             "; churn lifecycle decisions stable")
          + ("" if "contention" not in out else
             "; contention SLO-friendly gain "
             f"+{out['contention']['gain_slo_friendly_vector_vs_mem_blind']}"
             )
          + ("" if "adaptive" not in out else
             "; adaptive beats static "
             f"(-{out['adaptive']['churn_gain_static_minus_adaptive']} "
             "violation windows, fig9 p99 "
             f"{out['adaptive']['fig9_p99_improvement_x']:.2f}x)")
          + ("" if "scenarios" not in out else
             "; workload scenarios stable"))


if __name__ == "__main__":
    main()
